#!/usr/bin/env python
"""Trace report — walk an exported ``TRACE_*.json`` (Chrome-trace JSON,
written by ``Tracer.export``) and print, per task, the workflow's
critical path with the dominant latency segment at every hop.

Works from the exported file alone (stdlib only, no repro import): the
span tree is rebuilt from the ``args.span_id``/``args.parent_id`` the
exporter embeds in every complete event.

    PYTHONPATH=src python tools/trace_report.py artifacts/bench/TRACE_fig1.json
    python tools/trace_report.py --validate TRACE_*.json   # schema check

``--validate`` exits non-zero when a file is not loadable Chrome-trace
JSON (the CI schema gate).
"""
from __future__ import annotations

import argparse
import json
import sys
from dataclasses import dataclass, field
from typing import Optional

_EPS = 1e-9


@dataclass
class SpanView:
    """One span rebuilt from an exported complete ('X') event."""

    span_id: int
    name: str
    cat: str
    trace_id: str
    t0: float                      # seconds (events carry microseconds)
    t1: float
    parent_id: Optional[int] = None
    args: dict = field(default_factory=dict)

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


# ---------------------------------------------------------------------------
# loading + schema validation
# ---------------------------------------------------------------------------

def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


_PHASES = {"X", "B", "E", "i", "I", "M", "s", "f", "t", "C", "b", "e", "n"}


def validate(doc) -> list[str]:
    """Chrome-trace JSON shape errors ([] = valid).  Accepts the two
    legal top-level forms (object with ``traceEvents``, or a bare event
    array) and checks the fields every consumer (chrome://tracing,
    Perfetto) requires per event."""
    errors: list[str] = []
    if isinstance(doc, dict):
        events = doc.get("traceEvents")
        if not isinstance(events, list):
            return ["top-level object has no 'traceEvents' array"]
    elif isinstance(doc, list):
        events = doc
    else:
        return ["document is neither an object nor an event array"]
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _PHASES:
            errors.append(f"event {i}: bad phase {ph!r}")
        if not isinstance(ev.get("ts"), (int, float)):
            errors.append(f"event {i}: missing numeric 'ts'")
        if "pid" not in ev:
            errors.append(f"event {i}: missing 'pid'")
        if ph == "X" and not isinstance(ev.get("dur"), (int, float)):
            errors.append(f"event {i}: complete event missing 'dur'")
        if ph in ("s", "f") and "id" not in ev:
            errors.append(f"event {i}: flow event missing 'id'")
        if len(errors) >= 20:
            errors.append("... (truncated)")
            break
    return errors


def spans_from(doc) -> list[SpanView]:
    events = doc["traceEvents"] if isinstance(doc, dict) else doc
    out = []
    for ev in events:
        if ev.get("ph") != "X":
            continue
        a = ev.get("args") or {}
        if "span_id" not in a:
            continue
        t0 = ev["ts"] / 1e6
        out.append(SpanView(int(a["span_id"]), ev.get("name", ""),
                            ev.get("cat", ""), str(a.get("trace_id", "")),
                            t0, t0 + ev.get("dur", 0) / 1e6,
                            a.get("parent_id"), a))
    out.sort(key=lambda s: (s.t0, s.span_id))
    return out


def flow_links(doc) -> int:
    """Count of causal action→span links (flow-start events)."""
    events = doc["traceEvents"] if isinstance(doc, dict) else doc
    return sum(1 for ev in events if ev.get("ph") == "s")


# ---------------------------------------------------------------------------
# critical path
# ---------------------------------------------------------------------------

def _children(spans: list[SpanView]) -> dict[Optional[int], list[SpanView]]:
    idx: dict[Optional[int], list[SpanView]] = {}
    for s in spans:
        idx.setdefault(s.parent_id, []).append(s)
    return idx


def critical_path(spans: list[SpanView],
                  trace_id: str) -> list[SpanView]:
    """The chain of stage/request spans that determined the task's end:
    start from the hop finishing last, repeatedly step to the
    predecessor hop that finished latest no later than the current
    hop's start (the edge it actually waited on), prepend until the
    chain bottoms out at the task's first hop."""
    hops = [s for s in spans
            if s.trace_id == trace_id and s.cat in ("stage", "request")]
    if not hops:
        return []
    # workflow traces path over stages; flat fig1 traces over requests
    if any(s.cat == "stage" for s in hops):
        hops = [s for s in hops if s.cat == "stage"]
    path = [max(hops, key=lambda s: s.t1)]
    while True:
        cur = path[0]
        preds = [s for s in hops
                 if s is not cur and s.t1 <= cur.t0 + _EPS
                 and s not in path]
        if not preds:
            return path
        path.insert(0, max(preds, key=lambda s: s.t1))


def _descendant_segments(span: SpanView,
                         children: dict) -> dict[str, float]:
    """Summed cat=='segment' durations under a path hop (requests under
    a stage contribute theirs)."""
    segs: dict[str, float] = {}
    stack = [span]
    while stack:
        node = stack.pop()
        for c in children.get(node.span_id, ()):
            if c.cat == "segment":
                segs[c.name] = segs.get(c.name, 0.0) + c.dur
            else:
                stack.append(c)
    return segs


def dominant_segment(span: SpanView,
                     children: dict) -> tuple[str, float, float]:
    """(name, seconds, fraction-of-hop) of the hop's largest segment;
    ('-', 0, 0) when the hop recorded none."""
    segs = _descendant_segments(span, children)
    if not segs:
        return ("-", 0.0, 0.0)
    name = max(segs, key=lambda k: segs[k])
    return (name, segs[name], segs[name] / max(span.dur, _EPS))


def decomposition_check(spans: list[SpanView]) -> list[tuple]:
    """Per closed request span: (req span, segment sum, request dur).
    The acceptance criterion is |sum - dur| within 1% of dur."""
    children = _children(spans)
    out = []
    for s in spans:
        if s.cat != "request" or s.args.get("open"):
            continue
        total = sum(c.dur for c in children.get(s.span_id, ())
                    if c.cat == "segment")
        # pre-engine throttle spans are parented under the root too
        out.append((s, total, s.dur))
    return out


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

def report(doc, limit: int = 8) -> str:
    spans = spans_from(doc)
    children = _children(spans)
    tasks = [s for s in spans if s.cat == "task"]
    if not tasks:
        # flat traces (pool benches) have parentless request roots
        tasks = [s for s in spans
                 if s.cat == "request" and s.parent_id is None]
    lines = [f"{len(spans)} spans, {len(tasks)} traced tasks, "
             f"{flow_links(doc)} causal action links"]
    for task in sorted(tasks, key=lambda s: -s.dur)[:limit]:
        lines.append("")
        lines.append(f"{task.name}  [{task.t0:.3f}s → {task.t1:.3f}s]  "
                     f"e2e {task.dur * 1e3:.1f} ms")
        path = critical_path(spans, task.trace_id)
        if path and path[0] is not task:
            lines.append("  critical path:")
            for hop in path:
                seg, sec, frac = dominant_segment(hop, children)
                mark = (f"dominant: {seg} {sec * 1e3:.1f} ms "
                        f"({frac:.0%})" if seg != "-" else "no segments")
                lines.append(f"    {hop.name:<28s} "
                             f"[{hop.t0:.3f}, {hop.t1:.3f}]  "
                             f"{hop.dur * 1e3:7.1f} ms   {mark}")
        acts = task.args.get("actions") or []
        for a in acts:
            lines.append(f"    ! control: {a}")
    checks = decomposition_check(spans)
    if checks:
        worst = max(abs(tot - dur) / max(dur, _EPS)
                    for _, tot, dur in checks)
        lines.append("")
        lines.append(f"{len(checks)} closed requests; worst "
                     f"segment-sum/e2e mismatch {worst:.2%}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="+", help="TRACE_*.json files")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check only (CI gate); non-zero on error")
    ap.add_argument("--limit", type=int, default=8,
                    help="max tasks to print per trace")
    args = ap.parse_args(argv)
    bad = 0
    for path in args.paths:
        try:
            doc = load(path)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: UNREADABLE: {e}")
            bad += 1
            continue
        errors = validate(doc)
        if errors:
            print(f"{path}: INVALID")
            for e in errors:
                print(f"  {e}")
            bad += 1
            continue
        if args.validate:
            n = len(doc["traceEvents"] if isinstance(doc, dict) else doc)
            print(f"{path}: ok ({n} events)")
            continue
        print(f"== {path}")
        print(report(doc, limit=args.limit))
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
