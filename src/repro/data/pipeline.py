"""Token data pipeline: deterministic synthetic corpus, host-sharded,
with background prefetch.

The corpus is a seeded Zipf-ish token stream with local structure
(Markov bigram mixing) so the ~100M-model training example shows a real
loss curve, not memorized noise.  Every batch is derived from
``(seed, step)`` alone — restart-safe: after checkpoint restore the
pipeline regenerates exactly the batches it would have produced
(``state_dict``/``load_state`` carry the step counter).

Sharding: each data-parallel host generates only its slice of the global
batch (``host_index``/``host_count``), the standard per-host input
pipeline for multi-pod training.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    prefetch: int = 2
    zipf_a: float = 1.2
    mix: float = 0.7          # bigram-structure mixing weight


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.host_count == 0
        self.cfg = cfg
        self.step = 0
        self._local = cfg.global_batch // cfg.host_count
        # fixed bigram successor table: token t prefers (t*a+b)%V zone
        rng = np.random.default_rng(cfg.seed ^ 0x5EED)
        self._succ = rng.integers(0, cfg.vocab,
                                  size=(min(cfg.vocab, 4096),), dtype=np.int64)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = 1.0 / ranks ** cfg.zipf_a
        self._zipf = p / p.sum()
        self._queue: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- deterministic batch -----------------------------------------------------
    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, cfg.host_index))
        b, s = self._local, cfg.seq_len
        zipf_draw = rng.choice(cfg.vocab, size=(b, s + 1), p=self._zipf)
        toks = np.empty((b, s + 1), np.int64)
        toks[:, 0] = zipf_draw[:, 0]
        follow = rng.random((b, s)) < cfg.mix
        for t in range(1, s + 1):
            prev = toks[:, t - 1] % len(self._succ)
            structured = (self._succ[prev] + (t % 7)) % cfg.vocab
            toks[:, t] = np.where(follow[:, t - 1], structured,
                                  zipf_draw[:, t])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    # -- iteration + prefetch -----------------------------------------------------
    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        if self._queue is None:
            self._start_prefetch()
        batch = self._queue.get()
        self.step += 1
        return batch

    def _start_prefetch(self) -> None:
        self._queue = queue.Queue(maxsize=self.cfg.prefetch)
        start = self.step

        def worker():
            step = start
            while not self._stop.is_set():
                b = self.batch_at(step)
                while not self._stop.is_set():
                    try:
                        self._queue.put(b, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def close(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
        self._queue = None
        self._thread = None
        self._stop = threading.Event()

    # -- restart-safe state -------------------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def load_state(self, state: dict) -> None:
        assert state["seed"] == self.cfg.seed, "corpus seed mismatch"
        self.close()
        self.step = int(state["step"])
