"""Virtual time: a deterministic discrete-event loop.

Every latency-bearing component (engines, network links, agents, the
controller's poll loop) schedules callbacks on one ``EventLoop``; the
benchmarks advance virtual time until quiescence.  This is what makes the
paper's load sweeps (Fig 3/6/7) reproducible on a CPU-only container —
the *costs* come from the roofline model, the *ordering* from here.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable


class Clock:
    def __init__(self) -> None:
        self._now = 0.0

    def now(self) -> float:
        return self._now

    def _advance(self, t: float) -> None:
        assert t >= self._now - 1e-12, (t, self._now)
        self._now = max(self._now, t)


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    fn: Callable = field(compare=False)
    cancelled: bool = field(default=False, compare=False)


class EventLoop:
    """Single-threaded discrete-event scheduler over a virtual clock."""

    def __init__(self) -> None:
        self.clock = Clock()
        self._heap: list[_Event] = []
        self._seq = itertools.count()

    def now(self) -> float:
        return self.clock.now()

    def call_at(self, t: float, fn: Callable) -> _Event:
        ev = _Event(max(t, self.now()), next(self._seq), fn)
        heapq.heappush(self._heap, ev)
        return ev

    def call_after(self, dt: float, fn: Callable) -> _Event:
        return self.call_at(self.now() + dt, fn)

    def cancel(self, ev: _Event) -> None:
        ev.cancelled = True

    def run_until(self, t_end: float = float("inf"),
                  max_events: int = 10_000_000) -> None:
        n = 0
        while self._heap and n < max_events:
            ev = self._heap[0]
            if ev.time > t_end:
                break
            heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            self.clock._advance(ev.time)
            ev.fn()
            n += 1
        if t_end != float("inf"):
            self.clock._advance(t_end)

    def idle(self) -> bool:
        return not self._heap
