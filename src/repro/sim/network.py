"""Link model for agent-to-agent messages and KV-cache transfers.

Each directed link is a FIFO pipe with latency + bandwidth; transfers
serialize on the link (the availability horizon), which is what makes
proactive ("hinted") KV pushes overlap generation while reactive ones
serialize behind the request — the paper's Fig-7 mechanism.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.sim.clock import EventLoop

# Message-size model: tokens -> bytes on the wire (text + protocol framing)
BYTES_PER_TOKEN_WIRE = 6
MSG_HEADER_BYTES = 512          # per-message protocol/framing overhead
MSG_FIXED_LATENCY = 0.8e-3      # per-message RPC latency (s)


@dataclass
class Link:
    loop: EventLoop
    bandwidth: float = 12.5e9     # B/s (ICI/DCN-class for KV, NIC for msgs)
    latency: float = MSG_FIXED_LATENCY
    proc_time: float = 0.0        # per-message endpoint processing (serde,
                                  # protocol handling) — occupies the pipe
    name: str = "link"
    _free_at: float = field(default=0.0, repr=False)
    bytes_sent: float = field(default=0.0, repr=False)
    msgs_sent: int = field(default=0, repr=False)

    def transfer(self, nbytes: float, fn, extra_latency: float = 0.0):
        """Schedule ``fn`` at delivery time; returns the delivery time."""
        start = max(self.loop.now(), self._free_at)
        dur = nbytes / self.bandwidth + self.proc_time
        done = start + dur
        self._free_at = done
        deliver = done + self.latency + extra_latency
        self.bytes_sent += nbytes
        self.msgs_sent += 1
        self.loop.call_at(deliver, fn)
        return deliver

    def message_bytes(self, tokens: int) -> int:
        return MSG_HEADER_BYTES + tokens * BYTES_PER_TOKEN_WIRE

    @property
    def queue_delay(self) -> float:
        return max(0.0, self._free_at - self.loop.now())
