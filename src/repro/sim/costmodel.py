"""Roofline-derived engine cost model (TPU v5e constants, the same ones
EXPERIMENTS.md §Roofline uses).

Step latencies for the sim engine come from the same three-term roofline
the dry-run analysis reports:

    t_step = max(FLOPs / (chips·peak), bytes / (chips·hbm_bw)) + overhead

Two calibration entry points close the loop between this analytic form
and reality:

* ``from_dryrun`` rescales the analytic FLOPs with the compiled
  HLO_FLOPs/MODEL_FLOPs ratio from launch/dryrun.py artifacts (static:
  what the compiler built);
* ``from_calibration`` loads a ``CALIB_*.json`` artifact written by
  ``benchmarks/calibrate.py`` — ``flops_scale`` / ``bytes_scale`` /
  ``step_overhead`` least-squares-fitted to *measured* step times of the
  jitted prefill/decode functions (dynamic: what the hardware ran; see
  sim/calibration.py).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Optional

from repro.configs.base import ModelConfig

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip (TPU v5e)
HBM_BW = 819e9             # B/s per chip
ICI_BW = 50e9              # B/s per link
DCN_BW = 25e9              # B/s per pod link (cross-pod)
HOST_BW = 64e9             # B/s HBM<->host DMA (PCIe Gen5 x16-class)
STEP_OVERHEAD = 2.0e-4     # dispatch/launch overhead per engine step (s)
BYTES_PER_PARAM = 2        # bf16 weights


@dataclass
class CostModel:
    cfg: ModelConfig
    chips: int = 1
    flops_scale: float = 1.0      # HLO_FLOPs / MODEL_FLOPs (from dry-run)
    bytes_scale: float = 1.0
    step_overhead: float = STEP_OVERHEAD   # per-step dispatch cost (s)

    # -- static quantities ---------------------------------------------------
    def n_params(self) -> int:
        from repro.models import param_count
        return param_count(self.cfg)

    def n_active_params(self) -> int:
        """MoE: only top_k (+shared, +dense-residual) experts per token."""
        cfg = self.cfg
        if cfg.n_experts == 0:
            return self.n_params()
        from repro.models import param_count
        dense_equiv = cfg.replace(
            n_experts=cfg.top_k, top_k=cfg.top_k)
        return param_count(dense_equiv)

    def kv_bytes_per_token(self) -> int:
        cfg = self.cfg
        if cfg.family == "ssm":
            return 0  # constant-size state, no per-token growth
        per_layer = 2 * cfg.n_kv_heads * cfg.d_head * BYTES_PER_PARAM
        n_kv_layers = cfg.n_layers
        return per_layer * n_kv_layers

    def state_bytes(self) -> int:
        """Constant-size recurrent state (SSM/hybrid archs)."""
        cfg = self.cfg
        if cfg.family == "ssm":
            d_inner = cfg.ssm_expand * cfg.d_model
            dh = d_inner // max(cfg.n_heads, 1)
            return cfg.n_layers * cfg.n_heads * dh * (dh + 1) * 4
        if cfg.family == "hybrid":
            d_inner = cfg.ssm_expand * cfg.d_model
            return cfg.n_layers * d_inner * cfg.ssm_state * 4
        return 0

    def kv_transfer_bytes(self, context_len: int) -> int:
        """Bytes moved when migrating a request's decode state — bounded by
        the window for SWA layers (the controller's Fig-7 policy consumes
        this: SSM state is ~free to move, 500k dense KV is not)."""
        cfg = self.cfg
        if cfg.family == "ssm":
            return self.state_bytes()
        eff = context_len
        if cfg.window > 0 and not cfg.local_global_ratio:
            eff = min(context_len, cfg.window)
        return self.kv_bytes_per_token() * eff + self.state_bytes()

    def handoff_time(self, context_len: int, bandwidth: float = 12.5e9,
                     latency: float = 1.0e-3, overlap_s: float = 0.0) -> float:
        """Critical-path cost of a prefill→decode KV handoff.  The raw
        transfer moves ``kv_transfer_bytes(context_len)`` over the
        interconnect; ``overlap_s`` is the window the transfer ran
        concurrently with something useful (chunk-streamed handoffs
        overlap the tail of prefill), so only the non-overlapped
        remainder — floored at one link latency — is exposed to TTFT.
        Role-balancing policies consume this number when deciding
        whether flipping an engine's role pays."""
        raw = self.kv_transfer_bytes(context_len) / bandwidth + latency
        return max(raw - overlap_s, latency)

    def offload_time(self, context_len: int, bandwidth: float = HOST_BW,
                     latency: float = 0.5e-3) -> float:
        """HBM→host spill of a suspended sequence's KV over the host DMA
        link.  Off the critical path (the slot is already released when
        the copy runs), but OffloadPolicy charges it when deciding
        whether a suspend pays for itself."""
        return self.kv_transfer_bytes(context_len) / bandwidth + latency

    def restore_time(self, context_len: int, bandwidth: float = HOST_BW,
                     latency: float = 0.5e-3) -> float:
        """Host→HBM refill on resume — the post-tool TTFT tax a warm
        restore pays instead of a full recompute prefill."""
        return self.kv_transfer_bytes(context_len) / bandwidth + latency

    # -- step times -----------------------------------------------------------
    def _roofline(self, flops: float, bytes_: float) -> float:
        t_c = flops * self.flops_scale / (self.chips * PEAK_FLOPS)
        t_m = bytes_ * self.bytes_scale / (self.chips * HBM_BW)
        return max(t_c, t_m) + self.step_overhead

    def prefill_cost(self, prompt_tokens: int, batch: int = 1,
                     context: int = 0) -> tuple[float, float]:
        """Analytic (FLOPs, bytes) of one prefill step — the unscaled
        quantities the calibration fit regresses measured times onto."""
        n = self.n_active_params()
        toks = prompt_tokens * batch
        flops = 2.0 * n * toks
        # attention term (quadratic unless windowed); keys span the
        # resident context plus the new tokens
        cfg = self.cfg
        s_eff = context + prompt_tokens
        if cfg.window > 0:
            s_eff = min(s_eff, cfg.window)
        attn_flops = (4.0 * cfg.n_layers * cfg.n_heads * cfg.d_head
                      * prompt_tokens * s_eff * batch)
        bytes_ = (n * BYTES_PER_PARAM
                  + (toks + context * batch) * self.kv_bytes_per_token())
        return flops + attn_flops, bytes_

    def prefill_time(self, prompt_tokens: int, batch: int = 1,
                     context: int = 0) -> float:
        """Time to prefill ``prompt_tokens`` *new* tokens.  ``context`` is
        KV already resident (a cached shared prefix, or earlier chunks of
        a chunked prefill): it is not recomputed, but the new tokens
        attend over it, so it contributes attention FLOPs and KV reads —
        this is what makes prefix-cache savings hardware-honest rather
        than free."""
        return self._roofline(*self.prefill_cost(prompt_tokens, batch,
                                                 context))

    def decode_cost(self, batch: int,
                    mean_context: float) -> tuple[float, float]:
        """Analytic (FLOPs, bytes) of one decode step."""
        n = self.n_active_params()
        flops = 2.0 * n * batch
        cfg = self.cfg
        ctx = mean_context
        if cfg.window > 0 and not cfg.local_global_ratio:
            ctx = min(mean_context, cfg.window)
        # attention FLOPs over the resident context — symmetric with
        # prefill_cost's attention term (one new token, s_eff = ctx);
        # without it only the KV-read *bytes* were charged, so a
        # compute-bound long-context decode was mispriced as flat
        if cfg.family != "ssm":
            flops += 4.0 * cfg.n_layers * cfg.n_heads * cfg.d_head \
                * ctx * batch
        kv_read = batch * ctx * self.kv_bytes_per_token()
        bytes_ = n * BYTES_PER_PARAM + kv_read + batch * self.state_bytes()
        return flops, bytes_

    def decode_time(self, batch: int, mean_context: float) -> float:
        return self._roofline(*self.decode_cost(batch, mean_context))

    def mixed_cost(self, prefill_tokens: int, context: int, batch: int,
                   mean_context: float) -> tuple[float, float]:
        """Analytic (FLOPs, bytes) of one *mixed* step: a prefill chunk
        co-running with ``batch`` decode slots in a single fused forward.
        FLOPs add; bytes add MINUS one full weight read — the fusion
        saving that makes mixed batching cheaper than a prefill step
        plus a decode step run back to back (the weights stream through
        the chip once, amortized over both workloads)."""
        pf_f, pf_b = self.prefill_cost(prefill_tokens, context=context)
        if batch <= 0:
            return pf_f, pf_b
        dc_f, dc_b = self.decode_cost(batch, mean_context)
        weight_read = self.n_active_params() * BYTES_PER_PARAM
        return pf_f + dc_f, pf_b + dc_b - weight_read

    def mixed_time(self, prefill_tokens: int, context: int, batch: int,
                   mean_context: float) -> float:
        return self._roofline(*self.mixed_cost(prefill_tokens, context,
                                               batch, mean_context))

    def call_time(self, prompt_tokens: int, new_tokens: int,
                  context: int = 0, batch: int = 1) -> float:
        """Estimated end-to-end time of one agent call: prefill the
        prompt, then decode ``new_tokens`` one step each at the mean
        context reached while generating.  The workflow graph plane uses
        this as the per-stage cost when deriving critical-path
        priorities and edge-propagated deadlines — an *estimate* (real
        steps batch with co-resident requests), but the relative stage
        weights are what the scheduler needs."""
        t = self.prefill_time(prompt_tokens, batch=batch, context=context)
        mean_ctx = context + prompt_tokens + new_tokens / 2.0
        return t + new_tokens * self.decode_time(batch, mean_ctx)

    # -- calibration -----------------------------------------------------------
    @classmethod
    def from_dryrun(cls, cfg: ModelConfig, chips: int,
                    artifact: Optional[Path]) -> "CostModel":
        """Static calibration: rescale analytic FLOPs by the compiled
        HLO_FLOPs/MODEL_FLOPs ratio from a launch/dryrun.py artifact."""
        cm = cls(cfg, chips)
        if artifact and Path(artifact).exists():
            data = json.loads(Path(artifact).read_text())
            model_flops = data.get("model_flops")
            hlo_flops = data.get("flops")
            if model_flops and hlo_flops and model_flops > 0:
                cm.flops_scale = max(1.0, hlo_flops / model_flops)
        return cm

    @classmethod
    def from_calibration(cls, cfg: ModelConfig, chips: int,
                         artifact: Optional[Path]) -> "CostModel":
        """Measured calibration: load the fitted ``flops_scale`` /
        ``bytes_scale`` / ``step_overhead`` from a ``CALIB_*.json``
        artifact (benchmarks/calibrate.py).  Missing/invalid artifacts
        fall back to the hand-set roofline constants."""
        cm = cls(cfg, chips)
        if not artifact:
            return cm
        from repro.sim.calibration import load_calibration
        calib = load_calibration(artifact)
        if calib is None:
            return cm
        cm.flops_scale = calib.flops_scale
        cm.bytes_scale = calib.bytes_scale
        cm.step_overhead = calib.step_overhead
        return cm


DEFAULT_CALIB_DIR = "artifacts/bench"


def costmodel_for(cfg: ModelConfig, chips: int = 1,
                  calib_dir=None) -> CostModel:
    """The one constructor sim engines should use: resolve the per-model
    measured-calibration artifact ``CALIB_{cfg.name}.json`` (written by
    ``benchmarks/calibrate.py``) under ``calib_dir``, the
    ``REPRO_CALIB_DIR`` environment variable, or the default benchmark
    artifact dir, and build the CostModel from it.  Missing/invalid
    artifacts fall back to the analytic roofline constants, so sims stay
    runnable on a fresh checkout."""
    import os
    if calib_dir is None:
        calib_dir = os.environ.get("REPRO_CALIB_DIR", DEFAULT_CALIB_DIR)
    artifact = Path(calib_dir) / f"CALIB_{cfg.name}.json"
    return CostModel.from_calibration(cfg, chips, artifact)
