"""Measured calibration of the roofline CostModel.

``benchmarks/calibrate.py`` times the *actual* jitted engine step
functions (``models.prefill`` / ``models.decode_step`` — the same
executables serving/engine.py dispatches) across a (batch × context)
grid, pairs each measurement with the analytic (FLOPs, bytes) that
``CostModel.prefill_cost`` / ``decode_cost`` charge for that shape, and
this module fits the three roofline free parameters

    t = max(flops_scale · t_c, bytes_scale · t_m) + step_overhead

by alternating least squares: classify every point compute- or
memory-bound under the current scales, solve the resulting *linear*
system (weighted by 1/measured so small decode steps count as much as
big prefills), re-classify, repeat to a fixed point.  The fitted
``Calibration`` round-trips through ``CALIB_*.json`` artifacts that
``CostModel.from_calibration`` loads — closing the loop between the sim
plane's predictions and what the hardware (or XLA backend) really ran.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Optional, Sequence

import numpy as np

from repro.sim.costmodel import HBM_BW, PEAK_FLOPS

CALIB_VERSION = 1

# Fit-quality gates declared in the artifact: measured step times on a
# real accelerator are stable enough for a tight band; XLA-CPU timings
# (CI smoke) jitter more and go superlinear at larger shapes (cache
# effects the roofline's max() cannot express), so the cpu gate is
# looser rather than flaky — it validates the plumbing, not CPU-as-TPU.
TOLERANCE = {"tpu": 0.35, "gpu": 0.35, "cpu": 0.75}


@dataclass
class CalibrationPoint:
    """One measured grid point: a step shape, its analytic cost, and the
    wall-clock the jitted step actually took."""

    kind: str              # "prefill" | "decode"
    batch: int
    context: int           # prompt length (prefill) / resident KV (decode)
    flops: float           # analytic FLOPs (CostModel.*_cost, unscaled)
    bytes: float           # analytic bytes moved
    measured_s: float      # measured wall-clock of one jitted step


@dataclass
class Calibration:
    """Fitted roofline parameters + the evidence they were fitted to."""

    model: str
    chips: int
    backend: str           # jax.default_backend() at measurement time
    flops_scale: float
    bytes_scale: float
    step_overhead: float
    tolerance: float
    max_rel_err: float
    within_tolerance: bool
    points: list[CalibrationPoint] = field(default_factory=list)

    def predict(self, p: CalibrationPoint) -> float:
        t_c = p.flops * self.flops_scale / (self.chips * PEAK_FLOPS)
        t_m = p.bytes * self.bytes_scale / (self.chips * HBM_BW)
        return max(t_c, t_m) + self.step_overhead

    def rel_errors(self) -> list[float]:
        return [abs(self.predict(p) - p.measured_s) / max(p.measured_s, 1e-12)
                for p in self.points]


# ---------------------------------------------------------------------------
# Fitting
# ---------------------------------------------------------------------------
def fit_roofline(points: Sequence[CalibrationPoint], chips: int = 1,
                 max_iters: int = 64) -> tuple[float, float, float]:
    """Fit (flops_scale, bytes_scale, step_overhead) to measured points.

    The roofline is piecewise-linear in the parameters once each point's
    binding resource is known, so we alternate: assign each point to the
    compute or memory branch under the current scales, weighted-least-
    squares the now-linear model (weights 1/measured ⇒ relative-error
    objective), and iterate until the assignment is a fixed point.
    """
    if not points:
        return 1.0, 1.0, 0.0
    t_c = np.array([p.flops / (chips * PEAK_FLOPS) for p in points])
    t_m = np.array([p.bytes / (chips * HBM_BW) for p in points])
    y = np.array([p.measured_s for p in points])
    w = 1.0 / np.maximum(y, 1e-12)          # relative-error weighting
    fs, bs, c = 1.0, 1.0, 0.0
    assign = t_c >= t_m                     # start from the raw roofline

    def solve(mask: np.ndarray) -> tuple[float, float, float]:
        cols = [np.where(mask, t_c, 0.0), np.where(~mask, t_m, 0.0),
                np.ones_like(y)]
        a = np.stack(cols, axis=1) * w[:, None]
        sol, *_ = np.linalg.lstsq(a, y * w, rcond=None)
        if sol[2] < 0.0:                    # overhead can't be negative:
            sol, *_ = np.linalg.lstsq(a[:, :2], y * w, rcond=None)
            sol = np.array([sol[0], sol[1], 0.0])
        return float(sol[0]), float(sol[1]), float(sol[2])

    for _ in range(max_iters):
        nfs, nbs, nc = solve(assign)
        # a branch with no assigned points is unconstrained by the data —
        # keep its previous scale instead of trusting lstsq's null answer
        if assign.any():
            fs = max(nfs, 1e-12)
        if (~assign).any():
            bs = max(nbs, 1e-12)
        c = max(nc, 0.0)
        new_assign = fs * t_c >= bs * t_m
        if bool(np.all(new_assign == assign)):
            break
        assign = new_assign
    return fs, bs, c


def calibrate(model: str, backend: str,
              points: Sequence[CalibrationPoint], chips: int = 1,
              tolerance: Optional[float] = None) -> Calibration:
    """Fit + evaluate: returns a Calibration whose ``within_tolerance``
    says whether every grid point's prediction landed inside the band."""
    fs, bs, c = fit_roofline(points, chips)
    tol = TOLERANCE.get(backend, TOLERANCE["cpu"]) \
        if tolerance is None else tolerance
    calib = Calibration(model=model, chips=chips, backend=backend,
                        flops_scale=fs, bytes_scale=bs, step_overhead=c,
                        tolerance=tol, max_rel_err=0.0,
                        within_tolerance=True, points=list(points))
    errs = calib.rel_errors()
    calib.max_rel_err = max(errs) if errs else 0.0
    calib.within_tolerance = calib.max_rel_err <= tol
    return calib


# ---------------------------------------------------------------------------
# Artifact I/O
# ---------------------------------------------------------------------------
def save_calibration(calib: Calibration, path: Path) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    payload = {"version": CALIB_VERSION, **asdict(calib)}
    path.write_text(json.dumps(payload, indent=2) + "\n")
    return path


def load_calibration(path) -> Optional[Calibration]:
    """Load a CALIB_*.json; None for missing/invalid/unknown-version
    artifacts so callers fall back to the analytic constants."""
    path = Path(path)
    if not path.exists():
        return None
    try:
        data = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError):
        return None
    if data.get("version") != CALIB_VERSION:
        return None
    try:
        points = [CalibrationPoint(**p) for p in data.get("points", [])]
        return Calibration(
            model=data["model"], chips=data["chips"],
            backend=data["backend"], flops_scale=data["flops_scale"],
            bytes_scale=data["bytes_scale"],
            step_overhead=data["step_overhead"],
            tolerance=data["tolerance"], max_rel_err=data["max_rel_err"],
            within_tolerance=data["within_tolerance"], points=points)
    except (KeyError, TypeError):
        return None
