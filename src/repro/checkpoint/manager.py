"""Atomic, keep-K, optionally-async checkpointing.

Layout:  <dir>/step_<n>/  arrays.npz  +  meta.json  +  _COMPLETE
Atomicity: write into ``<dir>/.tmp_<n>``, fsync, then ``os.rename`` —
a crashed writer never leaves a half checkpoint that restore could pick
up (restore only considers directories with the ``_COMPLETE`` marker).

``save(..., blocking=False)`` hands the (host-fetched) pytree to a
writer thread so the train loop overlaps checkpoint I/O with compute —
the async-checkpoint trick every large run uses.  ``restore_latest``
reshards onto the current mesh via the provided shardings (elastic
restarts onto a different topology work as long as dims stay divisible).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ----------------------------------------------------------------
    _BITS = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}

    def save(self, step: int, tree: Any, meta: Optional[dict] = None,
             blocking: bool = True) -> None:
        """``tree`` may contain jax Arrays (fetched here) or numpy."""
        self.wait()                           # one async save at a time
        leaves, treedef = jax.tree.flatten(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        dtypes = []
        payload = {}
        for i, x in enumerate(host_leaves):
            dtypes.append(str(x.dtype))
            if x.dtype.kind not in "biufc":   # bf16/f8 etc: store raw bits
                x = x.view(self._BITS[x.dtype.itemsize])
            payload[f"leaf_{i}"] = x
        meta = dict(meta or {})
        meta["step"] = step
        meta["treedef"] = str(treedef)
        meta["n_leaves"] = len(host_leaves)
        meta["dtypes"] = dtypes

        if blocking:
            self._write(step, payload, meta)
        else:
            t = threading.Thread(target=self._write_guarded,
                                 args=(step, payload, meta), daemon=True)
            self._thread = t
            t.start()

    def _write_guarded(self, step, payload, meta):
        try:
            self._write(step, payload, meta)
        except BaseException as e:          # surfaced on next wait()
            self._error = e

    def _write(self, step: int, payload: dict, meta: dict) -> None:
        tmp = self.dir / f".tmp_{step}"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **payload)
        (tmp / "meta.json").write_text(json.dumps(
            {k: v for k, v in meta.items()}, default=str))
        (tmp / "_COMPLETE").touch()
        with open(tmp / "_COMPLETE", "rb") as f:
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint write failed") from err

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- restore -----------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "_COMPLETE").exists():
                try:
                    out.append(int(p.name.split("_", 1)[1]))
                except ValueError:
                    continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, like: Any,
                shardings: Optional[Any] = None) -> tuple[Any, dict]:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        NamedShardings to place leaves onto the current mesh."""
        path = self.dir / f"step_{step}"
        if not (path / "_COMPLETE").exists():
            raise FileNotFoundError(f"incomplete checkpoint: {path}")
        with np.load(path / "arrays.npz") as z:
            leaves = [z[f"leaf_{i}"] for i in range(len(z.files))]
        meta = json.loads((path / "meta.json").read_text())
        saved_dtypes = meta.get("dtypes")
        if saved_dtypes:
            leaves = [a.view(np.dtype(d)) if a.dtype.kind in "u"
                      and np.dtype(d).kind not in "biufc" else a
                      for a, d in zip(leaves, saved_dtypes)]
        _, treedef = jax.tree.flatten(like)
        assert treedef.num_leaves == len(leaves), \
            f"leaf count mismatch: ckpt {len(leaves)} vs {treedef.num_leaves}"
        ref = jax.tree.leaves(like)
        cast = []
        for a, r in zip(leaves, ref):
            dt = getattr(r, "dtype", None)
            cast.append(a.astype(dt) if dt is not None else a)
        if shardings is not None:
            flat_sh = treedef.flatten_up_to(shardings)
            cast = [jax.device_put(a, s) if s is not None else a
                    for a, s in zip(cast, flat_sh)]
        return jax.tree.unflatten(treedef, cast), meta

    def restore_latest(self, like: Any, shardings: Optional[Any] = None):
        step = self.latest_step()
        if step is None:
            return None
        tree, meta = self.restore(step, like, shardings)
        return step, tree, meta
