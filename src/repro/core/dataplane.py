"""Configurable data plane (paper §3.3).

``Channel`` is the *shim layer* between the agent-protocol surface
(agents/protocol.py exposes an A2A-like API on top of it) and the
transport (sim/network.Link).  It owns the attributes the paper wants
runtime-controllable:

* **granularity** — BATCH / PIPELINE / STREAM buffering of the producer's
  token flow (Fig 2).  Switchable mid-task: buffered content flushes
  under the new mode's boundary rules from that point on.
* **pacing** — a minimum inter-message gap, so the controller can slow a
  chatty producer without touching the agent.
* **priority** — stamped on every message; downstream engines' schedulers
  honor it (pipeline-wide prioritization).
* **speculative gating** — request-level rule hook: speculative messages
  are held in the shim until the controller releases them ("when an agent
  sends a speculative request, block it until resources are free").

Every knob goes through the same two-function ``set()/reset()`` surface
(Table 1) as engines and agents, so the controller needs exactly one
integration.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Protocol

from repro.core.knobs import ControlSurface, KnobSpec
from repro.core.types import Granularity, Message, Priority
from repro.sim.clock import EventLoop
from repro.sim.network import Link


class Endpoint(Protocol):
    name: str

    def deliver(self, msg: Message) -> None: ...


@dataclass
class _TaskBuf:
    task_id: str
    session: Optional[str] = None
    tokens: int = 0                  # buffered, not yet flushed
    units: int = 0                   # completed units in buffer
    total_tokens: int = 0
    total_units: int = 0
    meta: dict = field(default_factory=dict)
    speculative: bool = False
    open_unit_tokens: int = 0        # tokens in the currently-open unit


class Channel(ControlSurface):
    """One directed agent→agent (or agent→router) communication shim."""

    kind = "channel"
    CAPABILITIES = ("granularity", "pace", "gate")
    METRICS = ("msgs_sent", "bytes_sent", "link_delay")
    KNOB_SPECS = (
        KnobSpec("granularity", enum=Granularity,
                 on_change="_granularity_changed",
                 doc="BATCH/PIPELINE/STREAM buffering of the token flow"),
        KnobSpec("stream_chunk", kind="int", lo=1,
                 doc="tokens per message under STREAM"),
        KnobSpec("pace", kind="float", lo=0.0,
                 doc="min seconds between flushes"),
        KnobSpec("priority", enum=Priority,
                 doc="priority stamped on outgoing messages"),
        KnobSpec("gate_speculative", kind="bool", on_change="_gate_changed",
                 doc="hold speculative messages until released"),
    )

    def __init__(self, loop: EventLoop, link: Link, src: str, dst: Endpoint,
                 name: Optional[str] = None, collector=None,
                 granularity: Granularity = Granularity.BATCH,
                 stream_chunk: int = 8):
        self.loop = loop
        self.link = link
        self.src = src
        self.dst = dst
        self.name = name or f"{src}->{dst.name}"
        self.collector = collector
        self.granularity = granularity
        self.stream_chunk = int(stream_chunk)
        self.pace = 0.0                      # min seconds between flushes
        self.priority = Priority.NORMAL
        self.gate_speculative = False
        self._bufs: dict[str, _TaskBuf] = {}
        self._held: list[Message] = []       # gated speculative messages
        self._last_flush = -1e18
        self.msgs_sent = 0
        self.tokens_sent = 0

    # -------------------------------------------------- knob change hooks
    # (get/set/reset/card come from ControlSurface)
    def _granularity_changed(self, old, new) -> None:
        # re-evaluate buffers under the new mode immediately
        for buf in list(self._bufs.values()):
            self._maybe_flush(buf)

    def _gate_changed(self, old, new) -> None:
        if not new:
            self.release_held()

    # ------------------------------------------------------------- producer
    def begin_task(self, task_id: str, session: Optional[str] = None,
                   speculative: bool = False, **meta) -> None:
        self._bufs[task_id] = _TaskBuf(task_id, session, meta=dict(meta),
                                       speculative=speculative)

    def push_tokens(self, task_id: str, n: int = 1) -> None:
        buf = self._bufs[task_id]
        buf.tokens += n
        buf.total_tokens += n
        buf.open_unit_tokens += n
        if self.granularity is Granularity.STREAM:
            while buf.tokens >= self.stream_chunk:
                self._flush(buf, self.stream_chunk)

    def end_unit(self, task_id: str) -> None:
        buf = self._bufs[task_id]
        buf.units += 1
        buf.total_units += 1
        buf.open_unit_tokens = 0
        if self.granularity is Granularity.PIPELINE:
            self._flush(buf, buf.tokens, unit_end=True)
        elif self.granularity is Granularity.STREAM and buf.tokens:
            self._flush(buf, buf.tokens, unit_end=True)

    def end_task(self, task_id: str) -> None:
        buf = self._bufs.pop(task_id)
        self._flush(buf, buf.tokens, unit_end=buf.units > 0, task_end=True)

    # ------------------------------------------------------------- flushing
    def _maybe_flush(self, buf: _TaskBuf) -> None:
        """Apply the current mode's boundary rule to buffered content
        (used after a mid-task granularity switch)."""
        if self.granularity is Granularity.STREAM:
            while buf.tokens >= self.stream_chunk:
                self._flush(buf, self.stream_chunk)
        elif self.granularity is Granularity.PIPELINE and buf.units > 0:
            # flush all *complete* units; keep the open unit buffered
            done = buf.tokens - buf.open_unit_tokens
            if done > 0:
                self._flush(buf, done, unit_end=True)

    def _flush(self, buf: _TaskBuf, tokens: int, unit_end: bool = False,
               task_end: bool = False) -> None:
        units = buf.units if (unit_end or task_end) else 0
        msg = Message(
            src=self.src, dst=self.dst.name,
            payload={"session": buf.session, "unit_end": unit_end,
                     "task_end": task_end, "units": units, **buf.meta},
            units=max(units, 1), tokens=tokens,
            granularity=self.granularity, priority=self.priority,
            created_at=self.loop.now(), task_id=buf.task_id,
            speculative=buf.speculative)
        buf.tokens -= tokens
        buf.units = 0
        if msg.speculative and self.gate_speculative:
            self._held.append(msg)
            return
        self._send(msg)

    def _send(self, msg: Message) -> None:
        delay = 0.0
        if self.pace > 0:
            gap = self.loop.now() - self._last_flush
            if gap < self.pace:
                delay = self.pace - gap
        self._last_flush = self.loop.now() + delay
        nbytes = self.link.message_bytes(msg.tokens)
        self.link.transfer(nbytes, lambda m=msg: self.dst.deliver(m),
                           extra_latency=delay)
        self.msgs_sent += 1
        self.tokens_sent += msg.tokens
        if self.collector is not None:
            t = self.loop.now()
            self.collector.counter(f"{self.name}.msgs_sent", 1, t)
            self.collector.counter(f"{self.name}.bytes_sent", nbytes, t)
            self.collector.gauge(f"{self.name}.link_delay",
                                 self.link.queue_delay, t)

    # ------------------------------------------------------------ gating
    def release_held(self) -> None:
        held, self._held = self._held, []
        for msg in held:
            self._send(msg)

    @property
    def held_count(self) -> int:
        return len(self._held)
