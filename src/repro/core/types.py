"""Shared vocabulary of the serving stack: requests, messages, communication
granularities, priorities.  Used by every plane, the engines, and the sim."""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Any, Optional

_ids = itertools.count()


def fresh_id(prefix: str = "r") -> str:
    return f"{prefix}{next(_ids)}"


class Granularity(str, enum.Enum):
    """Message granularity on an agent-to-agent channel — the paper's core
    data-plane knob (Fig 2): batch the whole response, pipeline it
    unit-by-unit (e.g. function-by-function), or stream token-by-token."""

    BATCH = "batch"
    PIPELINE = "pipeline"
    STREAM = "stream"


class Priority(int, enum.Enum):
    LOW = 0
    NORMAL = 1
    HIGH = 2
    INTERACTIVE = 3


class SLOClass(str, enum.Enum):
    """Service class of a tenant's traffic (the multi-tenant SLO plane's
    coarse vocabulary): ``gold`` is latency-sensitive interactive work
    with a TTFT target, ``standard`` is ordinary traffic, ``batch`` is
    deferrable throughput work the controller may pause under pressure."""

    GOLD = "gold"
    STANDARD = "standard"
    BATCH = "batch"


class RequestState(str, enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    RUNNING = "running"
    PREEMPTED = "preempted"
    HANDOFF = "handoff"      # prefill done; KV in flight to a decode engine
    SUSPENDED = "suspended"  # parked on an external wait (tool call)
    FINISHED = "finished"
    FAILED = "failed"


@dataclass
class Request:
    """One LLM inference request inside an engine."""

    prompt_len: int
    max_new_tokens: int
    req_id: str = field(default_factory=lambda: fresh_id("req"))
    priority: Priority = Priority.NORMAL
    arrival_time: float = 0.0
    # workflow-plane metadata: the stage that issued the call and its
    # propagated finish deadline (inf = none).  The scheduler orders the
    # waiting queue EDF-within-priority over ``deadline``, so defaults
    # leave every pre-graph call site's behaviour untouched.
    deadline: float = float("inf")
    stage: Optional[str] = None
    # tenancy-plane metadata: which tenant issued the request and its
    # service class.  Defaults leave every pre-tenancy call site's
    # behaviour untouched (one implicit "default" tenant, standard SLO).
    tenant: str = "default"
    slo_class: str = SLOClass.STANDARD.value
    # engine-assigned
    state: RequestState = RequestState.QUEUED
    slot: int = -1
    prefilled: int = 0              # prompt tokens already prefilled
    available: int = -1             # prompt tokens that have *arrived*
                                    # (-1 => all; grows under streaming)
    generated: int = 0
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    # payloads (real engine)
    prompt_tokens: Optional[Any] = None      # np.ndarray int32
    output_tokens: list = field(default_factory=list)
    # pipeline metadata
    parent_task: Optional[str] = None
    meta: dict = field(default_factory=dict)

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.generated

    def feed(self, n: int) -> None:
        """More prompt tokens arrived (progressive prefill under
        STREAM granularity)."""
        self.available = min(self.prompt_len, max(self.available, 0) + n)

    @property
    def done(self) -> bool:
        return self.generated >= self.max_new_tokens


@dataclass
class Message:
    """A unit of agent-to-agent communication flowing through the data
    plane shim.  ``granularity`` is stamped by the shim when the channel's
    mode is applied; ``units`` counts the logical content units (tokens
    for STREAM, functions for PIPELINE, whole responses for BATCH)."""

    src: str
    dst: str
    payload: Any
    units: int = 1
    tokens: int = 0
    granularity: Granularity = Granularity.BATCH
    priority: Priority = Priority.NORMAL
    msg_id: str = field(default_factory=lambda: fresh_id("msg"))
    created_at: float = 0.0
    task_id: Optional[str] = None
    speculative: bool = False
    # tenancy plane: stamped by the issuing workload / pool so routers
    # can meter per-tenant admission ahead of the policy pick
    tenant: str = "default"
    slo_class: str = SLOClass.STANDARD.value


@dataclass
class AgentCard:
    """Registration record (the paper's §3.1 agent/tool hooks): identity
    plus the advertised set()-able knobs and exported metrics."""

    name: str
    kind: str                        # 'llm' | 'tool'
    knobs: dict[str, Any] = field(default_factory=dict)      # name -> default
    metrics: tuple[str, ...] = ()
    capabilities: tuple[str, ...] = ()   # e.g. ('kv_transfer', 'pause')
