"""Metrics plane (paper §3.2).

Two-tier design, exactly as proposed:

* ``Collector`` — the *local metric collector* at each node.  Writes go
  into fixed-size ring buffers (the paper's "lightweight shared-memory
  structures"): O(1) per observation, no allocation on the hot path, and
  bounded memory regardless of traffic.
* ``CentralPoller`` — the control plane's *centralized polling* façade:
  it fetches windows from every registered collector **on demand** (no
  constant streaming) and materializes aggregates into the controller's
  ``StateStore``.
* ``AGGREGATIONS`` — the *flexible aggregation functions*; callers can
  register custom ones (``register_aggregation``) without touching the
  plane, as §3.2 requires for mixed-volume metrics (per-token TPT vs
  per-query TTFT).
* ``MetricSpec`` — the *metric specification language* giving the
  controller semantic understanding (direction, kind, unit).  Specs come
  from structured dicts (the paper's JSON/YAML path) or from
  ``MetricSpec.from_docstring`` — a deterministic parser over the
  natural-language docstring grammar (the paper suggests an LLM here; we
  keep the interface and make the transform rule-based so the container
  needs no model).
"""
from __future__ import annotations

import fnmatch
import math
import re
from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional


# ---------------------------------------------------------------------------
# Metric bus (event tier)
# ---------------------------------------------------------------------------


@dataclass
class ThresholdSub:
    """One threshold subscription: fire ``fn(name, value, t)`` when a
    published sample enters the subscribed region.

    Edge-triggered by default: the subscription re-arms only after a
    sample *leaves* the region, so a sustained breach fires once, not on
    every sample.  ``cooldown`` additionally rate-limits fires.
    """

    sub_id: int
    metric: str                          # exact name or glob
    fn: Callable[[str, float, float], None]
    above: Optional[float] = None
    below: Optional[float] = None
    predicate: Optional[Callable[[float], bool]] = None
    cooldown: float = 0.0
    edge: bool = True
    fires: int = 0
    # per concrete metric name: a glob subscription must track each
    # matched series independently, or one instance's breach would
    # suppress / mask another's
    _in_region: dict = field(default_factory=dict)
    _last_fire: dict = field(default_factory=dict)

    def _hit(self, value: float) -> bool:
        if self.predicate is not None:
            return bool(self.predicate(value))
        if self.above is not None and value > self.above:
            return True
        if self.below is not None and value < self.below:
            return True
        return False

    def check(self, name: str, value: float, t: float) -> bool:
        hit = self._hit(value)
        was = self._in_region.get(name, False)
        if not hit:
            self._in_region[name] = False
            return False
        if self.edge and was:
            return False
        if t - self._last_fire.get(name, -math.inf) < self.cooldown:
            return False               # suppressed: stay ARMED, so the
                                       # breach fires once cooldown expires
        self._in_region[name] = True   # entry recorded only on a real fire
        self._last_fire[name] = t
        self.fires += 1
        self.fn(name, value, t)
        return True


class MetricBus:
    """Push tier of the metrics plane: components publish deltas as they
    write, and threshold subscriptions fire *between* controller polls.

    The interval poll path scans every ring of every collector each
    tick; the bus inverts that — O(subscriptions-on-this-metric) per
    observation, nothing at all for unwatched metrics — which is the
    shape that scales to large fleets.  The controller runs both paths
    (hybrid): polls for policy state, bus events for fast reaction.
    """

    def __init__(self):
        self._exact: dict[str, list[ThresholdSub]] = {}
        self._globs: list[ThresholdSub] = []
        self._next_id = 0
        self.published = 0
        self.delivered = 0

    def subscribe(self, metric: str, fn: Callable[[str, float, float], None],
                  above: Optional[float] = None,
                  below: Optional[float] = None,
                  predicate: Optional[Callable[[float], bool]] = None,
                  cooldown: float = 0.0, edge: bool = True) -> ThresholdSub:
        if above is None and below is None and predicate is None:
            raise ValueError("subscribe needs above=, below= or predicate=")
        sub = ThresholdSub(self._next_id, metric, fn, above, below,
                           predicate, cooldown, edge)
        self._next_id += 1
        if any(c in metric for c in "*?["):
            self._globs.append(sub)
        else:
            self._exact.setdefault(metric, []).append(sub)
        return sub

    def unsubscribe(self, sub: ThresholdSub) -> None:
        if sub in self._globs:
            self._globs.remove(sub)
        subs = self._exact.get(sub.metric)
        if subs and sub in subs:
            subs.remove(sub)

    def publish(self, name: str, value: float, t: float) -> None:
        self.published += 1
        for sub in self._exact.get(name, ()):
            if sub.check(name, value, t):
                self.delivered += 1
        for sub in self._globs:
            if fnmatch.fnmatch(name, sub.metric) and sub.check(name, value, t):
                self.delivered += 1

    def subscriptions(self) -> list[ThresholdSub]:
        return [s for subs in self._exact.values() for s in subs] + \
            list(self._globs)

# ---------------------------------------------------------------------------
# Ring buffer
# ---------------------------------------------------------------------------


class Ring:
    """Fixed-capacity (value, time) ring; O(1) append, windowed reads."""

    __slots__ = ("cap", "vals", "times", "idx", "count")

    def __init__(self, cap: int = 256):
        self.cap = cap
        self.vals = [0.0] * cap
        self.times = [0.0] * cap
        self.idx = 0
        self.count = 0

    def push(self, value: float, t: float) -> None:
        self.vals[self.idx] = value
        self.times[self.idx] = t
        self.idx = (self.idx + 1) % self.cap
        self.count += 1

    def window(self, since: float = -math.inf) -> list[tuple[float, float]]:
        """(time, value) pairs newer than ``since``, oldest first."""
        n = min(self.count, self.cap)
        start = (self.idx - n) % self.cap
        out = []
        for i in range(n):
            j = (start + i) % self.cap
            if self.times[j] >= since:
                out.append((self.times[j], self.vals[j]))
        return out

    def last(self) -> Optional[float]:
        if self.count == 0:
            return None
        return self.vals[(self.idx - 1) % self.cap]


# ---------------------------------------------------------------------------
# Aggregation functions (flexible, user-extensible)
# ---------------------------------------------------------------------------


def _percentile(xs: list[float], q: float) -> float:
    if not xs:
        return math.nan
    s = sorted(xs)
    k = (len(s) - 1) * q
    lo, hi = int(math.floor(k)), int(math.ceil(k))
    if lo == hi:
        return s[lo]
    return s[lo] + (s[hi] - s[lo]) * (k - lo)


AGGREGATIONS: dict[str, Callable[[list[float]], float]] = {
    "mean": lambda xs: sum(xs) / len(xs) if xs else math.nan,
    "max": lambda xs: max(xs) if xs else math.nan,
    "min": lambda xs: min(xs) if xs else math.nan,
    "sum": lambda xs: sum(xs),
    "count": lambda xs: float(len(xs)),
    "last": lambda xs: xs[-1] if xs else math.nan,
    "p50": lambda xs: _percentile(xs, 0.50),
    "p90": lambda xs: _percentile(xs, 0.90),
    "p95": lambda xs: _percentile(xs, 0.95),
    "p99": lambda xs: _percentile(xs, 0.99),
}


def register_aggregation(name: str,
                         fn: Callable[[list[float]], float]) -> None:
    """§3.2 'custom aggregation functions' hook."""
    AGGREGATIONS[name] = fn


class RollingStat:
    """Bounded rolling sample window for components that export a
    *derived* gauge — e.g. a workflow stage publishing its own p95 call
    latency as ``stage.<name>.p95`` so MetricBus threshold triggers
    (``on stage reviewer.p95 > 2``) can subscribe to a plain series
    instead of re-aggregating rings on every push."""

    def __init__(self, cap: int = 128):
        self.cap = cap
        self._xs: list[float] = []
        self._idx = 0

    def add(self, x: float) -> None:
        if len(self._xs) < self.cap:
            self._xs.append(x)
        else:
            self._xs[self._idx] = x
        self._idx = (self._idx + 1) % self.cap

    def pctl(self, q: float) -> float:
        return _percentile(self._xs, q)

    def mean(self) -> float:
        return sum(self._xs) / len(self._xs) if self._xs else math.nan

    def __len__(self) -> int:
        return len(self._xs)


class FleetAggregate:
    """Fleet-level derived gauges (§3.2's flexible aggregation, pushed
    down to the collector tier).  ``watch`` re-publishes an aggregate of
    per-member series as a ``<prefix>.<name>`` gauge on every member
    write, so MetricBus threshold rules and intent programs (``on
    cluster.prefill_pressure > 2 => set engine e2.role prefill``)
    subscribe to one plain series instead of re-aggregating windows —
    the disaggregation plane's RoleBalancerPolicy reads exactly these.
    """

    def __init__(self, collector: "Collector", prefix: str = "cluster"):
        if collector.bus is None:
            raise ValueError("FleetAggregate needs a Collector with a "
                             "MetricBus attached")
        self.collector = collector
        self.prefix = prefix
        self.watches: list[str] = []

    def watch(self, name: str, members: list[str], how: str = "sum",
              scale: float = 1.0) -> None:
        """Publish ``AGGREGATIONS[how]`` over the members' freshest
        values (times ``scale``) whenever any member is written."""
        agg = AGGREGATIONS[how]
        out = f"{self.prefix}.{name}"

        def _update(_name: str, _value: float, t: float) -> None:
            xs = [v for v in (self.collector.last(m) for m in members)
                  if v is not None]
            if xs:
                self.collector.gauge(out, agg(xs) * scale, t)

        for m in members:
            self.collector.bus.subscribe(m, predicate=lambda v: True,
                                         edge=False, fn=_update)
        self.watches.append(out)

    def watch_window(self, name: str, member: str, how: str = "p95",
                     window: float = math.inf, scale: float = 1.0) -> None:
        """Like ``watch``, but over ONE member's recent ring *window*
        rather than many members' freshest values — for rollups that
        need the sample distribution, not a fleet snapshot.  The
        tenancy plane derives ``tenant.<t>.p95_ttft`` from the raw
        ``tenant.<t>.ttft`` observations this way, so intent triggers
        (``on tenant gold.p95_ttft > 1.5``) ride the ordinary push
        tier."""
        agg = AGGREGATIONS[how]
        out = f"{self.prefix}.{name}"

        def _update(_name: str, _value: float, t: float) -> None:
            xs = [v for (_, v) in self.collector.read(member, t - window)]
            if xs:
                self.collector.gauge(out, agg(xs) * scale, t)

        self.collector.bus.subscribe(member, predicate=lambda v: True,
                                     edge=False, fn=_update)
        self.watches.append(out)


def ewma(alpha: float = 0.3) -> Callable[[list[float]], float]:
    def _fn(xs: list[float]) -> float:
        acc = math.nan
        for x in xs:
            acc = x if math.isnan(acc) else alpha * x + (1 - alpha) * acc
        return acc
    return _fn


register_aggregation("ewma", ewma())


# ---------------------------------------------------------------------------
# Metric specification (semantic understanding)
# ---------------------------------------------------------------------------

_KIND_WORDS = {
    "latency": ("latency", "time", "delay", "seconds", "duration"),
    "counter": ("count", "total", "number of", "cumulative"),
    "utilization": ("utilization", "fraction", "occupancy", "pressure"),
    "rate": ("per second", "rate", "throughput"),
    "gauge": ("length", "depth", "size", "current"),
}

_LOWER_BETTER = ("lower is better", "minimize", "smaller is better",
                 "lower the better", "should be low")
_HIGHER_BETTER = ("higher is better", "maximize", "larger is better",
                  "higher the better", "should be high")

_UNIT_RE = re.compile(r"\bin\s+(seconds|ms|milliseconds|tokens|bytes|"
                      r"pages|requests|fraction|percent)\b")


@dataclass(frozen=True)
class MetricSpec:
    """Semantic descriptor the controller uses to interpret a metric.

    direction: 'lower_better' | 'higher_better' | 'neutral' — e.g. when
    the objective is throughput, high ``page_util`` is good but a high
    ``queue_len`` is not; the spec is what encodes that (§3.2 goal 4).
    """

    name: str
    kind: str = "gauge"            # gauge|counter|latency|rate|utilization
    unit: str = ""
    direction: str = "neutral"
    description: str = ""
    default_agg: str = "mean"

    @classmethod
    def from_dict(cls, d: dict) -> "MetricSpec":
        """Structured (JSON/YAML-shaped) spec file path."""
        return cls(name=d["name"], kind=d.get("kind", "gauge"),
                   unit=d.get("unit", ""),
                   direction=d.get("direction", "neutral"),
                   description=d.get("description", ""),
                   default_agg=d.get("default_agg", "mean"))

    @classmethod
    def from_docstring(cls, name: str, doc: str) -> "MetricSpec":
        """Deterministic NL → spec transform (rule-based stand-in for the
        paper's LLM-assisted path; same interface)."""
        low = doc.lower()
        kind = "gauge"
        for k, words in _KIND_WORDS.items():
            if any(w in low for w in words):
                kind = k
                break
        direction = "neutral"
        if any(w in low for w in _LOWER_BETTER):
            direction = "lower_better"
        elif any(w in low for w in _HIGHER_BETTER):
            direction = "higher_better"
        elif kind == "latency":
            direction = "lower_better"
        m = _UNIT_RE.search(low)
        unit = m.group(1) if m else ("seconds" if kind == "latency" else "")
        default_agg = "p95" if kind == "latency" else (
            "sum" if kind == "counter" else "mean")
        return cls(name=name, kind=kind, unit=unit, direction=direction,
                   description=doc.strip(), default_agg=default_agg)


# Built-in specs for everything the engines/channels/agents export.
BUILTIN_SPECS: dict[str, MetricSpec] = {}


def _builtin(name: str, doc: str) -> None:
    BUILTIN_SPECS[name] = MetricSpec.from_docstring(name, doc)


_builtin("queue_len", "Current length of the admission queue; lower is better under latency goals.")
_builtin("num_running", "Current number of running sequences.")
_builtin("page_util", "KV page pool utilization as a fraction; higher is better for throughput, but 1.0 means preemption pressure.")
_builtin("step_time", "Engine step time in seconds; lower is better.")
_builtin("mean_step_time", "EWMA of measured engine step time in seconds, published every step; lower is better. The hardware-honesty signal intents trigger on when measured step time drifts from the CostModel's prediction.")
_builtin("ttft", "Time to first token in seconds; lower is better.")
_builtin("latency", "End-to-end request latency in seconds; lower is better.")
_builtin("tpt", "Time per output token in seconds; lower is better.")
_builtin("itl_p95", "Windowed p95 inter-token latency in seconds, published every step; lower is better. The decode-stall signal: a long serialized prefill spikes it, which is what adaptive chunked-prefill intents and ChunkPolicy trigger on.")
_builtin("throughput", "Completed requests per second; higher is better.")
_builtin("tokens_total", "Cumulative number of generated tokens.")
_builtin("task_latency", "End-to-end pipeline task latency in seconds; lower is better.")
_builtin("msgs_sent", "Cumulative number of messages sent on a channel.")
_builtin("bytes_sent", "Cumulative number of bytes sent on a channel.")
_builtin("link_delay", "Current queueing delay of the link in seconds; lower is better.")
_builtin("transfer_bytes", "Cumulative bytes of KV-cache state moved between instances.")
_builtin("hit_rate", "Prefix-cache token hit fraction; higher is better.")
_builtin("prefill_queue_tokens", "Current number of prompt tokens backed up behind prefill; lower is better under latency goals.")
_builtin("decode_slot_util", "Decoding-slot occupancy as a fraction; higher is better for throughput.")
_builtin("prefill_pressure", "Fleet prefill backlog relative to the per-step prefill budget; lower is better.")
_builtin("held_count", "Current number of messages held in the router (blocked or fleet-empty); lower is better.")
_builtin("handoffs", "Cumulative number of prefill-to-decode KV handoffs.")
_builtin("handoff_bytes", "Cumulative bytes of KV state moved by prefill-to-decode handoffs.")
_builtin("saved_prefill_tokens", "Cumulative number of prompt tokens served from the prefix cache instead of re-prefilled.")
_builtin("shared_pages", "Current number of KV pages held in shared (refcounted) prefix blocks.")
_builtin("p95_ttft", "Windowed p95 time to first token in seconds; lower is better.")
_builtin("share", "Windowed fraction of fleet tokens served to a tenant.")
_builtin("throttle_rate", "Windowed fraction of a tenant's messages held by the admission meter; lower is better.")
_builtin("admitted_tokens", "Cumulative number of tokens metered through a tenant's admission bucket.")
_builtin("throttled", "Cumulative number of a tenant's messages held by the admission meter.")
_builtin("queue_wait", "Queue-wait segment of a request in seconds; lower is better.")
_builtin("throttle_hold", "Tenant-throttle-hold segment of a request in seconds; lower is better.")
_builtin("handoff_wait", "KV-handoff-wait segment of a request in seconds; lower is better.")
_builtin("prefill", "Prefill segment of a request in seconds; lower is better.")
_builtin("decode", "Decode segment of a request in seconds; lower is better.")
_builtin("actions_retained", "Current number of control-plane actions retained in the audit ring.")
_builtin("spans_total", "Cumulative number of trace spans recorded.")
_builtin("spans_dropped", "Cumulative number of trace spans evicted from the bounded store.")


# ---------------------------------------------------------------------------
# Local collector (tier 1)
# ---------------------------------------------------------------------------


class Collector:
    """Per-node metric collector.

    ``gauge`` overwrites a point-in-time series; ``observe`` appends an
    event sample (latencies etc.); ``counter`` accumulates.  All three
    land in ring buffers read by ``CentralPoller.poll`` — writers never
    block on the control plane.  When a ``MetricBus`` is attached, every
    write is also pushed through it so threshold subscriptions can react
    between polls (the event tier; still O(1) when nothing subscribes).
    """

    def __init__(self, node: str = "node0", cap: int = 512,
                 bus: Optional[MetricBus] = None):
        self.node = node
        self.cap = cap
        self.bus = bus
        self._rings: dict[str, Ring] = {}
        self._counters: dict[str, float] = {}
        self._specs: dict[str, MetricSpec] = {}

    # -- write side (hot path) ------------------------------------------------
    def _ring(self, name: str) -> Ring:
        r = self._rings.get(name)
        if r is None:
            r = self._rings[name] = Ring(self.cap)
        return r

    def gauge(self, name: str, value: float, t: float) -> None:
        self._ring(name).push(float(value), t)
        if self.bus is not None:
            self.bus.publish(name, float(value), t)

    def observe(self, name: str, value: float, t: float) -> None:
        self._ring(name).push(float(value), t)
        if self.bus is not None:
            self.bus.publish(name, float(value), t)

    def counter(self, name: str, delta: float, t: float) -> None:
        total = self._counters.get(name, 0.0) + delta
        self._counters[name] = total
        self._ring(name).push(total, t)
        if self.bus is not None:
            self.bus.publish(name, total, t)

    # -- spec side --------------------------------------------------------------
    def describe(self, name: str, spec_or_doc) -> None:
        """Attach semantics: a MetricSpec, a dict (JSON path), or a
        natural-language docstring (NL path)."""
        if isinstance(spec_or_doc, MetricSpec):
            self._specs[name] = spec_or_doc
        elif isinstance(spec_or_doc, dict):
            self._specs[name] = MetricSpec.from_dict({"name": name,
                                                      **spec_or_doc})
        else:
            self._specs[name] = MetricSpec.from_docstring(name,
                                                          str(spec_or_doc))

    def spec(self, name: str) -> MetricSpec:
        if name in self._specs:
            return self._specs[name]
        base = name.rsplit(".", 1)[-1]
        return BUILTIN_SPECS.get(base, MetricSpec(name=name))

    # -- read side (poller only) --------------------------------------------
    def names(self) -> list[str]:
        return list(self._rings)

    def read(self, name: str, since: float = -math.inf):
        r = self._rings.get(name)
        return r.window(since) if r is not None else []

    def last(self, name: str) -> Optional[float]:
        r = self._rings.get(name)
        return r.last() if r is not None else None


# ---------------------------------------------------------------------------
# State store + central poller (tier 2)
# ---------------------------------------------------------------------------


@dataclass
class Series:
    spec: MetricSpec
    points: list[tuple[float, float]] = field(default_factory=list)

    def agg(self, how: str, window: float = math.inf,
            now: float = math.inf) -> float:
        lo = (now - window) if math.isfinite(now) else -math.inf
        xs = [v for (t, v) in self.points if t >= lo]
        return AGGREGATIONS[how](xs)


class StateStore:
    """The controller's logical state store (§3.1 design point 3): the
    freshest polled window of every metric, keyed ``node.metric``."""

    def __init__(self):
        self.series: dict[str, Series] = {}
        self.polled_at: float = -math.inf

    def update(self, name: str, spec: MetricSpec,
               points: Iterable[tuple[float, float]]) -> None:
        s = self.series.get(name)
        if s is None:
            s = self.series[name] = Series(spec)
        s.spec = spec
        s.points = list(points)

    # -- query API used by policies / the intent language ---------------------
    def get(self, name: str, agg: Optional[str] = None,
            window: float = math.inf, default: float = math.nan) -> float:
        if any(c in name for c in "*?["):
            return self._get_glob(name, agg, window, default)
        s = self.series.get(name)
        if s is None or not s.points:
            return default
        how = agg or s.spec.default_agg
        v = s.agg(how, window, now=self.polled_at)
        return default if (isinstance(v, float) and math.isnan(v)) else v

    def _get_glob(self, pattern: str, agg: Optional[str],
                  window: float, default: float) -> float:
        """Fleet-wide query: pool every series matching the glob (e.g.
        ``mean(tester-*.queue_len)``) and aggregate the combined window —
        mirroring the MetricBus's glob threshold subscriptions."""
        lo = ((self.polled_at - window)
              if math.isfinite(self.polled_at) else -math.inf)
        xs: list[float] = []
        how = agg
        for n, s in self.series.items():
            if not fnmatch.fnmatch(n, pattern) or not s.points:
                continue
            if how is None:
                how = s.spec.default_agg
            xs.extend(v for (t, v) in s.points if t >= lo)
        if not xs:
            return default
        v = AGGREGATIONS[how or "mean"](xs)
        return default if (isinstance(v, float) and math.isnan(v)) else v

    def names(self, pattern: str = "") -> list[str]:
        return [n for n in self.series if pattern in n]

    def spec(self, name: str) -> Optional[MetricSpec]:
        s = self.series.get(name)
        return s.spec if s else None


class CentralPoller:
    """On-demand pull of every collector's fresh window into the store."""

    def __init__(self, store: StateStore, window: float = 5.0):
        self.store = store
        self.window = window
        self.collectors: list[Collector] = []
        self.polls = 0

    def attach(self, collector: Collector) -> None:
        if collector not in self.collectors:
            self.collectors.append(collector)

    def poll(self, now: float) -> None:
        since = now - self.window
        for c in self.collectors:
            for name in c.names():
                self.store.update(name, c.spec(name), c.read(name, since))
        self.store.polled_at = now
        self.polls += 1
