"""Tracing plane — end-to-end request spans + control-plane flight
recorder (the observability half of the SDN story).

The control plane can *act* from runtime state, but until now it could
not *explain*: aggregate gauges answer "is p95 high", not "why was this
request slow" or "what did that intent actually change".  This module
adds both halves:

* ``Tracer`` — a span store threaded through every layer a request
  crosses: router admission / tenant throttle hold, scheduler queue
  wait and preemption, prefill, chunk-streamed KV handoff, decode, and
  workflow stage/DAG edges (stage spans parent onto the task root;
  engine request spans parent onto their issuing stage).  Per-request
  **segment** spans (``queue_wait``, ``throttle_hold``,
  ``handoff_wait``, ``prefill``, ``decode``) tile the request's
  lifetime exactly — they are opened/closed at the same lifecycle
  transitions the engines already stamp, so their durations sum to the
  end-to-end measured latency.  Every closed segment is also published
  as a ``request.<segment>`` observation through the MetricBus, so
  intent programs can trigger on *segments*, not just totals.

  Sampling is a control-plane attribute: the tracer registers as a
  ``tracer`` controllable (knobs ``enabled`` / ``sample``) with
  capability ``trace``, and the intent verb ``trace [tenant|stage NAME]
  on|off|RATE`` scopes sampling per tenant or per stage at runtime.
  Decisions are deterministic (crc32 hash of the trace id) — no RNG,
  no wall clock — so a sim replay traces the same tasks.

* ``FlightRecorder`` — a bounded black box: every control-plane action
  from the controller's audit log, plus rolling windows of watched
  metric series (``watch("tester-*.queue_len")``).  At export time
  actions are causally annotated onto the data-plane spans they
  overlapped, so a trace shows "p95 breached → intent X fired → engine
  e3 role flipped → this request's handoff_wait".  The recorded metric
  windows are the substrate ROADMAP item 5's ``dry-run`` verb replays.

``Tracer.export`` writes Perfetto/Chrome-trace JSON (``TRACE_*.json``,
load it at ``chrome://tracing`` or https://ui.perfetto.dev): complete
("X") events per span, instant ("i") events per control action, and
flow ("s"/"f") events drawing each causal action→span link.
``tools/trace_report.py`` walks the exported JSON alone and reprints
the DAG critical path with the dominant segment per stage.
"""
from __future__ import annotations

import json
import zlib
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Optional

from repro.core.knobs import ControlSurface, KnobSpec
from repro.core.metrics import MetricBus, Ring

# the per-request latency decomposition: these tile [arrival, finish]
SEGMENTS = ("queue_wait", "throttle_hold", "handoff_wait",
            "prefill", "decode")


@dataclass
class Span:
    """One timed interval on a trace tree.  ``trace_id`` groups a task's
    spans; ``parent_id`` links request→stage→task (and segment→request).
    ``t1 is None`` while the span is open."""

    span_id: int
    name: str
    cat: str                       # task | stage | request | segment | kv
    trace_id: str
    t0: float
    t1: Optional[float] = None
    parent_id: Optional[int] = None
    attrs: dict = field(default_factory=dict)

    @property
    def closed(self) -> bool:
        return self.t1 is not None

    @property
    def dur(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else 0.0


class Tracer(ControlSurface):
    """Span store + sampling policy, registered as a controllable."""

    kind = "tracer"
    CAPABILITIES = ("trace",)
    METRICS = ("spans_total", "spans_dropped")
    KNOB_SPECS = (
        KnobSpec("enabled", kind="bool",
                 doc="master switch for span capture"),
        KnobSpec("sample", kind="float", lo=0.0, hi=1.0,
                 doc="global trace sampling rate (fraction of tasks)"),
    )

    def __init__(self, clock: Callable[[], float], name: str = "tracer",
                 collector=None, cap: int = 65536):
        self.name = name
        self.clock = clock
        self.collector = collector
        self.cap = cap
        self.enabled = False           # knob: off by default (zero cost)
        self.sample = 1.0              # knob: rate once enabled
        self.scopes: dict[str, float] = {}   # "tenant:gold"/"stage:map" -> rate
        self.spans: list[Span] = []          # closed spans (bounded ring)
        self._open: dict[int, Span] = {}
        self._decisions: dict[str, bool] = {}
        self._task_spans: dict[str, Span] = {}
        self._next_id = 0
        self.spans_total = 0
        self.spans_dropped = 0

    def _surface_now(self) -> float:
        return self.clock()

    # -- sampling policy ----------------------------------------------------
    def set_scope(self, scope: Optional[str], rate: float) -> None:
        """The ``trace`` verb's target: ``scope`` is ``None`` (global),
        ``tenant:NAME`` or ``stage:NAME``; ``rate`` in [0, 1] (the verb
        maps on→1.0, off→0.0).  Any positive scoped rate implies the
        master switch — ``trace tenant gold on`` must not silently no-op
        because global tracing was never enabled."""
        rate = min(max(float(rate), 0.0), 1.0)
        if scope is None:
            self.sample = rate
            self.enabled = rate > 0
        else:
            self.scopes[scope] = rate
            if rate > 0:
                self.enabled = True

    @staticmethod
    def _hash_ok(key: str, rate: float) -> bool:
        if rate >= 1.0:
            return True
        if rate <= 0.0:
            return False
        # deterministic: the sim has no RNG, and a replay must trace
        # the same tasks
        return (zlib.crc32(key.encode()) % 10000) / 10000.0 < rate

    def decide(self, trace_id: str, tenant: str = "default",
               stage: Optional[str] = None) -> bool:
        """Sample decision for a trace id, cached so every span of a
        task agrees.  A ``stage:`` scope is most specific and overrides
        the task-level decision for that stage's requests; a ``tenant:``
        scope overrides the global rate."""
        if not self.enabled:
            return False               # not cached: enabling mid-run
        if stage is not None:          # must reach tasks submitted later
            srate = self.scopes.get(f"stage:{stage}")
            if srate is not None:
                return self._hash_ok(f"{trace_id}:{stage}", srate)
        d = self._decisions.get(trace_id)
        if d is None:
            rate = self.scopes.get(f"tenant:{tenant}", self.sample)
            d = self._hash_ok(trace_id, rate)
            if len(self._decisions) > 4 * self.cap:
                self._decisions.clear()
            self._decisions[trace_id] = d
        return d

    def decided(self, trace_id: str) -> bool:
        """True only for trace ids already sampled in — used by
        supplementary recorders (kv chunks) that must not originate
        fresh decisions."""
        return self._decisions.get(trace_id, False)

    # -- span lifecycle -----------------------------------------------------
    def begin(self, name: str, trace_id: str, cat: str = "span",
              parent: Optional[Span] = None, t: Optional[float] = None,
              **attrs) -> Span:
        sp = Span(self._next_id, name, cat, trace_id,
                  self.clock() if t is None else t,
                  parent_id=parent.span_id if parent is not None else None,
                  attrs=attrs)
        self._next_id += 1
        self._open[sp.span_id] = sp
        return sp

    def end(self, span: Optional[Span], t: Optional[float] = None) -> None:
        if span is None or span.t1 is not None:
            return
        span.t1 = self.clock() if t is None else t
        self._open.pop(span.span_id, None)
        self._store(span)

    def record(self, name: str, trace_id: str, t0: float, t1: float,
               cat: str = "span", parent: Optional[Span] = None,
               **attrs) -> Span:
        """One-shot span with both endpoints known."""
        sp = Span(self._next_id, name, cat, trace_id, t0, t1,
                  parent_id=parent.span_id if parent is not None else None,
                  attrs=attrs)
        self._next_id += 1
        self._store(sp)
        return sp

    def _store(self, span: Span) -> None:
        self.spans_total += 1
        self.spans.append(span)
        if len(self.spans) > self.cap:
            drop = self.cap // 2
            del self.spans[:drop]
            self.spans_dropped += drop
        if span.cat == "segment" and self.collector is not None:
            # the per-segment decomposition gauges intents trigger on
            self.collector.observe(f"request.{span.name}", span.dur,
                                   span.t1)

    # -- task roots ---------------------------------------------------------
    def begin_task(self, task_id: str, tenant: str = "default",
                   t: Optional[float] = None, **attrs) -> Optional[Span]:
        if not self.decide(task_id, tenant=tenant):
            return None
        sp = self.begin(f"task:{task_id}", task_id, cat="task", t=t,
                        tenant=tenant, **attrs)
        self._task_spans[task_id] = sp
        return sp

    def end_task(self, task_id: str, t: Optional[float] = None) -> None:
        self.end(self._task_spans.pop(task_id, None), t)

    def task_span(self, task_id: str) -> Optional[Span]:
        return self._task_spans.get(task_id)

    # -- export -------------------------------------------------------------
    def all_spans(self) -> list[Span]:
        out = list(self.spans) + list(self._open.values())
        out.sort(key=lambda s: (s.t0, s.span_id))
        return out

    def export(self, path=None, recorder: "FlightRecorder" = None,
               clip_at: Optional[float] = None) -> dict:
        """Build (and optionally write) the Chrome-trace JSON document.
        Open spans are clipped at ``clip_at`` (default: now) and marked
        ``open``; recorder actions become instant events with flow
        edges to the spans they causally overlapped."""
        now = self.clock() if clip_at is None else clip_at
        spans = self.all_spans()
        actions = list(recorder.actions) if recorder is not None else []
        links = correlate_actions(actions, spans)
        linked: dict[int, list] = {}
        for a, s in links:
            linked.setdefault(s.span_id, []).append(a)

        tracks: dict[str, int] = {}
        tids: dict[str, int] = {}

        def pid(track: str) -> int:
            return tracks.setdefault(track, len(tracks) + 1)

        def tid(trace_id: str) -> int:
            return tids.setdefault(trace_id, len(tids) + 1)

        _CAT_TRACK = {"task": "tasks", "stage": "stages", "kv": "kv-fabric"}
        events = []
        placed: dict[int, tuple[int, int]] = {}   # span_id -> (pid, tid)
        for s in spans:
            track = s.attrs.get("engine") or _CAT_TRACK.get(s.cat,
                                                            "requests")
            p, th = pid(track), tid(s.trace_id)
            placed[s.span_id] = (p, th)
            end = s.t1 if s.t1 is not None else now
            args = {"span_id": s.span_id, "parent_id": s.parent_id,
                    "trace_id": s.trace_id, **s.attrs}
            if s.t1 is None:
                args["open"] = True
            acts = linked.get(s.span_id)
            if acts:
                args["actions"] = [f"{a.kind} {a.target}: {a.detail}"
                                   for a in acts]
            events.append({"name": s.name, "cat": s.cat, "ph": "X",
                           "ts": round(s.t0 * 1e6, 3),
                           "dur": round(max(end - s.t0, 0.0) * 1e6, 3),
                           "pid": p, "tid": th, "args": args})
        cpid = pid("control-plane")
        for a in actions:
            events.append({"name": f"{a.kind}:{a.target}", "cat": "control",
                           "ph": "i", "s": "p",
                           "ts": round(a.t * 1e6, 3), "pid": cpid, "tid": 0,
                           "args": {"kind": a.kind, "target": a.target,
                                    "detail": a.detail}})
        for i, (a, s) in enumerate(links, 1):
            p, th = placed[s.span_id]
            events.append({"name": "causal", "cat": "control", "ph": "s",
                           "id": i, "ts": round(a.t * 1e6, 3),
                           "pid": cpid, "tid": 0})
            events.append({"name": "causal", "cat": "control", "ph": "f",
                           "bp": "e", "id": i,
                           "ts": round(max(a.t, s.t0) * 1e6, 3),
                           "pid": p, "tid": th})
        for track, p in tracks.items():
            events.append({"name": "process_name", "ph": "M", "ts": 0,
                           "pid": p, "tid": 0, "args": {"name": track}})
        doc = {"traceEvents": events, "displayTimeUnit": "ms",
               "otherData": {"clock": "virtual-seconds",
                             "spans": len(spans), "actions": len(actions),
                             "links": len(links)}}
        if path is not None:
            Path(path).write_text(json.dumps(doc, indent=1) + "\n")
        return doc


def correlate_actions(actions, spans, per_action: int = 4,
                      cap: int = 512) -> list:
    """Causal annotation: for each control-plane action, the data-plane
    spans it temporally overlapped — preferring spans whose attributes
    name the action's target (an engine, tenant or stage), falling back
    to the overlapping trace roots.  Returns (action, span) pairs,
    bounded so a chatty controller cannot blow up the export."""
    out = []
    for a in actions:
        overlapping = [s for s in spans
                       if s.t0 - 1e-9 <= a.t
                       and (s.t1 is None or a.t <= s.t1 + 1e-9)]
        if not overlapping:
            continue
        tgt = str(a.target)

        def _names_target(s):
            if not tgt or tgt == "-":
                return False
            hay = [s.name] + [str(v) for v in s.attrs.values()]
            return any(tgt == h or (len(tgt) > 2 and tgt in h)
                       for h in hay)

        hit = [s for s in overlapping if _names_target(s)] \
            or [s for s in overlapping if s.parent_id is None]
        for s in hit[:per_action]:
            out.append((a, s))
            if len(out) >= cap:
                return out
    return out


def request_decomposition(spans) -> list:
    """Per traced request: (request span, {segment: summed seconds},
    request duration).  Only closed requests — the acceptance check is
    that the segment sum matches the request's end-to-end latency."""
    by_parent: dict[int, list[Span]] = {}
    for s in spans:
        if s.parent_id is not None:
            by_parent.setdefault(s.parent_id, []).append(s)
    out = []
    for s in spans:
        if s.cat != "request" or s.t1 is None:
            continue
        segs: dict[str, float] = {}
        for c in by_parent.get(s.span_id, ()):
            if c.cat == "segment" and c.t1 is not None:
                segs[c.name] = segs.get(c.name, 0.0) + c.dur
        out.append((s, segs, s.dur))
    return out


class FlightRecorder:
    """Bounded black box for the control plane: every audit-log action
    plus rolling windows of watched metric series.  The recorded
    windows are what a future ``dry-run`` verb replays through the
    CostModel to predict an intent's effect before it goes live."""

    def __init__(self, clock: Callable[[], float],
                 bus: Optional[MetricBus] = None,
                 action_cap: int = 2048, window_cap: int = 1024):
        self.clock = clock
        self.bus = bus
        self.action_cap = action_cap
        self.window_cap = window_cap
        self.actions: list = []            # controller Action records
        self.actions_total = 0
        self.windows: dict[str, Ring] = {}
        self.watched: list[str] = []

    # -- control-plane feed (Controller._log forwards here) ------------------
    def record_action(self, action) -> None:
        self.actions_total += 1
        self.actions.append(action)
        if len(self.actions) > self.action_cap:
            del self.actions[: self.action_cap // 2]

    def actions_between(self, t0: float = float("-inf"),
                        t1: float = float("inf"),
                        kind: Optional[str] = None) -> list:
        return [a for a in self.actions
                if t0 <= a.t <= t1 and (kind is None or a.kind == kind)]

    # -- metric-window feed --------------------------------------------------
    def watch(self, pattern: str) -> None:
        """Record every published sample of series matching ``pattern``
        (exact name or glob) into a bounded per-series ring."""
        if self.bus is None:
            raise RuntimeError("FlightRecorder.watch needs a MetricBus")
        self.watched.append(pattern)
        self.bus.subscribe(pattern, predicate=lambda v: True, edge=False,
                           fn=self._sample)

    def _sample(self, name: str, value: float, t: float) -> None:
        ring = self.windows.get(name)
        if ring is None:
            ring = self.windows[name] = Ring(self.window_cap)
        ring.push(value, t)

    def window(self, name: str,
               since: float = float("-inf")) -> list:
        ring = self.windows.get(name)
        return ring.window(since) if ring is not None else []

    def snapshot(self, since: float = float("-inf")) -> dict:
        """The dry-run substrate: recorded actions + metric windows
        newer than ``since``, as plain data."""
        return {
            "t": self.clock(),
            "actions": [(a.t, a.kind, a.target, a.detail)
                        for a in self.actions if a.t >= since],
            "metrics": {n: r.window(since)
                        for n, r in self.windows.items()},
        }
