"""Controller-side registry (paper §3.1 "agent/tool hooks").

Every controllable object — engine, agent, tool, channel, router —
*registers at launch*, advertising its AgentCard (knobs, metrics,
capabilities).  The controller then manipulates all of them through the
paper's two-function Table-1 surface:

    registry.set("tester-0", "max_num_seqs", 4)
    registry.reset("tester-0", "max_num_seqs")

The per-object ``set_param`` method is the object's *shim layer*: it maps
the uniform knob name onto whatever internal API the object has (exactly
the vLLM ``max_num_seqs`` example from the paper).
"""
from __future__ import annotations


from repro.core.types import AgentCard


class Controllable:
    """Duck-typed interface: card() / set_param() / reset_param()."""


class Registry:
    def __init__(self):
        self._objs: dict[str, object] = {}
        self._cards: dict[str, AgentCard] = {}
        self.set_count = 0

    # -- registration (launch-time hook) ------------------------------------
    def register(self, obj) -> AgentCard:
        card = obj.card()
        if card.name in self._objs:
            raise ValueError(f"duplicate registration: {card.name}")
        self._objs[card.name] = obj
        self._cards[card.name] = card
        return card

    def deregister(self, name: str) -> None:
        self._objs.pop(name, None)
        self._cards.pop(name, None)

    # -- discovery -----------------------------------------------------------
    def names(self) -> list[str]:
        return list(self._objs)

    def get(self, name: str):
        return self._objs[name]

    def card(self, name: str) -> AgentCard:
        return self._cards[name]

    def of_kind(self, kind: str) -> list[str]:
        return [n for n, c in self._cards.items() if c.kind == kind]

    def with_capability(self, cap: str) -> list[str]:
        return [n for n, c in self._cards.items() if cap in c.capabilities]

    def knobs(self, name: str) -> dict:
        return dict(self._cards[name].knobs)

    # -- Table-1 surface ------------------------------------------------------
    def set(self, name: str, knob: str, value) -> None:
        self._objs[name].set_param(knob, value)
        self.set_count += 1

    def reset(self, name: str, knob: str) -> None:
        self._objs[name].reset_param(knob)

    def get_param(self, name: str, knob: str):
        return self._objs[name].get_param(knob)
