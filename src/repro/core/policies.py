"""Control policies — the closed-loop programs the paper's evaluation
exercises (Figs 6–7), plus guards used by examples/tests.

Each is a plain ``Policy``: it reads the state store, and acts only
through the ControlContext capability surface.  The same behaviours can
be expressed in the declarative intent language (core/intent.py); these
programmatic versions exist because Fig 6/7 need stateful logic
(hysteresis counters, per-session placement maps) beyond the guarded
commands the language targets.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Optional

from repro.core.controller import ControlContext, Policy
from repro.core.types import Granularity


class AdaptiveGranularityPolicy(Policy):
    """Fig 6: switch a channel's granularity with downstream load.

    Load signal = queue length + running at the consumer engine(s).
    Thresholds carry hysteresis (switch up at ``hi``, back down at
    ``lo``) so the data plane doesn't flap around a boundary.
    """

    name = "adaptive-granularity"

    def __init__(self, channel: str, consumers: list[str],
                 stream_below: float = 2.0, batch_above: float = 8.0,
                 window: float = 1.0, dwell: float = 1.5):
        assert stream_below <= batch_above
        self.channel = channel
        self.consumers = consumers
        self.stream_below = stream_below
        self.batch_above = batch_above
        self.window = window
        self.dwell = dwell              # min residency in a mode (anti-flap)
        self.mode: Optional[Granularity] = None
        self.switches: list[tuple[float, Granularity]] = []

    def _load(self, ctx: ControlContext) -> float:
        total = 0.0
        for c in self.consumers:
            total += ctx.metric(f"{c}.queue_len", "mean", self.window)
            total += ctx.metric(f"{c}.num_running", "mean", self.window)
        return total

    def on_tick(self, ctx: ControlContext) -> None:
        load = self._load(ctx)
        mode = self.mode or Granularity.PIPELINE
        if load >= self.batch_above:
            mode = Granularity.BATCH
        elif load <= self.stream_below:
            mode = Granularity.STREAM
        elif self.mode is None:
            mode = Granularity.PIPELINE
        elif self.mode is Granularity.BATCH and load < self.batch_above * 0.6:
            mode = Granularity.PIPELINE
        elif self.mode is Granularity.STREAM and load > self.stream_below * 1.5:
            mode = Granularity.PIPELINE
        if mode is not self.mode:
            if self.switches and ctx.now - self.switches[-1][0] < self.dwell:
                return
            ctx.granularity(self.channel, mode)
            self.mode = mode
            self.switches.append((ctx.now, mode))


@dataclass
class _SessionHome:
    instance: str
    context_len: int = 0


class LoadBalancePolicy(Policy):
    """Fig 7: keep tester instances balanced; migrate session KV state.

    * ``mode='none'``   — static session→instance hash (the baseline).
    * ``mode='reactive'`` — route to least-loaded; the destination pulls
      session KV *after* the request arrives (transfer serializes with
      the request).
    * ``mode='hints'``  — on ``task_start`` (the upstream agent begins
      generating) the controller *proactively* pushes the session KV to
      the chosen instance, overlapping the transfer with generation —
      the paper's 1.8× mechanism.
    """

    name = "load-balance"

    def __init__(self, instances: list[str], mode: str = "hints",
                 imbalance_min: float = 6.0, cooldown: float = 4.0,
                 window: float = 0.5, pending_weight: float = 6.0,
                 pending_horizon: float = 1.5):
        assert mode in ("none", "reactive", "hints")
        self.instances = instances
        self.mode = mode
        self.imbalance_min = imbalance_min
        self.cooldown = cooldown            # min gap between migrations
        self.window = window                # of the same session
        # install-time accounting: the controller charges each routing
        # decision to the target *before* the metrics can see it, else
        # every session herds to the same briefly-cold instance
        self.pending_weight = pending_weight
        self.pending_horizon = pending_horizon
        self._pending: dict[str, list[float]] = {i: [] for i in instances}
        self.homes: dict[str, _SessionHome] = {}
        self._last_move: dict[str, float] = {}
        self.migrations = 0
        self.hints_sent = 0

    # -- helpers ----------------------------------------------------------------
    def _static_instance(self, session: str) -> str:
        h = zlib.crc32(session.encode())
        return self.instances[h % len(self.instances)]

    def _load(self, ctx: ControlContext, inst: str) -> float:
        q = ctx.metric(f"{inst}.queue_len", "last", default=0.0)
        r = ctx.metric(f"{inst}.num_running", "last", default=0.0)
        pend = self._pending.get(inst, [])
        horizon = ctx.now - self.pending_horizon
        pend[:] = [t for t in pend if t >= horizon]
        return q + r + self.pending_weight * len(pend)

    def _pick(self, ctx: ControlContext) -> str:
        return min(self.instances, key=lambda i: self._load(ctx, i))

    def _charge(self, ctx: ControlContext, inst: str) -> None:
        self._pending.setdefault(inst, []).append(ctx.now)

    # -- event path (push, between polls) ------------------------------------
    def on_event(self, ctx: ControlContext, kind: str, **kw) -> None:
        if kind != "task_start":
            return
        session = kw["session"]
        home = self.homes.get(session)
        if self.mode == "none":
            inst = self._static_instance(session)
            if home is None:
                self.homes[session] = _SessionHome(inst)
                ctx.route(session, inst)
            return
        # dynamic: choose the least-loaded instance *now*
        inst = self._pick(ctx)
        if home is None:
            self.homes[session] = _SessionHome(inst)
            self._charge(ctx, inst)
            ctx.route(session, inst)
            return
        if inst == home.instance:
            self._charge(ctx, inst)
            ctx.route(session, inst)
            return
        # migration is not free (KV moves, the destination warms up) —
        # move only if the imbalance is material and this session hasn't
        # just moved (cost-aware throttling, not per-message micromanaging)
        gap = self._load(ctx, home.instance) - self._load(ctx, inst)
        recently = ctx.now - self._last_move.get(session, -1e18)
        if gap < self.imbalance_min or recently < self.cooldown:
            self._charge(ctx, home.instance)
            ctx.route(session, home.instance)
            return
        self._last_move[session] = ctx.now
        self._charge(ctx, inst)
        ctx.route(session, inst)
        self.migrations += 1
        if self.mode == "hints":
            # proactive: start moving state NOW, while the developer is
            # still generating — the transfer overlaps generation
            ctx.transfer_kv(session, home.instance, inst, proactive=True)
            self.hints_sent += 1
        # reactive: no transfer here — the destination instance pulls the
        # state only once the request arrives (serialized on the request)
        home.instance = inst

    def on_tick(self, ctx: ControlContext) -> None:
        pass                            # all work happens on task_start


class SpeculativeGatePolicy(Policy):
    """Request-level rule from §3.1: block speculative sends while the
    consumer is loaded; release when pressure clears."""

    name = "speculative-gate"

    def __init__(self, channel: str, consumers: list[str],
                 gate_above: float = 4.0, window: float = 1.0):
        self.channel = channel
        self.consumers = consumers
        self.gate_above = gate_above
        self.window = window
        self.gated = False

    def on_tick(self, ctx: ControlContext) -> None:
        load = sum(ctx.metric(f"{c}.queue_len", "mean", self.window)
                   for c in self.consumers)
        if load >= self.gate_above and not self.gated:
            ctx.set(self.channel, "gate_speculative", True)
            self.gated = True
        elif load < self.gate_above * 0.5 and self.gated:
            ctx.set(self.channel, "gate_speculative", False)
            self.gated = False


class SLOGuardPolicy(Policy):
    """Intent example from §3.1: 'ensure p90 latency of interactive
    requests meets the SLO' — demote background traffic and tighten
    admission until the SLO holds, then relax."""

    name = "slo-guard"

    def __init__(self, latency_metric: str, slo: float, engine: str,
                 background_channel: Optional[str] = None,
                 window: float = 2.0):
        self.latency_metric = latency_metric
        self.slo = slo
        self.engine = engine
        self.background_channel = background_channel
        self.window = window
        self.tightened = False
        self.violations = 0

    def on_tick(self, ctx: ControlContext) -> None:
        p90 = ctx.metric(self.latency_metric, "p90", self.window,
                         default=float("nan"))
        if p90 != p90:
            return
        if p90 > self.slo and not self.tightened:
            self.violations += 1
            ctx.set(self.engine, "admit_priority_min", 1)   # drop LOW
            ctx.set(self.engine, "decode_first", True)
            if self.background_channel:
                ctx.granularity(self.background_channel, Granularity.BATCH)
            self.tightened = True
        elif p90 <= self.slo * 0.7 and self.tightened:
            ctx.reset(self.engine, "admit_priority_min")
            ctx.reset(self.engine, "decode_first")
            if self.background_channel:
                ctx.reset(self.background_channel, "granularity")
            self.tightened = False


class TenantGuardPolicy(Policy):
    """Tenancy plane: keep a gold tenant's TTFT SLO by reshaping the
    fleet's fairness state — bump the gold tenant's weighted-fair
    ``weight`` and pause ``batch``-class tenants while the breach is
    sustained; restore both once the SLO holds again with margin.  Acts
    only through the registered ``tenant.<name>`` knobs, so the same
    behaviour is expressible in intent as

        rule guard on tenant gold.p95_ttft > 1.5 hold 2:
            => set tenant gold.weight 8; set tenant batch.paused true

    The p95 is computed from the raw ``tenant.<t>.ttft`` observations
    in the store (window ``window``), so the policy works with or
    without a MetricBus; ``sustain`` consecutive breaching ticks are
    required before acting (transient spikes don't pause anyone).
    """

    name = "tenant-guard"

    def __init__(self, gold: str, batch: list[str], slo_ttft: float,
                 boost_weight: float = 8.0, window: float = 2.0,
                 sustain: int = 3, clear_frac: float = 0.6,
                 pause_batch: bool = True, prefix: str = "tenant"):
        self.gold = gold
        self.batch = batch
        self.slo_ttft = slo_ttft
        self.boost_weight = boost_weight
        self.window = window
        self.sustain = sustain              # consecutive breaching ticks
        self.clear_frac = clear_frac        # hysteresis release threshold
        self.pause_batch = pause_batch
        self.prefix = prefix
        self.tightened = False
        self.breaches = 0
        self.actions: list[tuple[float, str]] = []

    def _p95(self, ctx: ControlContext) -> float:
        return ctx.metric(f"{self.prefix}.{self.gold}.ttft", "p95",
                          self.window, default=float("nan"))

    def _relax(self, ctx: ControlContext) -> None:
        ctx.reset(f"{self.prefix}.{self.gold}", "weight")
        if self.pause_batch:
            for b in self.batch:
                ctx.reset(f"{self.prefix}.{b}", "paused")
        self.tightened = False
        self.breaches = 0
        self.actions.append((ctx.now, "relax"))

    def on_tick(self, ctx: ControlContext) -> None:
        p95 = self._p95(ctx)
        if p95 != p95:
            # no gold samples in the window: nothing left to protect —
            # a tightened guard must not leave batch tenants paused
            # (= starved) forever after the gold traffic goes quiet
            if self.tightened:
                self._relax(ctx)
            return
        if p95 > self.slo_ttft:
            self.breaches += 1
        else:
            self.breaches = 0
        if self.breaches >= self.sustain and not self.tightened:
            ctx.set(f"{self.prefix}.{self.gold}", "weight",
                    self.boost_weight)
            if self.pause_batch:
                for b in self.batch:
                    ctx.set(f"{self.prefix}.{b}", "paused", True)
            self.tightened = True
            self.actions.append((ctx.now, "tighten"))
        elif self.tightened and p95 <= self.slo_ttft * self.clear_frac:
            self._relax(ctx)


class StageTierPolicy(Policy):
    """Workflow-plane tiering (Aragog-style): when a stage's p95 call
    latency breaches, shift its calls to the smaller model tier; when
    it stays calm, shift back up.  Acts only through the stage's
    registered ``stage.<name>.model_tier`` knob, so the same behaviour
    is expressible in intent as

        rule slow on stage reviewer.p95 > 2 hold 3:
            => set stage reviewer.model_tier small
    """

    name = "stage-tier"

    def __init__(self, stages: list[str], slow_above: float,
                 fast_below: Optional[float] = None,
                 small: str = "small", large: str = "large",
                 dwell: float = 2.0):
        self.stages = stages
        self.slow_above = slow_above
        self.fast_below = (fast_below if fast_below is not None
                           else slow_above * 0.4)
        self.small = small
        self.large = large
        self.dwell = dwell               # min residency per tier (anti-flap)
        self._moved: dict[str, float] = {}
        self.shifts: list[tuple[float, str, str]] = []

    def on_tick(self, ctx: ControlContext) -> None:
        for s in self.stages:
            p95 = ctx.metric(f"stage.{s}.p95", "last",
                             default=float("nan"))
            if p95 != p95:
                continue
            cur = ctx.get(f"stage.{s}", "model_tier")
            want = cur
            if p95 > self.slow_above and cur != self.small:
                want = self.small
            elif p95 < self.fast_below and cur != self.large:
                want = self.large
            if want == cur:
                continue
            if ctx.now - self._moved.get(s, -1e18) < self.dwell:
                continue
            ctx.set(f"stage.{s}", "model_tier", want)
            self._moved[s] = ctx.now
            self.shifts.append((ctx.now, s, want))


class ChunkPolicy(Policy):
    """Mixed-batching plane: retune an engine's chunked-prefill size from
    its runtime decode-stall signal — the paper's software-defined knob
    loop closed over the ``prefill_chunk`` attribute.

    Sustained ``itl_p95`` above the SLO means the co-running prefill
    chunk is stealing too much of each fused step: halve the chunk
    (floored at ``chunk_min``, so prefill always progresses).  When ITL
    is calm with margin AND prompt tokens are backed up behind prefill,
    grow the chunk back (capped at ``chunk_max``) so TTFT recovers.
    ``dwell`` rate-limits moves (anti-flap), and a ``prefill_chunk`` of
    0 (= whole prompt) is treated as ``chunk_max`` when shrinking.
    Acts only through the engine's registered Table-1 knob, so the same
    behaviour is expressible in intent as

        rule stall on engine e0.itl_p95 > 0.05:
            => set engine e0.prefill_chunk 256
    """

    name = "chunk-policy"

    def __init__(self, engine: str, itl_slo: float,
                 chunk_min: int = 64, chunk_max: int = 1024,
                 shrink: float = 0.5, grow: float = 2.0,
                 clear_frac: float = 0.5, dwell: float = 0.5):
        assert 0 < shrink < 1 < grow
        self.engine = engine
        self.itl_slo = itl_slo
        self.chunk_min = chunk_min
        self.chunk_max = chunk_max
        self.shrink = shrink
        self.grow = grow
        self.clear_frac = clear_frac     # grow only below slo*clear_frac
        self.dwell = dwell
        self._last_move = -1e18
        self.moves: list[tuple[float, int]] = []

    def on_tick(self, ctx: ControlContext) -> None:
        itl = ctx.metric(f"{self.engine}.itl_p95", "last",
                         default=float("nan"))
        if itl != itl:
            return                       # no decode signal yet
        if ctx.now - self._last_move < self.dwell:
            return
        cur = int(ctx.get(self.engine, "prefill_chunk"))
        eff = cur if cur > 0 else self.chunk_max
        want = eff
        if itl > self.itl_slo:
            want = max(self.chunk_min, int(eff * self.shrink))
        elif itl < self.itl_slo * self.clear_frac:
            backlog = ctx.metric(f"{self.engine}.prefill_queue_tokens",
                                 "last", default=0.0)
            if backlog > 0 and eff < self.chunk_max:
                want = min(self.chunk_max, int(eff * self.grow))
        if want == cur:
            return
        ctx.set(self.engine, "prefill_chunk", want)
        self._last_move = ctx.now
        self.moves.append((ctx.now, want))


class OffloadPolicy(Policy):
    """Tool-call suspend/resume plane: escalate an engine's KV offload
    stance from queue pressure.  Under light load the ``auto`` rule is
    right — pinning a tool-waiting sequence in its slot is free when
    nobody wants the slot.  Once admission backs up, every parked
    sequence is stolen decode capacity: push the engine to
    ``aggressive`` (spill every suspend to the host tier), and relax
    back to ``auto`` only below the hysteresis low-water mark.  Acts
    only through the engine's registered Table-1 ``offload`` knob, so
    the same behaviour is expressible in intent as

        rule offload on engine e0.queue_len > 8:
            => set engine e0.offload aggressive
    """

    name = "offload-policy"

    def __init__(self, engine: str, queue_hi: float = 8.0,
                 queue_lo: float = 2.0, dwell: float = 0.5):
        assert queue_lo <= queue_hi
        self.engine = engine
        self.queue_hi = queue_hi
        self.queue_lo = queue_lo
        self.dwell = dwell
        self._last_move = -1e18
        self.moves: list[tuple[float, str]] = []

    def on_tick(self, ctx: ControlContext) -> None:
        q = ctx.metric(f"{self.engine}.queue_len", "last",
                       default=float("nan"))
        if q != q:
            return                       # engine not reporting yet
        if ctx.now - self._last_move < self.dwell:
            return
        cur = str(ctx.get(self.engine, "offload"))
        want = cur
        if q > self.queue_hi:
            want = "aggressive"
        elif q <= self.queue_lo and cur == "aggressive":
            want = "auto"
        if want == cur:
            return
        ctx.set(self.engine, "offload", want)
        self._last_move = ctx.now
        self.moves.append((ctx.now, want))


class RoleBalancerPolicy(Policy):
    """Disaggregation plane (ISSUE 4): flip engine *roles* from fleet
    pressure — the SDN-native version of disaggregated serving.  Reads
    the ``FleetAggregate`` gauges the DisaggPool publishes
    (``cluster.prefill_pressure``, ``cluster.decode_slot_util``) and
    acts only through each engine's registered ``role`` knob, so the
    same behaviour is expressible in intent as

        rule surge on cluster.prefill_pressure > 2 hold 1:
            => set engine e2.role prefill

    Guard rails: the fleet always keeps at least one prefill-capable
    and at least one decode-capable engine (``unified`` counts as
    both), and ``dwell`` rate-limits flips so the fleet doesn't thrash
    around a pressure boundary.
    """

    name = "role-balancer"

    def __init__(self, engines: list[str], pressure_hi: float = 2.0,
                 pressure_lo: float = 0.25, min_prefill: int = 0,
                 min_decode: int = 1, dwell: float = 0.5,
                 release_dwell: Optional[float] = None,
                 window: float = 1.0, prefix: str = "cluster",
                 slot_profile: Optional[dict] = None):
        assert pressure_lo <= pressure_hi
        self.engines = engines
        self.pressure_hi = pressure_hi
        self.pressure_lo = pressure_lo
        self.min_prefill = min_prefill
        self.min_decode = min_decode
        self.dwell = dwell
        # asymmetric residency: conscripting a prefill engine migrates
        # its running decodes (disruptive — deliberate), releasing one
        # back to decode duty drains nothing (cheap — prompt), so the
        # two directions get separate dwells
        self.release_dwell = (release_dwell if release_dwell is not None
                              else dwell / 3.0)
        self.window = window        # sustained-pressure window: a role
        self.prefix = prefix        # flip drains real work, so transient
                                    # spikes must not trigger one
        # role -> max_num_seqs co-flip: a decode-only engine spends the
        # activation memory a unified engine reserves for prefill chunks
        # on extra decode slots instead, so flipping the role also
        # reshapes the batch (both through the same Table-1 surface)
        self.slot_profile = slot_profile or {}
        self._last_flip = -1e18
        self.flips: list[tuple[float, str, str]] = []

    def _flip(self, ctx: ControlContext, engine: str, role: str) -> None:
        ctx.role(engine, role)
        if role in self.slot_profile:
            ctx.set(engine, "max_num_seqs", self.slot_profile[role])
        self._last_flip = ctx.now
        self.flips.append((ctx.now, engine, role))

    def on_tick(self, ctx: ControlContext) -> None:
        since_flip = ctx.now - self._last_flip
        if since_flip < min(self.dwell, self.release_dwell):
            return
        pressure = ctx.metric(f"{self.prefix}.prefill_pressure", "mean",
                              self.window, default=float("nan"))
        if pressure != pressure:
            return                       # fleet gauges not flowing yet
        roles = {e: ctx.get(e, "role") for e in self.engines}
        n_prefill = sum(1 for r in roles.values() if r == "prefill")
        decode_capable = sum(1 for r in roles.values() if r != "prefill")
        prefill_capable = len(roles) - sum(1 for r in roles.values()
                                           if r == "decode")
        if pressure > self.pressure_hi and since_flip >= self.dwell:
            # prefill starved: conscript the least decode-utilized
            # non-prefill engine — but never drain the decode fleet
            if decode_capable - 1 < max(self.min_decode, 1):
                return
            cand = [e for e in self.engines if roles[e] != "prefill"]
            pick = min(cand, key=lambda e: ctx.metric(
                f"{e}.decode_slot_util", "last", default=0.0))
            self._flip(ctx, pick, "prefill")
        elif (pressure < self.pressure_lo and n_prefill > self.min_prefill
                and since_flip >= self.release_dwell):
            # prefill idle: return the emptiest prefill engine to
            # decode duty — but keep a prefill path alive
            if prefill_capable - 1 < 1:
                return
            cand = [e for e in self.engines if roles[e] == "prefill"]
            pick = min(cand, key=lambda e: ctx.metric(
                f"{e}.prefill_queue_tokens", "last", default=0.0))
            self._flip(ctx, pick, "decode")


class AutoscalePolicy(Policy):
    """Elastic-scaling hook (§4 posture): ask the runtime to add/remove
    instances when sustained load crosses thresholds.  The actual
    spawn/drain is the runtime's job (runtime/elastic.py); the policy
    only decides."""

    name = "autoscale"

    def __init__(self, instances: list[str], scale_up_at: float = 12.0,
                 scale_down_at: float = 1.0, window: float = 2.0,
                 cooldown: float = 5.0):
        self.instances = instances
        self.scale_up_at = scale_up_at
        self.scale_down_at = scale_down_at
        self.window = window
        self.cooldown = cooldown
        self._last = -1e18
        self.decisions: list[tuple[float, str]] = []
        self.scale_fn = None            # runtime attaches

    def on_tick(self, ctx: ControlContext) -> None:
        if ctx.now - self._last < self.cooldown:
            return
        loads = [ctx.metric(f"i.queue_len".replace("i", i), "mean",
                            self.window) for i in self.instances]
        mean_load = sum(loads) / max(len(loads), 1)
        if mean_load >= self.scale_up_at:
            self.decisions.append((ctx.now, "up"))
            ctx.note("autoscale", f"scale up (load={mean_load:.1f})")
            if self.scale_fn:
                self.scale_fn(+1)
            self._last = ctx.now
        elif mean_load <= self.scale_down_at and len(self.instances) > 1:
            self.decisions.append((ctx.now, "down"))
            ctx.note("autoscale", f"scale down (load={mean_load:.1f})")
            if self.scale_fn:
                self.scale_fn(-1)
            self._last = ctx.now
