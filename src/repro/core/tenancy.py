"""Multi-tenant SLO plane — tenancy as a first-class, intent-controllable
serving object.

The paper's thesis is that serving attributes should be *programmed*
from runtime state.  Up to now the most load-bearing attribute of all —
who gets served next — was a single static sort; this module makes the
tenant the unit of control:

* ``TenantSpec`` — declarative description of one tenant: fair-share
  ``weight`` (consumed by the scheduler's ``weighted_fair`` queue
  discipline), token-bucket ``rate``/``burst`` (enforced by the router's
  admission meter), and SLO targets (``slo_class``, ``p95_ttft_target``).
* ``TenantEntry`` — the per-tenant ControlSurface, registered as
  ``tenant.<name>`` (the stage-plane idiom): ``weight`` / ``rate`` /
  ``burst`` / ``paused`` are ordinary Table-1 knobs, so policies and
  intent programs (``set tenant batch.weight 0.2``) reshape fairness at
  runtime through the same audited surface as every other attribute.
  The entry also owns the tenant's token bucket.
* ``TenantDirectory`` — the shared lookup the data plane consults
  (schedulers read weights, routers meter buckets) plus the metric
  rollup point: it publishes ``tenant.<t>.ttft`` observations and the
  derived ``tenant.<t>.p95_ttft`` / ``.share`` / ``.throttle_rate``
  gauges (via ``FleetAggregate.watch_window`` when a MetricBus is
  attached, so intent triggers like ``on tenant gold.p95_ttft > 1.5``
  ride the ordinary push tier).

Unknown tenants are auto-registered with neutral defaults (weight 1,
unmetered), so pre-tenancy call sites — everything stamps the implicit
``"default"`` tenant — run unchanged.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.knobs import ControlSurface, KnobSpec
from repro.core.metrics import FleetAggregate, RollingStat
from repro.core.types import SLOClass


@dataclass
class TenantSpec:
    """Declarative tenant description (constructor input; the live,
    knob-controllable state lives on the registered ``TenantEntry``)."""

    tenant: str
    weight: float = 1.0                  # weighted-fair share weight
    rate: float = math.inf               # token-bucket refill (tokens/s)
    burst: float = 8192.0                # token-bucket capacity (tokens)
    slo_class: str = SLOClass.STANDARD.value
    p95_ttft_target: float = math.inf    # seconds; inf = no target


class TenantEntry(ControlSurface):
    """One tenant's live control state: a registered ``tenant.<name>``
    controllable whose knobs feed the scheduler's fairness accounting
    (``weight``) and the router's admission meter (``rate`` / ``burst``
    / ``paused``)."""

    kind = "tenant"
    CAPABILITIES = ("fairness", "throttle")
    METRICS = ("ttft", "p95_ttft", "share", "throttle_rate",
               "admitted_tokens", "throttled")
    KNOB_SPECS = (
        KnobSpec("weight", kind="float", lo=1e-3,
                 doc="weighted-fair share weight"),
        KnobSpec("rate", kind="float", lo=0.0,
                 doc="token-bucket refill in tokens/s; inf = unmetered"),
        KnobSpec("burst", kind="float", lo=1.0,
                 doc="token-bucket capacity in tokens"),
        KnobSpec("paused", kind="bool",
                 doc="hold this tenant's traffic at the router"),
    )

    def __init__(self, spec: TenantSpec, directory: "TenantDirectory"):
        self.tenant = spec.tenant
        self.name = f"{directory.prefix}.{spec.tenant}"
        self.weight = spec.weight
        self.rate = spec.rate
        self.burst = spec.burst
        self.paused = False
        self.slo_class = spec.slo_class
        self.p95_ttft_target = spec.p95_ttft_target
        self._dir = directory
        # token bucket (refilled lazily on access)
        self._level = spec.burst if math.isfinite(spec.rate) else 0.0
        self._refill_t = 0.0
        self.admitted_tokens = 0.0       # metered through the bucket
        self.throttled_count = 0         # admission holds
        self.served_tokens = 0.0         # actual prefill+decode work

    # -- bucket -----------------------------------------------------------
    def _refill(self, now: float) -> None:
        if now > self._refill_t:
            self._level = min(self.burst,
                              self._level + (now - self._refill_t) * self.rate)
            self._refill_t = now

    def try_take(self, tokens: float, now: float) -> bool:
        """Meter ``tokens`` through the bucket; False = hold the message
        (paused tenant, or the bucket has not refilled enough yet).  A
        message costing more than ``burst`` passes once the bucket is
        FULL, driving the level negative — debt paid forward — so
        held-never-dropped admission cannot deadlock on oversized
        messages while the long-run rate stays enforced."""
        if self.paused:
            return False
        if math.isinf(self.rate):
            return True
        self._refill(now)
        if (self._level + 1e-9 >= tokens
                or self._level + 1e-9 >= self.burst):
            self._level -= tokens
            return True
        return False

    def time_until(self, tokens: float, now: float) -> float:
        """Seconds until ``try_take(tokens)`` could succeed (inf while
        paused or with a zero refill rate)."""
        if self.paused:
            return math.inf
        if math.isinf(self.rate):
            return 0.0
        self._refill(now)
        deficit = min(tokens, self.burst) - self._level
        if deficit <= 0:
            return 0.0
        if self.rate <= 0:
            return math.inf
        return deficit / self.rate

    # -- knob side effects -------------------------------------------------
    def on_knob_set(self, name: str, old, new) -> None:
        # a rate/burst bump or an unpause can unblock held traffic NOW;
        # routers subscribe to the directory's release hook
        if name in ("rate", "burst", "paused") and old != new:
            self._dir.notify_release()


class TenantDirectory:
    """Shared tenant lookup + metric rollup point (see module doc).

    One directory serves a whole fleet: schedulers read ``weight()``,
    routers meter ``try_take()``/``time_until()``, engines report
    ``observe_ttft()``, and the scheduler's fairness accounting reports
    ``note_served()``.  Everything is keyed by plain tenant name;
    unknown tenants auto-register with neutral defaults.
    """

    def __init__(self, collector=None, registry=None, prefix: str = "tenant",
                 share_window: float = 5.0, ttft_window: float = 10.0,
                 share_pub_interval: float = 0.25):
        self.collector = collector
        self.registry = registry
        self.prefix = prefix
        self.share_window = share_window
        self.ttft_window = ttft_window
        self.share_pub_interval = share_pub_interval
        self.entries: dict[str, TenantEntry] = {}
        self._release_fns: list[Callable[[], None]] = []
        self._served: dict[str, deque] = {}      # tenant -> (t, tokens)
        self._served_sum: dict[str, float] = {}  # windowed running totals
        self._last_share_pub = -math.inf
        self._gate: dict[str, deque] = {}        # tenant -> (t, throttled?)
        self._ttft: dict[str, RollingStat] = {}
        # derived-rollup tier: with a MetricBus attached, p95_ttft is a
        # FleetAggregate window aggregation over the raw ttft series —
        # the same push tier every other fleet gauge uses
        self.fleet: Optional[FleetAggregate] = None
        if collector is not None and collector.bus is not None:
            self.fleet = FleetAggregate(collector, prefix=prefix)

    # -- registration ------------------------------------------------------
    def add(self, spec_or_name, **kw) -> TenantEntry:
        """Register a tenant from a TenantSpec (or name + spec kwargs)."""
        spec = (spec_or_name if isinstance(spec_or_name, TenantSpec)
                else TenantSpec(spec_or_name, **kw))
        if spec.tenant in self.entries:
            raise ValueError(f"duplicate tenant: {spec.tenant}")
        entry = TenantEntry(spec, self)
        self.entries[spec.tenant] = entry
        if self.registry is not None:
            self.registry.register(entry)
        if self.fleet is not None:
            self.fleet.watch_window(f"{spec.tenant}.p95_ttft",
                                    f"{self.prefix}.{spec.tenant}.ttft",
                                    how="p95", window=self.ttft_window)
        return entry

    def ensure(self, tenant: str) -> TenantEntry:
        entry = self.entries.get(tenant)
        if entry is None:
            entry = self.add(TenantSpec(tenant))
        return entry

    def get(self, tenant: str) -> TenantEntry:
        return self.entries[tenant]

    def names(self) -> list[str]:
        return list(self.entries)

    # -- data-plane reads --------------------------------------------------
    def weight(self, tenant: str) -> float:
        return self.ensure(tenant).weight

    def paused(self, tenant: str) -> bool:
        return self.ensure(tenant).paused

    def try_take(self, tenant: str, tokens: float, now: float) -> bool:
        return self.ensure(tenant).try_take(tokens, now)

    def time_until(self, tenant: str, tokens: float, now: float) -> float:
        return self.ensure(tenant).time_until(tokens, now)

    # -- release hooks (routers pump held traffic on refill/unpause) -------
    def subscribe_release(self, fn: Callable[[], None]) -> None:
        self._release_fns.append(fn)

    def notify_release(self) -> None:
        for fn in list(self._release_fns):
            fn()

    # -- metric rollups ----------------------------------------------------
    def _gauge(self, tenant: str, metric: str, value: float,
               t: float) -> None:
        if self.collector is not None:
            self.collector.gauge(f"{self.prefix}.{tenant}.{metric}",
                                 value, t)

    def note_admitted(self, tenant: str, tokens: float, t: float) -> None:
        """Router admission: the message cleared the tenant's bucket."""
        entry = self.ensure(tenant)
        entry.admitted_tokens += tokens
        if self.collector is not None:
            self.collector.counter(
                f"{self.prefix}.{tenant}.admitted_tokens", tokens, t)
        self._note_gate(tenant, throttled=False, t=t)

    def note_throttled(self, tenant: str, t: float) -> None:
        """Router admission: the message was held by the meter."""
        entry = self.ensure(tenant)
        entry.throttled_count += 1
        if self.collector is not None:
            self.collector.counter(
                f"{self.prefix}.{tenant}.throttled", 1, t)
        self._note_gate(tenant, throttled=True, t=t)

    def _note_gate(self, tenant: str, throttled: bool, t: float) -> None:
        q = self._gate.setdefault(tenant, deque())
        q.append((t, throttled))
        lo = t - self.share_window
        while q and q[0][0] < lo:
            q.popleft()
        if q:
            rate = sum(1 for _, th in q if th) / len(q)
            self._gauge(tenant, "throttle_rate", rate, t)

    def note_served(self, tenant: str, tokens: float, t: float) -> None:
        """Scheduler fairness accounting: actual prefill+decode tokens
        processed for this tenant.  Maintains O(1)-amortized windowed
        running sums (this is called once per decode token on the hot
        path) and publishes every tenant's ``share`` gauge — fraction of
        fleet tokens served — at most every ``share_pub_interval``."""
        self.ensure(tenant).served_tokens += tokens
        q = self._served.setdefault(tenant, deque())
        q.append((t, tokens))
        lo = t - self.share_window
        s = self._served_sum.get(tenant, 0.0) + tokens
        while q and q[0][0] < lo:
            s -= q.popleft()[1]
        self._served_sum[tenant] = s
        if (self.collector is None
                or t - self._last_share_pub < self.share_pub_interval):
            return
        # full cross-tenant sweep only at publish time: idle tenants'
        # stale window entries expire here, not on the per-token path
        self._last_share_pub = t
        for name, dq in self._served.items():
            sn = self._served_sum[name]
            while dq and dq[0][0] < lo:
                sn -= dq.popleft()[1]
            self._served_sum[name] = sn
        fleet_total = sum(self._served_sum.values())
        if fleet_total > 0:
            for name, tot in self._served_sum.items():
                self._gauge(name, "share", tot / fleet_total, t)

    def observe_ttft(self, tenant: str, ttft: float, t: float) -> None:
        """Engine first-token callback: raw per-tenant TTFT sample.
        With a MetricBus the derived ``p95_ttft`` gauge re-publishes via
        ``FleetAggregate.watch_window``; without one, from a bounded
        rolling window here (same series name either way)."""
        self.ensure(tenant)
        if self.collector is not None:
            self.collector.observe(f"{self.prefix}.{tenant}.ttft", ttft, t)
        if self.fleet is None:
            stat = self._ttft.get(tenant)
            if stat is None:
                stat = self._ttft[tenant] = RollingStat()
            stat.add(ttft)
            self._gauge(tenant, "p95_ttft", stat.pctl(0.95), t)
