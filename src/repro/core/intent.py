"""Declarative intent language (paper §3.1 goal 3, §5 "Languages for
Agentic Control").

Infrastructure engineers express goals without touching control-plane
internals; the compiler turns them into a closed-loop ``Policy``:

    objective: maximize throughput under p95(pipeline.task_latency) <= 2.0

    rule high_load: when mean(tester.queue_len, 2.0) > 8
        => granularity dev->tester batch
    rule mid_load: when mean(tester.queue_len, 2.0) > 2
        => granularity dev->tester pipeline
    rule low_load hold 0.5: when mean(tester.queue_len, 2.0) <= 2
        => granularity dev->tester stream; reset tester-0.admit_priority_min

Grammar (line oriented; '#' comments):

    objective: (minimize|maximize) EXPR [under COND]
    rule NAME [hold SECONDS]: when COND => ACTION (';' ACTION)*

    COND   := TERM (('and'|'or') TERM)*
    TERM   := AGG '(' METRIC [',' WINDOW] ')' CMP NUMBER
    ACTION := set TARGET.KNOB VALUE | reset TARGET.KNOB
            | granularity CHANNEL (batch|pipeline|stream)
            | route SESSION INSTANCE | pace CHANNEL SECONDS
            | note TEXT

Rules are evaluated top-to-bottom each controller tick; **the first rule
whose condition holds fires** (guarded-command semantics — put the most
specific condition first), unless it is still within its ``hold``
window.  ``set`` is idempotent at the controller, so a firing rule does
not thrash knobs that already hold the target value.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.controller import ControlContext, Policy
from repro.core.metrics import AGGREGATIONS


class IntentError(ValueError):
    pass


_CMP = {
    "<=": lambda a, b: a <= b,
    ">=": lambda a, b: a >= b,
    "<": lambda a, b: a < b,
    ">": lambda a, b: a > b,
    "==": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
}

_TERM_RE = re.compile(
    r"^\s*(?P<agg>\w+)\s*\(\s*(?P<metric>[\w.>\-]+)"
    r"\s*(?:,\s*(?P<window>[\d.]+)\s*)?\)\s*"
    r"(?P<cmp><=|>=|==|!=|<|>)\s*(?P<num>-?[\d.]+(?:e-?\d+)?)\s*$")


@dataclass
class Term:
    agg: str
    metric: str
    window: float
    cmp: str
    value: float

    def eval(self, ctx: ControlContext) -> bool:
        v = ctx.metric(self.metric, self.agg, self.window,
                       default=float("nan"))
        if v != v:                      # NaN — metric not yet observed
            return False
        return _CMP[self.cmp](v, self.value)

    def describe(self, ctx: ControlContext) -> str:
        v = ctx.metric(self.metric, self.agg, self.window,
                       default=float("nan"))
        return f"{self.agg}({self.metric})={v:.4g} {self.cmp} {self.value}"


@dataclass
class Cond:
    terms: list[Term]
    ops: list[str]                     # 'and' | 'or' between terms

    def eval(self, ctx: ControlContext) -> bool:
        out = self.terms[0].eval(ctx)
        for op, term in zip(self.ops, self.terms[1:]):
            if op == "and":
                out = out and term.eval(ctx)
            else:
                out = out or term.eval(ctx)
        return out


def _parse_value(s: str):
    ls = s.lower()
    if ls in ("true", "false"):
        return ls == "true"
    try:
        return int(s)
    except ValueError:
        pass
    try:
        return float(s)
    except ValueError:
        return s


def _parse_cond(text: str, lineno: int) -> Cond:
    parts = re.split(r"\s+(and|or)\s+", text)
    terms, ops = [], []
    for i, p in enumerate(parts):
        if i % 2 == 1:
            ops.append(p)
            continue
        m = _TERM_RE.match(p)
        if not m:
            raise IntentError(f"line {lineno}: bad condition term {p!r}")
        agg = m.group("agg")
        if agg not in AGGREGATIONS:
            raise IntentError(f"line {lineno}: unknown aggregation {agg!r}")
        terms.append(Term(agg, m.group("metric"),
                          float(m.group("window") or "inf"),
                          m.group("cmp"), float(m.group("num"))))
    return Cond(terms, ops)


def _parse_action(text: str, lineno: int) -> Callable[[ControlContext], None]:
    toks = text.split()
    if not toks:
        raise IntentError(f"line {lineno}: empty action")
    op, args = toks[0], toks[1:]
    if op == "set" and len(args) == 2:
        target, _, knob = args[0].rpartition(".")
        value = _parse_value(args[1])
        if not target:
            raise IntentError(f"line {lineno}: set needs TARGET.KNOB")
        return lambda ctx: ctx.set(target, knob, value)
    if op == "reset" and len(args) == 1:
        target, _, knob = args[0].rpartition(".")
        if not target:
            raise IntentError(f"line {lineno}: reset needs TARGET.KNOB")
        return lambda ctx: ctx.reset(target, knob)
    if op == "granularity" and len(args) == 2:
        chan, mode = args
        return lambda ctx: ctx.granularity(chan, mode)
    if op == "pace" and len(args) == 2:
        chan, sec = args[0], float(args[1])
        return lambda ctx: ctx.set(chan, "pace", sec)
    if op == "route" and len(args) == 2:
        sess, inst = args
        return lambda ctx: ctx.route(sess, inst)
    if op == "note":
        text_ = " ".join(args)
        return lambda ctx: ctx.note("intent", text_)
    raise IntentError(f"line {lineno}: unknown action {text!r}")


@dataclass
class IntentRule:
    name: str
    cond: Cond
    actions: list[Callable]
    hold: float = 0.0
    last_fired: float = -1e18
    fire_count: int = 0

    def maybe_fire(self, ctx: ControlContext) -> bool:
        if not self.cond.eval(ctx):
            return False
        if ctx.now - self.last_fired < self.hold:
            return True                 # matched but held: still consumes
        self.last_fired = ctx.now
        self.fire_count += 1
        for act in self.actions:
            act(ctx)
        return True


@dataclass
class Objective:
    direction: str                      # minimize | maximize
    expr: str
    constraint: Optional[str] = None

    def describe(self) -> str:
        s = f"{self.direction} {self.expr}"
        if self.constraint:
            s += f" under {self.constraint}"
        return s


class IntentPolicy(Policy):
    """A compiled intent program: guarded rules over the state store."""

    def __init__(self, objective: Optional[Objective],
                 rules: list[IntentRule], source: str = ""):
        self.objective = objective
        self.rules = rules
        self.source = source
        self.name = "intent"

    def on_tick(self, ctx: ControlContext) -> None:
        for rule in self.rules:
            if rule.maybe_fire(ctx):
                return                 # guarded commands: first match wins

    # -- introspection -----------------------------------------------------
    def stats(self) -> dict[str, int]:
        return {r.name: r.fire_count for r in self.rules}


_RULE_RE = re.compile(
    r"^rule\s+(?P<name>[\w\-]+)(?:\s+hold\s+(?P<hold>[\d.]+))?\s*:"
    r"\s*when\s+(?P<cond>.+?)\s*=>\s*(?P<actions>.+)$")
_OBJ_RE = re.compile(
    r"^objective\s*:\s*(?P<dir>minimize|maximize)\s+(?P<expr>.+?)"
    r"(?:\s+under\s+(?P<constraint>.+))?$")


def compile_intent(text: str) -> IntentPolicy:
    objective: Optional[Objective] = None
    rules: list[IntentRule] = []
    # allow rules to wrap onto continuation lines (indented)
    logical: list[tuple[int, str]] = []
    for i, raw in enumerate(text.splitlines(), 1):
        line = raw.split("#", 1)[0].rstrip()
        if not line.strip():
            continue
        if line[0].isspace() and logical:
            n, prev = logical[-1]
            logical[-1] = (n, prev + " " + line.strip())
        else:
            logical.append((i, line.strip()))
    for lineno, line in logical:
        m = _OBJ_RE.match(line)
        if m:
            objective = Objective(m.group("dir"), m.group("expr"),
                                  m.group("constraint"))
            continue
        m = _RULE_RE.match(line)
        if m:
            cond = _parse_cond(m.group("cond"), lineno)
            actions = [_parse_action(a.strip(), lineno)
                       for a in m.group("actions").split(";") if a.strip()]
            rules.append(IntentRule(m.group("name"), cond, actions,
                                    hold=float(m.group("hold") or 0.0)))
            continue
        raise IntentError(f"line {lineno}: cannot parse {line!r}")
    if not rules:
        raise IntentError("intent program has no rules")
    return IntentPolicy(objective, rules, source=text)
