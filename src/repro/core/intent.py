"""Declarative intent language v2 (paper §3.1 goal 3, §5 "Languages for
Agentic Control").

Infrastructure engineers express goals without touching control-plane
internals; the compiler turns them into a closed-loop ``Policy``:

    objective: maximize throughput under p95(pipeline.task_latency) <= 2.0

    rule high_load: when mean(tester.queue_len, 2.0) > 8
        => granularity dev->tester batch
    rule low_load hold 0.5: when mean(tester.queue_len, 2.0) <= 2
        => granularity dev->tester stream; reset tester-0.admit_priority_min
    # v2: event-triggered rules — fired by MetricBus threshold pushes
    # (or named controller events) BETWEEN interval polls
    rule burst on tester-0.queue_len > 12 hold 4:
        => scale tester-group +1; gate dev->tester on
    # workflow plane: `stage NAME` selects a registered stage.<NAME>
    # controllable / its exported stage.<NAME>.* gauges
    rule slow_review on stage reviewer.p95 > 2 hold 3:
        => set stage reviewer.model_tier small
    # disaggregation plane: flip an engine's phase role from fleet
    # pressure (`engine NAME` selects the engine's registered knobs)
    rule surge on cluster.prefill_pressure > 2 hold 1:
        => set engine e3.role prefill
    # tenancy plane: `tenant NAME` selects a registered tenant.<NAME>
    # controllable / its exported tenant.<NAME>.* rollups
    rule guard on tenant gold.p95_ttft > 1.5 hold 2:
        => set tenant batch.weight 0.2

Grammar (line oriented; '#' comments):

    objective: (minimize|maximize) EXPR [under COND]
    rule NAME [hold SECONDS] [on EVENT] [hold SECONDS]:
        [when COND] => ACTION (';' ACTION)*

    EVENT  := METRIC CMP NUMBER        (MetricBus threshold subscription)
            | NAME                     (named controller event, e.g.
                                        task_start, instance_failed)
    COND   := TERM (('and'|'or') TERM)*
    TERM   := AGG '(' METRIC [',' WINDOW] ')' CMP NUMBER
    METRIC := exact series name, or a glob (``tester-*.queue_len``)
              pooling every matching series fleet-wide;
              ``stage NAME.METRIC`` sugars to ``stage.NAME.METRIC``
              (the workflow plane's per-stage gauge namespace);
              ``engine NAME.METRIC`` sugars to ``NAME.METRIC``
              (engines register unprefixed);
              ``tenant NAME.METRIC`` sugars to ``tenant.NAME.METRIC``
              (the tenancy plane's per-tenant rollup namespace)
    ACTION := set [stage|engine|tenant] TARGET.KNOB VALUE
            | reset [stage|engine|tenant] TARGET.KNOB
            | granularity CHANNEL (batch|pipeline|stream)
            | route SESSION INSTANCE | pace CHANNEL SECONDS
            | scale GROUP (+N|-N|N) | gate CHANNEL (on|off)
            | transfer SESSION SRC DST
            | pin PREFIX | unpin PREFIX
            | trace (on|off|RATE)          (global span sampling)
            | trace (tenant|stage) NAME (on|off|RATE)
            | note TEXT

A rule must have a ``when`` condition, an ``on`` trigger, or both.

Tick rules are evaluated top-to-bottom each controller tick; **the first
rule whose condition holds fires** (guarded-command semantics — put the
most specific condition first), unless it is still within its ``hold``
window.  ``set`` is idempotent at the controller, so a firing rule does
not thrash knobs that already hold the target value.

``on`` rules are event-driven: installed on a controller with a
``MetricBus`` they become threshold subscriptions (fresh on-demand
poll, then the ``when`` guard, then the actions — all between interval
ticks).  With a ``hold`` the subscription is level-triggered and
``hold`` is the re-fire cooldown, so a *sustained* breach keeps firing
(e.g. keep adding replicas while overloaded); without one it is
edge-triggered and fires once per excursion.  Without a bus the rules
degrade gracefully to tick rules whose trigger becomes a
``last(METRIC) CMP NUMBER`` condition term, so the same program runs on
both control-plane generations.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.controller import ControlContext, Policy
from repro.core.metrics import AGGREGATIONS


class IntentError(ValueError):
    pass


_CMP = {
    "<=": lambda a, b: a <= b,
    ">=": lambda a, b: a >= b,
    "<": lambda a, b: a < b,
    ">": lambda a, b: a > b,
    "==": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
}

_TERM_RE = re.compile(
    r"^\s*(?P<agg>\w+)\s*\(\s*(?P<metric>[\w.>\-*?\[\]]+)"
    r"\s*(?:,\s*(?P<window>[\d.]+)\s*)?\)\s*"
    r"(?P<cmp><=|>=|==|!=|<|>)\s*(?P<num>-?[\d.]+(?:e-?\d+)?)\s*$")


@dataclass
class Term:
    agg: str
    metric: str
    window: float
    cmp: str
    value: float

    def eval(self, ctx: ControlContext) -> bool:
        v = ctx.metric(self.metric, self.agg, self.window,
                       default=float("nan"))
        if v != v:                      # NaN — metric not yet observed
            return False
        return _CMP[self.cmp](v, self.value)

    def describe(self, ctx: ControlContext) -> str:
        v = ctx.metric(self.metric, self.agg, self.window,
                       default=float("nan"))
        return f"{self.agg}({self.metric})={v:.4g} {self.cmp} {self.value}"


@dataclass
class Cond:
    terms: list[Term]
    ops: list[str]                     # 'and' | 'or' between terms

    def eval(self, ctx: ControlContext) -> bool:
        out = self.terms[0].eval(ctx)
        for op, term in zip(self.ops, self.terms[1:]):
            if op == "and":
                out = out and term.eval(ctx)
            else:
                out = out or term.eval(ctx)
        return out


def _parse_value(s: str):
    ls = s.lower()
    if ls in ("true", "false"):
        return ls == "true"
    try:
        return int(s)
    except ValueError:
        pass
    try:
        return float(s)
    except ValueError:
        return s


# workflow-plane selector sugar: `stage reviewer.p95` names the series
# `stage.reviewer.p95` (and, in set/reset, the `stage.reviewer`
# controllable) — the grammar keeps the paper's "stage" vocabulary
# while the planes keep plain dotted names
_STAGE_SEL_RE = re.compile(r"\bstage\s+(?=[\w\-]+\.)")
# disaggregation-plane sugar: `engine e3.role` names the engine's plain
# registered name (`e3.role`) — engines register unprefixed, so the
# selector word simply drops, keeping rules like
# `on cluster.prefill_pressure > 2 => set engine e3.role prefill` readable
_ENGINE_SEL_RE = re.compile(r"\bengine\s+(?=[\w\-]+\.)")
# tenancy-plane sugar: `tenant gold.p95_ttft` names the series
# `tenant.gold.p95_ttft` (and, in set/reset, the `tenant.gold`
# controllable) — same shape as the stage selector
_TENANT_SEL_RE = re.compile(r"\btenant\s+(?=[\w\-]+\.)")


def _desugar_stage(text: str) -> str:
    text = _STAGE_SEL_RE.sub("stage.", text)
    text = _TENANT_SEL_RE.sub("tenant.", text)
    return _ENGINE_SEL_RE.sub("", text)


def _parse_cond(text: str, lineno: int) -> Cond:
    parts = re.split(r"\s+(and|or)\s+", _desugar_stage(text))
    terms, ops = [], []
    for i, p in enumerate(parts):
        if i % 2 == 1:
            ops.append(p)
            continue
        m = _TERM_RE.match(p)
        if not m:
            raise IntentError(f"line {lineno}: bad condition term {p!r}")
        agg = m.group("agg")
        if agg not in AGGREGATIONS:
            raise IntentError(f"line {lineno}: unknown aggregation {agg!r}")
        terms.append(Term(agg, m.group("metric"),
                          float(m.group("window") or "inf"),
                          m.group("cmp"), float(m.group("num"))))
    return Cond(terms, ops)


def _parse_action(text: str, lineno: int) -> Callable[[ControlContext], None]:
    toks = _desugar_stage(text).split()
    if not toks:
        raise IntentError(f"line {lineno}: empty action")
    op, args = toks[0], toks[1:]
    if op == "set" and len(args) == 2:
        target, _, knob = args[0].rpartition(".")
        value = _parse_value(args[1])
        if not target:
            raise IntentError(f"line {lineno}: set needs TARGET.KNOB")
        return lambda ctx: ctx.set(target, knob, value)
    if op == "reset" and len(args) == 1:
        target, _, knob = args[0].rpartition(".")
        if not target:
            raise IntentError(f"line {lineno}: reset needs TARGET.KNOB")
        return lambda ctx: ctx.reset(target, knob)
    if op == "granularity" and len(args) == 2:
        chan, mode = args
        return lambda ctx: ctx.granularity(chan, mode)
    if op == "pace" and len(args) == 2:
        chan, sec = args[0], float(args[1])
        return lambda ctx: ctx.set(chan, "pace", sec)
    if op == "route" and len(args) == 2:
        sess, inst = args
        return lambda ctx: ctx.route(sess, inst)
    if op == "scale" and len(args) == 2:
        group, amt = args
        if not re.fullmatch(r"[+-]?\d+", amt):
            raise IntentError(
                f"line {lineno}: scale needs GROUP +N|-N|N, got {amt!r}")
        if amt[0] in "+-":
            delta = int(amt)
            return lambda ctx: ctx.scale(group, delta)
        target = int(amt)
        return lambda ctx: ctx.scale_to(group, target)
    if op == "gate" and len(args) == 2:
        chan, sw = args
        if sw not in ("on", "off"):
            raise IntentError(
                f"line {lineno}: gate needs CHANNEL on|off, got {sw!r}")
        return lambda ctx: ctx.gate(chan, sw == "on")
    if op == "transfer" and len(args) == 3:
        sess, src, dst = args
        return lambda ctx: ctx.transfer_kv(sess, src, dst, proactive=True)
    if op == "pin" and len(args) == 1:
        prefix = args[0]
        return lambda ctx: ctx.pin(prefix)
    if op == "unpin" and len(args) == 1:
        prefix = args[0]
        return lambda ctx: ctx.unpin(prefix)
    if op == "trace" and len(args) in (1, 3):
        def _rate(tok: str) -> float:
            if tok == "on":
                return 1.0
            if tok == "off":
                return 0.0
            try:
                r = float(tok)
            except ValueError:
                raise IntentError(
                    f"line {lineno}: trace rate must be on|off|FLOAT, "
                    f"got {tok!r}") from None
            if not 0.0 <= r <= 1.0:
                raise IntentError(
                    f"line {lineno}: trace rate {r:g} outside [0, 1]")
            return r
        if len(args) == 1:
            rate = _rate(args[0])
            return lambda ctx: ctx.trace(None, rate)
        sel, scope_name, tok = args
        if sel not in ("tenant", "stage"):
            raise IntentError(
                f"line {lineno}: trace selector must be tenant|stage, "
                f"got {sel!r}")
        rate = _rate(tok)
        scope = f"{sel}:{scope_name}"
        return lambda ctx: ctx.trace(scope, rate)
    if op == "note":
        text_ = " ".join(args)
        return lambda ctx: ctx.note("intent", text_)
    raise IntentError(f"line {lineno}: unknown action {text!r}")


_TRIGGER_RE = re.compile(
    r"^(?P<metric>[\w.>\-*?\[\]]+)\s*(?P<cmp><=|>=|==|!=|<|>)\s*"
    r"(?P<num>-?[\d.]+(?:e-?\d+)?)$")
_EVENT_NAME_RE = re.compile(r"^[\w\-]+$")


@dataclass(frozen=True)
class Trigger:
    """``on`` clause of a v2 rule: a metric threshold (MetricBus
    subscription) or a named controller event (task_start, ...)."""

    event: Optional[str] = None
    metric: Optional[str] = None
    cmp: Optional[str] = None
    value: Optional[float] = None

    def as_term(self) -> Term:
        """Tick-path fallback when no MetricBus is attached."""
        return Term("last", self.metric, float("inf"), self.cmp, self.value)

    def describe(self) -> str:
        if self.event is not None:
            return self.event
        return f"{self.metric} {self.cmp} {self.value:g}"


def _parse_trigger(text: str, lineno: int) -> Trigger:
    text = _desugar_stage(text.strip())
    m = _TRIGGER_RE.match(text)
    if m:
        return Trigger(metric=m.group("metric"), cmp=m.group("cmp"),
                       value=float(m.group("num")))
    if _EVENT_NAME_RE.match(text):
        return Trigger(event=text)
    raise IntentError(f"line {lineno}: bad trigger {text!r} "
                      "(want METRIC CMP NUMBER or an event name)")


@dataclass
class IntentRule:
    name: str
    cond: Optional[Cond]
    actions: list[Callable]
    hold: float = 0.0
    trigger: Optional[Trigger] = None
    bus_bound: bool = False            # trigger registered on a MetricBus
    last_fired: float = -1e18
    fire_count: int = 0

    def _guard_holds(self, ctx: ControlContext, from_event: bool) -> bool:
        # on the tick path an unbound metric trigger degrades to a
        # last(METRIC) CMP NUMBER term; on the event path the bus already
        # established it, so only the explicit `when` guard remains
        if (not from_event and self.trigger is not None
                and self.trigger.metric is not None):
            if not self.trigger.as_term().eval(ctx):
                return False
        if self.cond is not None and not self.cond.eval(ctx):
            return False
        return True

    def maybe_fire(self, ctx: ControlContext, from_event: bool = False) -> bool:
        if not self._guard_holds(ctx, from_event):
            return False
        if ctx.now - self.last_fired < self.hold:
            return not from_event       # matched but held: still consumes
        self.last_fired = ctx.now
        self.fire_count += 1
        for act in self.actions:
            act(ctx)
        return True


@dataclass
class Objective:
    direction: str                      # minimize | maximize
    expr: str
    constraint: Optional[str] = None

    def describe(self) -> str:
        s = f"{self.direction} {self.expr}"
        if self.constraint:
            s += f" under {self.constraint}"
        return s


class IntentPolicy(Policy):
    """A compiled intent program: guarded rules over the state store,
    plus v2 event rules bound to the controller's MetricBus."""

    def __init__(self, objective: Optional[Objective],
                 rules: list[IntentRule], source: str = ""):
        self.objective = objective
        self.rules = rules
        self.source = source
        self.name = "intent"

    # -- bind time ----------------------------------------------------------
    def on_install(self, controller) -> None:
        bus = getattr(controller, "bus", None)
        if bus is None:
            return                     # metric triggers degrade to tick path
        for rule in self.rules:
            trig = rule.trigger
            if trig is None or trig.metric is None:
                continue
            cmp_fn = _CMP[trig.cmp]
            # with a hold, level-trigger so a sustained breach re-fires
            # every `hold` seconds (e.g. keep scaling while overloaded);
            # without one, edge-trigger so it can't storm
            bus.subscribe(
                trig.metric,
                predicate=lambda v, f=cmp_fn, x=trig.value: f(v, x),
                cooldown=rule.hold, edge=rule.hold <= 0,
                fn=lambda name, value, t, r=rule: controller.fire_on_event(
                    lambda ctx: r.maybe_fire(ctx, from_event=True),
                    reason=f"rule {r.name}: {name}={value:.4g}"))
            rule.bus_bound = True

    # -- interval path -------------------------------------------------------
    def on_tick(self, ctx: ControlContext) -> None:
        for rule in self.rules:
            if rule.trigger is not None and (rule.bus_bound
                                             or rule.trigger.event):
                continue               # event rules never tick
            if rule.maybe_fire(ctx):
                return                 # guarded commands: first match wins

    # -- event path ----------------------------------------------------------
    def on_event(self, ctx: ControlContext, kind: str, **kw) -> None:
        fresh = False
        for rule in self.rules:
            if rule.trigger is not None and rule.trigger.event == kind:
                if not fresh:
                    ctx.refresh()      # `when` guards read current metrics,
                    fresh = True       # not the previous tick's window
                rule.maybe_fire(ctx, from_event=True)

    # -- introspection -----------------------------------------------------
    def stats(self) -> dict[str, int]:
        return {r.name: r.fire_count for r in self.rules}


_RULE_RE = re.compile(
    r"^rule\s+(?P<name>[\w\-]+)"
    r"(?:\s+hold\s+(?P<hold>[\d.]+))?"
    r"(?:\s+on\s+(?P<event>.+?))?"
    r"(?:\s+hold\s+(?P<hold2>[\d.]+))?"
    r"\s*:\s*(?:when\s+(?P<cond>.+?)\s*)?=>\s*(?P<actions>.+)$")
_OBJ_RE = re.compile(
    r"^objective\s*:\s*(?P<dir>minimize|maximize)\s+(?P<expr>.+?)"
    r"(?:\s+under\s+(?P<constraint>.+))?$")


def compile_intent(text: str) -> IntentPolicy:
    objective: Optional[Objective] = None
    rules: list[IntentRule] = []
    # allow rules to wrap onto continuation lines (indented)
    logical: list[tuple[int, str]] = []
    for i, raw in enumerate(text.splitlines(), 1):
        line = raw.split("#", 1)[0].rstrip()
        if not line.strip():
            continue
        if line[0].isspace() and logical:
            n, prev = logical[-1]
            logical[-1] = (n, prev + " " + line.strip())
        else:
            logical.append((i, line.strip()))
    for lineno, line in logical:
        m = _OBJ_RE.match(line)
        if m:
            objective = Objective(m.group("dir"), m.group("expr"),
                                  m.group("constraint"))
            continue
        m = _RULE_RE.match(line)
        if m:
            if m.group("hold") and m.group("hold2"):
                raise IntentError(f"line {lineno}: 'hold' given twice")
            trigger = (None if m.group("event") is None
                       else _parse_trigger(m.group("event"), lineno))
            cond = (None if m.group("cond") is None
                    else _parse_cond(m.group("cond"), lineno))
            if cond is None and trigger is None:
                raise IntentError(f"line {lineno}: rule "
                                  f"{m.group('name')!r} needs a 'when' "
                                  "condition or an 'on' trigger")
            actions = [_parse_action(a.strip(), lineno)
                       for a in m.group("actions").split(";") if a.strip()]
            rules.append(IntentRule(
                m.group("name"), cond, actions, trigger=trigger,
                hold=float(m.group("hold") or m.group("hold2") or 0.0)))
            continue
        raise IntentError(f"line {lineno}: cannot parse {line!r}")
    if not rules:
        raise IntentError("intent program has no rules")
    return IntentPolicy(objective, rules, source=text)
