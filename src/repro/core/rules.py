"""Control rules (paper §3.1 design point 1).

The controller does not micromanage messages; it installs *rules* into
the data plane:

* ``AgentRule`` — agent-level: the default communication mode for a
  channel, admission floor under load, pacing.  Applying one is a batch
  of ``set()`` calls against the channel/engine shims.
* ``RequestRule`` — request-level: fine-grained routing of requests to
  agent instances (session pinning, overrides) and gating of speculative
  sends.  Routers and channels consult the installed ``RuleTable``.
"""
from __future__ import annotations

import fnmatch
from dataclasses import dataclass
from typing import Optional

from repro.core.types import Granularity, Message


@dataclass
class AgentRule:
    """Default communication behaviour for channels matching ``target``."""

    target: str                             # channel-name glob
    granularity: Optional[Granularity] = None
    stream_chunk: Optional[int] = None
    pace: Optional[float] = None
    admit_priority_min: Optional[int] = None   # applied to the dst engine

    def knob_updates(self) -> dict:
        out = {}
        if self.granularity is not None:
            out["granularity"] = self.granularity
        if self.stream_chunk is not None:
            out["stream_chunk"] = self.stream_chunk
        if self.pace is not None:
            out["pace"] = self.pace
        return out


@dataclass
class RequestRule:
    """Routing / gating for requests matching (session, task, tenant,
    flags)."""

    session: str = "*"                      # glob over session ids
    task: str = "*"                         # glob over task ids
    tenant: str = "*"                       # glob over tenant names
    speculative: Optional[bool] = None      # match only (non-)speculative
    route_to: Optional[str] = None          # instance name
    block: bool = False                     # hold until rule removed
    priority: Optional[int] = None

    def matches(self, msg: Message) -> bool:
        sess = (msg.payload or {}).get("session") or ""
        if not fnmatch.fnmatch(sess, self.session):
            return False
        if not fnmatch.fnmatch(msg.task_id or "", self.task):
            return False
        if not fnmatch.fnmatch(msg.tenant or "", self.tenant):
            return False
        if self.speculative is not None and msg.speculative != self.speculative:
            return False
        return True


class RuleTable:
    """The installed rule state, shared controller ↔ data plane."""

    def __init__(self):
        self.agent_rules: list[AgentRule] = []
        self.request_rules: list[RequestRule] = []
        self.version = 0

    def install(self, rule) -> None:
        if isinstance(rule, AgentRule):
            self.agent_rules = [r for r in self.agent_rules
                                if r.target != rule.target] + [rule]
        else:
            self.request_rules.append(rule)
        self.version += 1

    def remove_request_rules(self, predicate) -> int:
        before = len(self.request_rules)
        self.request_rules = [r for r in self.request_rules
                              if not predicate(r)]
        self.version += 1
        return before - len(self.request_rules)

    def route_for(self, msg: Message) -> Optional[str]:
        """Last matching request-rule wins (most recently installed)."""
        out = None
        for r in self.request_rules:
            if r.route_to and r.matches(msg):
                out = r.route_to
        return out

    def blocked(self, msg: Message) -> bool:
        return any(r.block and r.matches(msg) for r in self.request_rules)
