"""The paper's contribution: a software-defined agentic serving stack.

* metrics plane — core/metrics.py  (collectors, aggregation, specs)
* data plane    — core/dataplane.py (the reconfigurable channel shim)
* control plane — core/controller.py + core/registry.py + core/rules.py
* intents       — core/intent.py   (declarative policy language)
* policies      — core/policies.py (Fig 6/7 control programs)
* tenancy plane — core/tenancy.py  (tenant specs, fair-share weights,
                  admission buckets, per-tenant SLO rollups)
"""
from repro.core.controller import (Action, ControlContext, Controller,
                                   Policy)
from repro.core.dataplane import Channel
from repro.core.intent import (IntentError, IntentPolicy, Trigger,
                               compile_intent)
from repro.core.knobs import ControlSurface, KnobSpec
from repro.core.metrics import (AGGREGATIONS, CentralPoller, Collector,
                                MetricBus, MetricSpec, StateStore,
                                ThresholdSub, register_aggregation)
from repro.core.registry import Registry
from repro.core.rules import AgentRule, RequestRule, RuleTable
from repro.core.tenancy import TenantDirectory, TenantEntry, TenantSpec
from repro.core.trace import FlightRecorder, Span, Tracer
from repro.core.types import (AgentCard, Granularity, Message, Priority,
                              Request, RequestState, SLOClass)

__all__ = [
    "AGGREGATIONS", "Action", "AgentCard", "AgentRule", "CentralPoller",
    "Channel", "Collector", "ControlContext", "ControlSurface", "Controller",
    "FlightRecorder", "Granularity", "IntentError", "IntentPolicy",
    "KnobSpec", "Message", "MetricBus", "MetricSpec", "Policy", "Priority",
    "Registry", "Request", "RequestRule", "RequestState", "RuleTable",
    "SLOClass", "Span", "StateStore", "TenantDirectory", "TenantEntry",
    "TenantSpec", "ThresholdSub", "Tracer", "Trigger", "compile_intent",
    "register_aggregation",
]
