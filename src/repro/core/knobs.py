"""Declarative knob surface (paper Table 1, unified).

The seed hand-rolled the two-function ``set()/reset()`` shim separately
in every controllable class (channel, router, scheduler, engine, tool),
each with its own if/elif validation ladder.  This module replaces all
of them with ONE implementation:

* ``KnobSpec`` — declares a knob: type, bounds/choices, the attribute it
  backs onto (dotted paths allowed, e.g. ``cfg.max_batch_tokens``), an
  optional ``on_change`` hook for side effects, an optional dynamic
  ``clamp`` hook, and an optional ``delegate`` that forwards the knob to
  a sub-object which is itself a ``ControlSurface`` (engines delegate
  scheduler knobs this way).
* ``ControlSurface`` — a mixin deriving ``get_param`` / ``set_param`` /
  ``reset_param`` / ``card()`` from the class's ``KNOB_SPECS``, with
  uniform coercion, clamping, default-tracking (first-set value is the
  reset target), and audit emission (a bounded per-object ``knob_log``
  plus a ``<name>.knob_sets`` counter when a collector is attached).

The controller's registry keeps talking plain ``set_param``/``reset_param``
— nothing upstream changes; only the per-class ladders are gone.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

from repro.core.types import AgentCard

_TRUE_WORDS = ("1", "true", "on", "yes")
_FALSE_WORDS = ("0", "false", "off", "no")


@dataclass(frozen=True)
class KnobSpec:
    """One controllable attribute, declaratively."""

    name: str
    kind: str = "float"              # int | float | bool | str | enum
    enum: Optional[type] = None      # Enum class (kind implied)
    lo: Optional[float] = None       # clamp floor (int/float kinds)
    hi: Optional[float] = None       # clamp ceiling
    choices: Optional[tuple] = None  # allowed values (str kinds)
    attr: Optional[str] = None       # backing attribute; dotted path ok
    delegate: Optional[str] = None   # forward to this sub-surface
    on_change: Optional[str] = None  # method name: (old, new) -> None
    clamp: Optional[str] = None      # method name: (value) -> value
    doc: str = ""

    def delegated(self, path: str, **overrides) -> "KnobSpec":
        # the delegate's own surface runs the on_change hook; the
        # delegating level only coerces/clamps and tracks defaults
        overrides.setdefault("on_change", None)
        return dataclasses.replace(self, delegate=path, **overrides)

    # -- uniform validation / coercion ------------------------------------
    def coerce(self, value):
        if self.enum is not None:
            value = self.enum(value)
        elif self.kind == "int":
            value = int(value)
        elif self.kind == "float":
            value = float(value)
        elif self.kind == "bool":
            if isinstance(value, str):
                low = value.lower()
                if low in _TRUE_WORDS:
                    value = True
                elif low in _FALSE_WORDS:
                    value = False
                else:
                    raise ValueError(
                        f"knob {self.name!r}: bad boolean {value!r}")
            else:
                value = bool(value)
        elif self.kind == "str":
            value = str(value)
        if self.choices is not None and value not in self.choices:
            raise ValueError(f"knob {self.name!r}: {value!r} not in "
                             f"{self.choices}")
        if self.lo is not None and value < self.lo:
            value = type(value)(self.lo)
        if self.hi is not None and value > self.hi:
            value = type(value)(self.hi)
        return value


def _walk(obj, path: str):
    """Resolve a dotted attribute path to (owner, leaf_name)."""
    parts = path.split(".")
    for p in parts[:-1]:
        obj = getattr(obj, p)
    return obj, parts[-1]


class ControlSurface:
    """Mixin: the ONE set()/reset() implementation (paper Table 1).

    Subclasses declare ``KNOB_SPECS`` plus the card metadata class attrs
    (``kind``, ``CAPABILITIES``, ``METRICS``); ``KNOBS`` and the spec map
    are derived automatically.
    """

    KNOB_SPECS: tuple[KnobSpec, ...] = ()
    KNOBS: tuple[str, ...] = ()
    _SPEC_MAP: dict[str, KnobSpec] = {}
    kind: str = "controllable"
    CAPABILITIES: tuple[str, ...] = ()
    METRICS: tuple[str, ...] = ()
    KNOB_LOG_CAP = 256               # bounded audit trail per object

    def __init_subclass__(cls, **kw):
        super().__init_subclass__(**kw)
        if "KNOB_SPECS" in cls.__dict__:
            cls.KNOBS = tuple(s.name for s in cls.KNOB_SPECS)
            cls._SPEC_MAP = {s.name: s for s in cls.KNOB_SPECS}

    # -- spec access ------------------------------------------------------
    def _spec(self, name: str) -> KnobSpec:
        spec = self._SPEC_MAP.get(name)
        if spec is None:
            raise KeyError(f"{getattr(self, 'name', type(self).__name__)}: "
                           f"unknown knob {name!r}")
        return spec

    def knob_names(self) -> tuple[str, ...]:
        return self.KNOBS

    def knob_specs(self) -> tuple[KnobSpec, ...]:
        return self.KNOB_SPECS

    @property
    def _knob_defaults(self) -> dict:
        d = self.__dict__.get("_knob_defaults_")
        if d is None:
            d = self.__dict__["_knob_defaults_"] = {}
        return d

    @property
    def knob_log(self) -> list:
        log = self.__dict__.get("_knob_log_")
        if log is None:
            log = self.__dict__["_knob_log_"] = []
        return log

    # -- Table-1 surface ---------------------------------------------------
    def get_param(self, name: str):
        spec = self._spec(name)
        if spec.delegate is not None:
            return getattr(self, spec.delegate).get_param(name)
        owner, leaf = _walk(self, spec.attr or spec.name)
        return getattr(owner, leaf)

    def set_param(self, name: str, value) -> None:
        spec = self._spec(name)
        old = self.get_param(name)
        value = spec.coerce(value)
        if spec.clamp is not None:
            value = getattr(self, spec.clamp)(value)
        self._knob_defaults.setdefault(name, old)
        if spec.delegate is not None:
            getattr(self, spec.delegate).set_param(name, value)
        else:
            owner, leaf = _walk(self, spec.attr or spec.name)
            setattr(owner, leaf, value)
        if spec.on_change is not None:
            getattr(self, spec.on_change)(old, value)
        self._knob_audit(name, old, value)
        self.on_knob_set(name, old, value)

    def reset_param(self, name: str) -> None:
        self._spec(name)                       # unknown knobs still raise
        defaults = self._knob_defaults
        if name in defaults:
            self.set_param(name, defaults[name])

    # -- audit -------------------------------------------------------------
    def _surface_now(self) -> float:
        loop = getattr(self, "loop", None)
        if loop is not None:
            return loop.now()
        return 0.0

    def _knob_audit(self, name: str, old, new) -> None:
        log = self.knob_log
        log.append((self._surface_now(), name, old, new))
        if len(log) > self.KNOB_LOG_CAP:
            del log[: self.KNOB_LOG_CAP // 2]
        collector = getattr(self, "collector", None)
        if collector is not None:
            collector.counter(
                f"{getattr(self, 'name', type(self).__name__)}.knob_sets",
                1, self._surface_now())

    def on_knob_set(self, name: str, old, new) -> None:
        """Class-wide post-set hook (e.g. engines kick their step loop)."""

    # -- registration card -------------------------------------------------
    def card_metrics(self) -> tuple[str, ...]:
        return self.METRICS

    def card(self) -> AgentCard:
        return AgentCard(
            name=self.name, kind=self.kind,
            knobs={k: self.get_param(k) for k in self.KNOBS},
            metrics=tuple(self.card_metrics()),
            capabilities=tuple(self.CAPABILITIES))
