"""Control plane (paper §3.1).

A logically centralized controller that

1. polls the metrics plane into its ``StateStore`` on a fixed interval
   (the paper's centralized on-demand polling),
2. reacts to **events** between polls: named events agents push
   (``task_start`` …) and ``MetricBus`` threshold subscriptions — the
   hybrid event/interval control loop,
3. runs installed **policies** — closed-loop programs written against the
   store + registry (hand-written, or compiled from the declarative
   intent language in core/intent.py),
4. enforces decisions through the Table-1 ``set()/reset()`` surface and
   the **rule table** (agent-level + request-level rules) the data plane
   consults.

Policies receive a ``ControlContext`` capability object rather than raw
internals, which keeps control programs small and auditable — and gives
us one choke-point to log every action (the audit trail the benchmarks
print).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import fnmatch

from repro.core.metrics import CentralPoller, MetricBus, StateStore
from repro.core.registry import Registry
from repro.core.rules import AgentRule, RequestRule, RuleTable
from repro.core.types import Granularity
from repro.sim.clock import EventLoop


@dataclass
class Action:
    t: float
    kind: str         # set | reset | rule | transfer | scale | note
    target: str
    detail: str


class ControlContext:
    """Capability surface handed to policies each tick."""

    def __init__(self, controller: "Controller"):
        self._c = controller
        self.store: StateStore = controller.store
        self.registry: Registry = controller.registry
        self.rules: RuleTable = controller.rules

    @property
    def now(self) -> float:
        return self._c.loop.now()

    @property
    def graph(self):
        """The serving topology's workflow graph (agents/graph.py), when
        one is attached — policies can read stage structure, e.g. to
        walk a breaching stage's successors before re-tiering it."""
        return self._c.graph

    # -- metric sugar -----------------------------------------------------------
    def metric(self, name: str, agg: Optional[str] = None,
               window: float = float("inf"), default: float = 0.0) -> float:
        return self.store.get(name, agg, window, default)

    def refresh(self) -> None:
        """On-demand poll: event handlers call this so guards read the
        current metric window, not the previous tick's."""
        self._c.poller.poll(self.now)

    # -- Table-1 surface ---------------------------------------------------------
    def set(self, target: str, knob: str, value) -> None:
        cur = self._c.registry.get_param(target, knob)
        if cur == value:
            return                      # no-op sets don't thrash the system
        self._c.registry.set(target, knob, value)
        self._c._log("set", target, f"{knob}={value}")

    def reset(self, target: str, knob: str) -> None:
        before = self._c.registry.get_param(target, knob)
        self._c.registry.reset(target, knob)
        if self._c.registry.get_param(target, knob) != before:
            self._c._log("reset", target, knob)

    def get(self, target: str, knob: str):
        return self._c.registry.get_param(target, knob)

    # -- convenience wrappers ---------------------------------------------------
    def granularity(self, channel: str, g) -> None:
        self.set(channel, "granularity", Granularity(g))

    def install(self, rule) -> None:
        self._c.rules.install(rule)
        self._c._log("rule", getattr(rule, "target", "request"), repr(rule))
        if isinstance(rule, AgentRule):
            self._apply_agent_rule(rule)

    def _apply_agent_rule(self, rule: AgentRule) -> None:
        """Installing an AgentRule IS a batch of ``set()`` calls (the
        rules module's contract): the channel knobs land on every
        registered channel matching ``target``, and
        ``admit_priority_min`` lands on those channels' *destination
        engines* — rules.py documents it as "applied to the dst engine",
        but ``knob_updates()`` (channel knobs only) silently dropped
        it."""
        reg = self._c.registry
        for name in reg.of_kind("channel"):
            if not fnmatch.fnmatch(name, rule.target):
                continue
            for knob, value in rule.knob_updates().items():
                self.set(name, knob, value)
            if rule.admit_priority_min is None:
                continue
            dst = getattr(reg.get(name), "dst", None)
            for eng in self._dst_engines(dst):
                self.set(eng, "admit_priority_min",
                         rule.admit_priority_min)

    def _dst_engines(self, dst) -> list[str]:
        """Registered engine names behind a channel destination: a
        router fans out to its instances; a direct endpoint is its own
        engine (agents register their engine under the agent's name)."""
        if dst is None:
            return []
        cand = (list(getattr(dst, "instances", None) or ())
                or [getattr(dst, "name", "")])
        out = []
        for n in cand:
            try:
                card = self._c.registry.card(n)
            except KeyError:
                continue
            if "admit_priority_min" in card.knobs:
                out.append(n)
        return out

    def route(self, session: str, instance: str) -> None:
        """Pin a session to an instance (request-level rule)."""
        self._c.rules.remove_request_rules(
            lambda r: r.session == session and r.route_to is not None)
        self._c.rules.install(RequestRule(session=session,
                                          route_to=instance))
        self._c._log("rule", instance, f"route session={session}")

    def transfer_kv(self, session: str, src: str, dst: str,
                    proactive: bool = False) -> None:
        """Cross-instance state transfer (§3.1's rich-control example)."""
        if self._c.transfer_fn is None:
            raise RuntimeError("no kv-transfer backend attached")
        self._c.transfer_fn(session, src, dst, proactive)
        self._c._log("transfer", f"{src}->{dst}",
                     f"session={session} proactive={proactive}")

    # -- intent v2 verbs ---------------------------------------------------------
    def scale_to(self, group: str, n: int) -> None:
        """Set a group's replica target (intent ``scale GROUP N``)."""
        cur = int(self.get(group, "replicas"))
        n = max(1, int(n))
        if n == cur:
            return
        self._c.registry.set(group, "replicas", n)
        self._c._log("scale", group, f"replicas {cur}->{n}")

    def scale(self, group: str, delta: int) -> None:
        """Scale a group by ±delta replicas (intent ``scale GROUP ±N``)."""
        cur = int(self.get(group, "replicas"))
        self.scale_to(group, cur + int(delta))

    def gate(self, channel: str, on: bool) -> None:
        """Gate/release a channel's speculative traffic
        (intent ``gate CHANNEL on|off``)."""
        self.set(channel, "gate_speculative", bool(on))

    def role(self, engine: str, role: str) -> None:
        """Flip an engine's phase role (disaggregation plane; intent
        ``set engine NAME.role unified|prefill|decode``).  The engine's
        fabric drains role-inconsistent work on the flip; audited
        distinctly from plain knob sets so role churn is greppable."""
        cur = self.get(engine, "role")
        if cur == role:
            return
        self._c.registry.set(engine, "role", role)
        self._c._log("role", engine, f"{cur}->{role}")

    def pin(self, prefix: str) -> int:
        """Pin a named prefix in every registered cache plane (intent
        ``pin PREFIX``): its blocks become exempt from eviction."""
        n, hit = 0, []
        for name in self.registry.with_capability("pin"):
            n += self.registry.get(name).pin(prefix)
            hit.append(name)
        self._c._log("pin", ",".join(hit) or "-",
                     f"prefix={prefix} blocks={n}")
        return n

    def unpin(self, prefix: str) -> int:
        """Release a pinned prefix (intent ``unpin PREFIX``)."""
        n, hit = 0, []
        for name in self.registry.with_capability("pin"):
            n += self.registry.get(name).unpin(prefix)
            hit.append(name)
        self._c._log("unpin", ",".join(hit) or "-",
                     f"prefix={prefix} blocks={n}")
        return n

    def trace(self, scope: Optional[str], rate: float) -> None:
        """Set trace sampling (intent ``trace [tenant|stage NAME]
        on|off|RATE``): ``scope`` is ``None`` for the global rate or
        ``tenant:NAME`` / ``stage:NAME``; fans out to every registered
        tracer via the ``trace`` capability."""
        hit = []
        for name in self.registry.with_capability("trace"):
            self.registry.get(name).set_scope(scope, rate)
            hit.append(name)
        self._c._log("trace", ",".join(hit) or "-",
                     f"scope={scope or 'global'} rate={rate:g}")

    def note(self, target: str, detail: str) -> None:
        self._c._log("note", target, detail)


class Policy:
    """Base class: closed-loop control program."""

    name = "policy"

    def on_tick(self, ctx: ControlContext) -> None:  # pragma: no cover
        raise NotImplementedError

    def on_event(self, ctx: ControlContext, kind: str, **kw) -> None:
        """Optional push-path: agents raise events (task_start, task_done,
        instance_failed) the controller forwards between polls."""

    def on_install(self, controller: "Controller") -> None:
        """Bind-time hook: e.g. intent programs register their ``on``
        triggers as MetricBus threshold subscriptions here."""


class Controller:
    def __init__(self, loop: EventLoop, registry: Registry,
                 poller: CentralPoller, store: Optional[StateStore] = None,
                 interval: float = 0.05, bus: Optional[MetricBus] = None,
                 collector=None, actions_cap: int = 4096):
        self.loop = loop
        self.registry = registry
        self.poller = poller
        self.store = store or poller.store
        self.interval = interval
        self.bus = bus
        self.collector = collector
        self.rules = RuleTable()
        self.policies: list[Policy] = []
        self.actions: list[Action] = []      # bounded audit ring
        self.actions_cap = actions_cap
        self.actions_total = 0
        self.recorder = None                 # optional FlightRecorder
        self.transfer_fn: Optional[Callable] = None
        self.graph = None                # workflow graph (control-plane view)
        self._running = False
        self.ticks = 0
        self.events_handled = 0

    # -- policy management ---------------------------------------------------
    def install(self, policy: Policy) -> None:
        self.policies.append(policy)
        hook = getattr(policy, "on_install", None)
        if hook is not None:
            hook(self)

    def attach_transfer(self, fn: Callable) -> None:
        self.transfer_fn = fn

    def reapply_agent_rules(self) -> None:
        """Re-apply every installed AgentRule against the *current*
        registry: instances registered after install (autoscale
        spawn-ups) receive the rules' knobs too, so a declared
        admission floor keeps holding fleet-wide.  Idempotent —
        ``ctx.set`` no-ops on values already held."""
        ctx = ControlContext(self)
        for rule in self.rules.agent_rules:
            ctx._apply_agent_rule(rule)

    def attach_graph(self, graph) -> None:
        """Register the serving topology's workflow graph as a
        control-plane object: policies and intent programs see the same
        DAG the scheduler derives critical-path priorities from."""
        self.graph = graph

    # -- loop ------------------------------------------------------------------
    def start(self) -> None:
        if self._running:
            return
        self._running = True
        self.loop.call_after(self.interval, self._tick)

    def stop(self) -> None:
        self._running = False

    def _tick(self) -> None:
        if not self._running:
            return
        now = self.loop.now()
        self.poller.poll(now)
        ctx = ControlContext(self)
        for p in self.policies:
            p.on_tick(ctx)
        self.ticks += 1
        self.loop.call_after(self.interval, self._tick)

    # -- push events from agents ------------------------------------------------
    def event(self, kind: str, **kw) -> None:
        ctx = ControlContext(self)
        for p in self.policies:
            p.on_event(ctx, kind, **kw)

    # -- event tier (MetricBus threshold subscriptions) --------------------------
    def watch_metric(self, metric: str, above: Optional[float] = None,
                     below: Optional[float] = None, cooldown: float = 0.0,
                     edge: bool = True):
        """Subscribe the control loop to a metric threshold: when the
        data plane pushes a sample into the region, policies get an
        ``on_event(ctx, "metric", ...)`` *between* interval polls.
        Requires a MetricBus; returns the subscription handle."""
        if self.bus is None:
            raise RuntimeError("controller has no MetricBus attached")
        return self.bus.subscribe(
            metric, above=above, below=below, cooldown=cooldown, edge=edge,
            fn=lambda name, value, t: self._defer(
                lambda: self.event("metric", name=name, value=value, t=t)))

    def _defer(self, fn: Callable[[], None]) -> None:
        """Run a control action on the next loop turn.  Bus callbacks
        arrive *inside* data-plane writes (mid engine-step); deferring
        keeps control actions from mutating scheduler state re-entrantly."""
        self.loop.call_after(0.0, fn)

    def fire_on_event(self, run: Callable[[ControlContext], None],
                      reason: str = "") -> None:
        """Event-path entry used by intent programs: on-demand poll for a
        fresh window, then run ``run(ctx)`` — deferred one loop turn."""
        def _go():
            self.poller.poll(self.loop.now())
            self.events_handled += 1
            if reason:
                self._log("event", "bus", reason)
            run(ControlContext(self))
        self._defer(_go)

    # -- audit ---------------------------------------------------------------------
    def attach_recorder(self, recorder) -> None:
        """Forward every audit-log action to a FlightRecorder (which
        keeps its own bound and the causal-annotation machinery)."""
        self.recorder = recorder

    def _log(self, kind: str, target: str, detail: str) -> None:
        t = self.loop.now()
        a = Action(t, kind, target, detail)
        self.actions_total += 1
        self.actions.append(a)
        if len(self.actions) > self.actions_cap:
            # ring behavior: drop the oldest half in one O(n) move so a
            # long fleet sim cannot leak audit memory
            del self.actions[: self.actions_cap // 2]
        if self.recorder is not None:
            self.recorder.record_action(a)
        if self.collector is not None:
            self.collector.gauge("controller.actions_retained",
                                 len(self.actions), t)

    def action_log(self, kind: Optional[str] = None) -> list[Action]:
        return [a for a in self.actions if kind is None or a.kind == kind]
