"""Slot-level cache surgery: extract/insert one sequence's decode state
from/into the engine's batched cache pytree.

The batch axis position differs per leaf (ring KV is (layers, B, S, H, d),
``pos`` is (B,), nested segments add leading stack dims), so we locate it
once per model config by diffing the shapes of batch=1 vs batch=2 cache
skeletons.  These two functions are the entire mechanical basis of
KV-cache migration (serving/kv_transfer.py) — the paper's "transfer state
during hand-off" control-surface example.
"""
from __future__ import annotations

import functools

import jax

from repro import models
from repro.configs.base import ModelConfig


@functools.lru_cache(maxsize=32)
def _batch_axes_cached(cfg: ModelConfig, max_context: int, enc_len: int):
    c1 = jax.eval_shape(lambda: models.init_cache(cfg, 1, max_context,
                                                  enc_len))
    c2 = jax.eval_shape(lambda: models.init_cache(cfg, 2, max_context,
                                                  enc_len))

    def axis(a, b):
        for i, (x, y) in enumerate(zip(a.shape, b.shape)):
            if x != y:
                return i
        raise ValueError(f"no batch axis: {a.shape}")

    l1, treedef = jax.tree.flatten(c1)
    l2, _ = jax.tree.flatten(c2)
    return treedef, tuple(axis(a, b) for a, b in zip(l1, l2))


def batch_axes(cfg: ModelConfig, max_context: int, enc_len: int = 0):
    return _batch_axes_cached(cfg, max_context, enc_len)


def cache_extract(cache, slot, axes_info):
    """Pull slot ``slot`` out as a batch=1 cache pytree."""
    treedef, axes = axes_info
    leaves = treedef.flatten_up_to(cache)
    out = [jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=ax)
           for leaf, ax in zip(leaves, axes)]
    return jax.tree.unflatten(treedef, out)


def cache_insert(cache, sub, slot, axes_info):
    """Write a batch=1 cache pytree into slot ``slot``."""
    treedef, axes = axes_info
    leaves = treedef.flatten_up_to(cache)
    subs = treedef.flatten_up_to(sub)
    out = [jax.lax.dynamic_update_slice_in_dim(leaf, s.astype(leaf.dtype),
                                               slot, axis=ax)
           for leaf, s, ax in zip(leaves, subs, axes)]
    return jax.tree.unflatten(treedef, out)


def cache_nbytes(cache) -> int:
    return int(sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(cache)))
