"""Token sampling: greedy / temperature / top-k, batched and jittable."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("top_k",))
def sample(logits: jax.Array, key: jax.Array, temperature: float = 0.0,
           top_k: int = 0) -> jax.Array:
    """logits: (B, V) -> (B,) int32."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def _sampled() -> jax.Array:
        lg = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
        if top_k > 0 and top_k < lg.shape[-1]:
            vals, idx = jax.lax.top_k(lg, top_k)
            draw = jax.random.categorical(key, vals, axis=-1)
            return jnp.take_along_axis(idx, draw[:, None], axis=1)[:, 0]
        return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)

    # temperature is traced (engines retune it via set()): select, don't
    # branch in python
    return jnp.where(temperature <= 0.0, greedy,
                     _sampled().astype(jnp.int32))
