"""Cross-instance KV/state transfer — the paper's "rich control surface"
example (§3.1) and the Fig-7 mechanism.

Two timing modes:

* **reactive** — the transfer starts when called (i.e. after the request
  already arrived at the destination); the request's prefill is gated on
  delivery, so the transfer latency lands on the critical path.
* **proactive ("hint")** — the controller starts the transfer while the
  *upstream* agent is still generating; by the time the request arrives
  the state is (usually) resident, and the hand-off costs ~nothing.

The byte count comes from the architecture's cost model
(``CostModel.kv_transfer_bytes``): SWA archs move at most ``window``
tokens of KV, SSM/hybrid archs move O(1) recurrent state — the
controller's migrate-or-not threshold consumes exactly this number.

The same manager moves *real* engine state when given Engine instances
(extract_state/inject_state pytrees); in the sim it moves byte counts.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.sim.clock import EventLoop
from repro.sim.network import Link


@dataclass
class SessionRecord:
    session: str
    instance: str                  # where the KV currently lives
    context_len: int = 0           # accumulated session context (tokens)
    inflight_to: Optional[str] = None
    ready_at: float = -1.0         # when the inflight copy lands


class SessionDirectory:
    """Controller-visible map: session → (home instance, context size)."""

    def __init__(self):
        self.records: dict[str, SessionRecord] = {}

    def ensure(self, session: str, instance: str) -> SessionRecord:
        rec = self.records.get(session)
        if rec is None:
            rec = self.records[session] = SessionRecord(session, instance)
        return rec

    def get(self, session: str) -> Optional[SessionRecord]:
        return self.records.get(session)

    def grow(self, session: str, tokens: int) -> None:
        rec = self.records.get(session)
        if rec is not None:
            rec.context_len += tokens

    def resident(self, session: str, instance: str, now: float) -> bool:
        rec = self.records.get(session)
        if rec is None:
            return False
        if rec.instance == instance:
            return True
        return (rec.inflight_to == instance and 0 <= rec.ready_at <= now)


class KVTransferManager:
    """Owns the inter-instance links and the transfer state machine."""

    def __init__(self, loop: EventLoop, directory: SessionDirectory,
                 bytes_fn: Callable[[int], int],
                 bandwidth: float = 12.5e9, latency: float = 1.0e-3,
                 collector=None, name: str = "kvx"):
        self.loop = loop
        self.dir = directory
        self.bytes_fn = bytes_fn          # context_len -> bytes to move
        self.collector = collector
        self.name = name
        self.bandwidth = bandwidth
        self.latency = latency
        self._links: dict[tuple[str, str], Link] = {}
        self.transfers = 0
        self.bytes_moved = 0.0
        self.payload_movers: dict[tuple[str, str], Callable] = {}

    def link(self, src: str, dst: str) -> Link:
        key = (src, dst)
        if key not in self._links:
            self._links[key] = Link(self.loop, self.bandwidth, self.latency,
                                    name=f"{self.name}:{src}->{dst}")
        return self._links[key]

    def attach_engines(self, agents: dict[str, object]) -> None:
        """Real-engine mode: wire extract/inject around the timed link."""
        self._agents = agents

    # -- the control-plane verb ------------------------------------------------
    def transfer(self, session: str, src: str, dst: str,
                 proactive: bool = False,
                 on_done: Optional[Callable[[], None]] = None) -> float:
        """Move a session's KV state src → dst; returns delivery time."""
        rec = self.dir.ensure(session, src)
        if rec.instance == dst:
            if on_done:
                on_done()
            return self.loop.now()
        nbytes = self.bytes_fn(rec.context_len)
        rec.inflight_to = dst
        rec.ready_at = float("inf")
        link = self.link(src, dst)

        def _deliver():
            rec.instance = dst
            rec.inflight_to = None
            if on_done:
                on_done()

        t = link.transfer(nbytes, _deliver)
        rec.ready_at = t
        self.transfers += 1
        self.bytes_moved += nbytes
        if self.collector is not None:
            self.collector.counter(f"{self.name}.transfer_bytes", nbytes,
                                   self.loop.now())
            self.collector.counter(f"{self.name}.transfers", 1,
                                   self.loop.now())
        return t

    # -- query used by the destination agent ------------------------------------
    def wait_time(self, session: str, instance: str) -> float:
        """Seconds until the session KV is resident at ``instance``;
        0 if resident, +inf if nothing is on the way."""
        rec = self.dir.get(session)
        now = self.loop.now()
        if rec is None:
            return float("inf")
        if rec.instance == instance:
            return 0.0
        if rec.inflight_to == instance:
            return max(0.0, rec.ready_at - now)
        return float("inf")
