"""Cross-instance KV/state transfer — the paper's "rich control surface"
example (§3.1) and the Fig-7 mechanism.

Two timing modes:

* **reactive** — the transfer starts when called (i.e. after the request
  already arrived at the destination); the request's prefill is gated on
  delivery, so the transfer latency lands on the critical path.
* **proactive ("hint")** — the controller starts the transfer while the
  *upstream* agent is still generating; by the time the request arrives
  the state is (usually) resident, and the hand-off costs ~nothing.

The byte count comes from the architecture's cost model
(``CostModel.kv_transfer_bytes``): SWA archs move at most ``window``
tokens of KV, SSM/hybrid archs move O(1) recurrent state — the
controller's migrate-or-not threshold consumes exactly this number.

The same manager moves *real* engine state when given Engine instances
(extract_state/inject_state pytrees); in the sim it moves byte counts.

On top of session migration, the manager owns the disaggregation
plane's **prefill→decode handoff pipeline**: a per-request transfer that
is *chunk-streamed* — as prefill advances on the prefill-role engine,
the KV computed so far is pushed to the paired decode engine
(``handoff_progress``), so by prefill completion only the tail chunk
remains in flight (``finish_handoff``) and the handoff latency exposed
on the critical path is ``CostModel.handoff_time`` with the prefill
duration as overlap, not the full transfer.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

from repro.sim.clock import EventLoop
from repro.sim.network import Link


@dataclass
class SessionRecord:
    session: str
    instance: str                  # where the KV currently lives
    context_len: int = 0           # accumulated session context (tokens)
    inflight_to: Optional[str] = None
    ready_at: float = -1.0         # when the inflight copy lands


class SessionDirectory:
    """Controller-visible map: session → (home instance, context size)."""

    def __init__(self):
        self.records: dict[str, SessionRecord] = {}

    def ensure(self, session: str, instance: str) -> SessionRecord:
        rec = self.records.get(session)
        if rec is None:
            rec = self.records[session] = SessionRecord(session, instance)
        return rec

    def get(self, session: str) -> Optional[SessionRecord]:
        return self.records.get(session)

    def grow(self, session: str, tokens: int) -> None:
        rec = self.records.get(session)
        if rec is not None:
            rec.context_len += tokens

    def resident(self, session: str, instance: str, now: float) -> bool:
        rec = self.records.get(session)
        if rec is None:
            return False
        if rec.instance == instance:
            return True
        return (rec.inflight_to == instance and 0 <= rec.ready_at <= now)


@dataclass
class HandoffRecord:
    """One in-flight prefill→decode handoff (per request, not session)."""

    req_id: str
    src: str
    dst: str
    streamed_tokens: int = 0       # prefix whose KV has been pushed
    ready_at: float = -1.0         # delivery time of the last chunk sent
    finished: bool = False         # finish_handoff called (tail in flight)


class KVTransferManager:
    """Owns the inter-instance links and the transfer state machine."""

    def __init__(self, loop: EventLoop, directory: SessionDirectory,
                 bytes_fn: Callable[[int], int],
                 bandwidth: float = 12.5e9, latency: float = 1.0e-3,
                 collector=None, name: str = "kvx"):
        self.loop = loop
        self.dir = directory
        self.bytes_fn = bytes_fn          # context_len -> bytes to move
        self.collector = collector
        self.name = name
        self.bandwidth = bandwidth
        self.latency = latency
        self._links: dict[tuple[str, str], Link] = {}
        self.tracer = None                # tracing plane | None
        self.transfers = 0
        self.bytes_moved = 0.0
        self.payload_movers: dict[tuple[str, str], Callable] = {}
        self.handoff_records: dict[str, HandoffRecord] = {}
        self.handoffs = 0
        self.handoff_bytes = 0.0

    def link(self, src: str, dst: str) -> Link:
        key = (src, dst)
        if key not in self._links:
            self._links[key] = Link(self.loop, self.bandwidth, self.latency,
                                    name=f"{self.name}:{src}->{dst}")
        return self._links[key]

    def attach_engines(self, agents: dict[str, object]) -> None:
        """Real-engine mode: wire extract/inject around the timed link."""
        self._agents = agents

    # -- the control-plane verb ------------------------------------------------
    def transfer(self, session: str, src: str, dst: str,
                 proactive: bool = False,
                 on_done: Optional[Callable[[], None]] = None) -> float:
        """Move a session's KV state src → dst; returns delivery time."""
        rec = self.dir.ensure(session, src)
        if rec.instance == dst:
            if on_done:
                on_done()
            return self.loop.now()
        nbytes = self.bytes_fn(rec.context_len)
        rec.inflight_to = dst
        rec.ready_at = float("inf")
        link = self.link(src, dst)

        def _deliver():
            rec.instance = dst
            rec.inflight_to = None
            if on_done:
                on_done()

        t = link.transfer(nbytes, _deliver)
        rec.ready_at = t
        self.transfers += 1
        self.bytes_moved += nbytes
        if self.collector is not None:
            self.collector.counter(f"{self.name}.transfer_bytes", nbytes,
                                   self.loop.now())
            self.collector.counter(f"{self.name}.transfers", 1,
                                   self.loop.now())
        return t

    # -- prefill→decode handoff pipeline (disaggregation plane) -----------------
    def start_handoff(self, req_id: str, src: str, dst: str) -> HandoffRecord:
        """Open a handoff session for one request.  Called when the
        router pre-pins the decode pair — *before* prefill produces its
        first token — so ``handoff_progress`` chunks can start streaming
        while the prompt is still being prefilled."""
        rec = HandoffRecord(req_id, src, dst)
        self.handoff_records[req_id] = rec
        return rec

    def handoff_progress(self, req_id: str, prefilled_tokens: int) -> None:
        """Prefill advanced to ``prefilled_tokens``: stream the newly
        computed KV chunk now, overlapping the remaining prefill.  Bytes
        are incremental through ``bytes_fn`` so windowed/SSM archs whose
        movable state saturates are not over-charged per chunk."""
        rec = self.handoff_records.get(req_id)
        if rec is None or rec.finished:
            return
        if prefilled_tokens <= rec.streamed_tokens:
            return
        delta = self.bytes_fn(prefilled_tokens) - self.bytes_fn(
            rec.streamed_tokens)
        rec.streamed_tokens = prefilled_tokens
        if delta <= 0:
            return
        rec.ready_at = self.link(rec.src, rec.dst).transfer(
            delta, lambda: None)
        self._count_handoff_bytes(delta)
        self._trace_chunk(rec, delta, rec.ready_at, tail=False)

    def finish_handoff(self, req_id: str, src: str, dst: str,
                       total_tokens: int,
                       on_ready: Callable[[], None]) -> float:
        """Prefill complete: stream the remaining tail and schedule
        ``on_ready`` at final delivery.  If the record was pinned to a
        different destination (its decode engine changed role while
        chunks were in flight), the already-streamed prefix is wasted
        and the full state restreams to the new target."""
        rec = self.handoff_records.get(req_id)
        if rec is None:
            rec = self.start_handoff(req_id, src, dst)
        if rec.dst != dst or rec.src != src:
            rec.src, rec.dst = src, dst
            rec.streamed_tokens = 0
            rec.ready_at = -1.0
        rec.finished = True
        tail = self.bytes_fn(total_tokens) - self.bytes_fn(
            rec.streamed_tokens)
        rec.streamed_tokens = max(rec.streamed_tokens, total_tokens)
        if tail > 0:
            t = self.link(src, dst).transfer(tail, on_ready)
            self._count_handoff_bytes(tail)
            self._trace_chunk(rec, tail, t, tail=True)
        else:
            # everything already streamed: residency lands with the last
            # in-flight chunk (or immediately, if it has already landed)
            t = max(self.loop.now(), rec.ready_at)
            self.loop.call_at(t, on_ready)
        rec.ready_at = t
        self.handoffs += 1
        if self.collector is not None:
            self.collector.counter(f"{self.name}.handoffs", 1,
                                   self.loop.now())
        return t

    def handoff_wait(self, req_id: str, instance: str) -> float:
        """Seconds until a handed-off request's KV is resident at
        ``instance``: 0 when no handoff is in flight (locally-prefilled
        state is resident by construction), +inf while prefill is still
        producing state or the transfer targets another instance."""
        rec = self.handoff_records.get(req_id)
        if rec is None:
            return 0.0
        if rec.dst != instance or not rec.finished:
            return float("inf")
        return max(0.0, rec.ready_at - self.loop.now())

    def end_handoff(self, req_id: str) -> None:
        """Drop a handoff record (delivered and admitted, or aborted)."""
        self.handoff_records.pop(req_id, None)

    def _trace_chunk(self, rec: HandoffRecord, nbytes: float,
                     ready_at: float, tail: bool) -> None:
        """Record one streamed KV chunk as a span on the kv-fabric
        track: [send, delivery].  Gated on an *existing* sample decision
        (``decided``) — the fabric keys handoffs by req_id and must not
        originate fresh decisions for requests tracing keyed by task."""
        tr = self.tracer
        if tr is None or not tr.decided(rec.req_id):
            return
        tr.record("kv_chunk_tail" if tail else "kv_chunk", rec.req_id,
                  self.loop.now(), ready_at, cat="kv",
                  src=rec.src, dst=rec.dst, bytes=int(nbytes),
                  req_id=rec.req_id)

    def _count_handoff_bytes(self, nbytes: float) -> None:
        self.handoff_bytes += nbytes
        self.bytes_moved += nbytes
        if self.collector is not None:
            self.collector.counter(f"{self.name}.handoff_bytes", nbytes,
                                   self.loop.now())

    # -- query used by the destination agent ------------------------------------
    def wait_time(self, session: str, instance: str) -> float:
        """Seconds until the session KV is resident at ``instance``;
        0 if resident, +inf if nothing is on the way."""
        rec = self.dir.get(session)
        now = self.loop.now()
        if rec is None:
            return float("inf")
        if rec.instance == instance:
            return 0.0
        if rec.inflight_to == instance:
            return max(0.0, rec.ready_at - now)
        return float("inf")
