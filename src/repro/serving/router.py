"""Multi-instance router — the request-level control point.

Sits between a channel and a group of agent instances.  Routing order:

1. an installed **request-level rule** (controller's ``ctx.route``) wins;
2. otherwise the router's own fallback policy applies: `static` session
   hash, `least_loaded`, `cache_aware` — score instances by the
   estimated prefix-cache hit (via the controller-visible
   ``CacheDirectory``) and break ties by load, so fan-out requests land
   where their shared prefix is already resident — `stage_aware` —
   Aragog-style per-stage model tiering: instances register with a
   model-size ``tier`` label, messages carry the desired tier (stamped
   from the issuing stage's ``model_tier`` knob), and the router keeps
   the call on a matching-tier instance (least-loaded within the tier,
   full least-loaded fallback when no instance of that tier exists) —
   or `disagg` — disaggregation-aware: pick the prefill-capable engine
   with the shallowest prefill queue, and when that engine is
   prefill-role, *pre-pin* the paired decode engine (lowest decode slot
   utilization) so the fabric can open the KV handoff before the first
   token exists (``pair_for`` hands the pin to the DisaggPool).

Messages delivered while the fleet is empty are held (with the blocked
ones) and re-dispatched on the next ``add_instance`` — the
``<router>.held_count`` gauge makes that window observable.

With a ``TenantDirectory`` attached, every message is metered through
its tenant's token bucket *ahead of* the rule/policy pick: messages of
a throttled (or paused) tenant are **held, never dropped**, and
re-released when the bucket refills or a ``rate``/``paused`` knob moves
— the ``<router>.throttled_count`` gauge tracks the held set, and the
directory publishes the per-tenant ``throttle_rate`` rollups.

Session affinity matters because the tester instances hold per-session
KV state; the controller's LoadBalancePolicy re-pins sessions and pairs
each re-pin with a KV transfer (serving/kv_transfer.py).

Blocked messages (request rules with ``block=True``) are held and
re-checked whenever the rule table version changes — and whenever an
instance is removed, so held traffic never targets a dead instance.
"""
from __future__ import annotations

import zlib
from typing import Callable, Optional

from repro.core.dataplane import Endpoint
from repro.core.knobs import ControlSurface, KnobSpec
from repro.core.rules import RuleTable
from repro.core.types import Message
from repro.sim.clock import EventLoop


def pick_decode_engine(engines: dict, exclude: Optional[str] = None):
    """Shared decode-placement criterion for the disaggregation plane:
    the non-prefill engine minimizing (decode_slot_util, load).  Used
    by both the router's ``disagg`` pre-pin and the DisaggPool's
    reactive handoff/re-home paths, so the pinned pair and the fallback
    can never disagree.  ``exclude`` is soft: it falls back to the
    excluded engine when nothing else can decode.  None when no engine
    is decode-capable."""
    cand = [(n, e) for n, e in engines.items()
            if getattr(e, "role", "unified") != "prefill" and n != exclude]
    if not cand:
        cand = [(n, e) for n, e in engines.items()
                if getattr(e, "role", "unified") != "prefill"]
    if not cand:
        return None
    return min(cand, key=lambda ne: (ne[1].scheduler.decode_slot_util,
                                     ne[1].load()))[0]


class Router(ControlSurface):
    kind = "router"
    CAPABILITIES = ("route",)
    KNOB_SPECS = (
        KnobSpec("policy", kind="str",
                 choices=("static", "least_loaded", "cache_aware",
                          "stage_aware", "disagg"),
                 doc="fallback routing policy when no rule matches"),
    )

    def __init__(self, loop: EventLoop, name: str = "router",
                 rules: Optional[RuleTable] = None, policy: str = "static",
                 collector=None, cache_dir=None,
                 prefix_fn: Optional[Callable[[Message], object]] = None,
                 tenants=None):
        self.loop = loop
        self.name = name
        self.rules = rules or RuleTable()
        self.policy = policy
        self.collector = collector
        self.cache_dir = cache_dir               # CacheDirectory | None
        self.prefix_fn = prefix_fn               # Message -> prefix source
        self.tenants = tenants                   # TenantDirectory | None
        self._throttled: list[Message] = []      # held by the meter
        self._throttle_seen: set[str] = set()    # counted-once msg ids
        self._held_tenants: dict[str, int] = {}  # tenant -> held count
        self._metered: set[str] = set()          # passed-the-bucket ids
        self._pump_at = float("inf")             # pending refill-pump time
        self.tracer = None                       # tracing plane | None
        self._hold_t0: dict[str, float] = {}     # msg_id -> first-hold time
        if tenants is not None:
            # rate/burst/paused knob moves can unblock held traffic NOW
            tenants.subscribe_release(self._pump_throttled)
        self.instances: dict[str, Endpoint] = {}
        self._loads: dict[str, object] = {}      # name -> load() callable
        self._tiers: dict[str, str] = {}         # name -> model-size tier
        self._engines: dict[str, object] = {}    # name -> engine (disagg)
        self._session_pin: dict[str, str] = {}   # fallback stickiness
        self._held: list[Message] = []
        self._pairs: dict[str, tuple[str, str]] = {}  # task -> (src, dst)
        self._rules_seen = -1
        self.routed: dict[str, int] = {}
        self.on_dispatch = None                  # (msg, instance) hook,
                                                 # fired at actual dispatch
        self.cache_routed = 0                    # picks won on prefix score
        self.tier_routed = 0                     # picks won on tier match
        self.disagg_routed = 0                   # picks won on role/depth

    # -- wiring ----------------------------------------------------------------
    def add_instance(self, agent, load_fn=None, tier: Optional[str] = None,
                     engine=None) -> None:
        self.instances[agent.name] = agent
        self._loads[agent.name] = load_fn or getattr(agent, "load", None)
        if tier is not None:
            self._tiers[agent.name] = tier
        if engine is not None:
            self._engines[agent.name] = engine   # live role/depth source
        self.routed.setdefault(agent.name, 0)
        # messages held while the fleet was empty (remove-last-then-add)
        # get their first chance at the new instance here
        self._pump()

    def remove_instance(self, name: str) -> None:
        self.instances.pop(name, None)
        self._loads.pop(name, None)
        self._tiers.pop(name, None)
        self._engines.pop(name, None)
        # stale fallback pins would re-route sessions to the dead name
        self._session_pin = {s: i for s, i in self._session_pin.items()
                             if i != name}
        # held/blocked messages re-evaluate against the surviving set
        # (their block rule may have been removed without a new deliver)
        if self.instances:
            self._pump()
        else:
            self._gauge_held()

    # -- set/reset shim: derived from ControlSurface -------------------------
    def card_metrics(self) -> tuple:
        return tuple(f"routed.{n}" for n in self.instances)

    # -- routing ------------------------------------------------------------------
    def _load_of(self, name: str) -> float:
        fn = self._loads.get(name)
        return fn() if callable(fn) else 0.0

    def _cache_pick(self, names: list[str], msg: Optional[Message]):
        """Best estimated prefix hit, ties broken by load; None when the
        directory has no signal (caller falls back to load)."""
        if self.cache_dir is None or self.prefix_fn is None or msg is None:
            return None
        source = self.prefix_fn(msg)
        if source is None:
            return None
        scores = {n: self.cache_dir.estimate_hit(source, n) for n in names}
        best = max(scores.values())
        if best <= 0:
            return None
        top = [n for n in names if scores[n] == best]
        self.cache_routed += 1
        return min(top, key=self._load_of)

    def _role_of(self, name: str) -> str:
        eng = self._engines.get(name)
        if eng is None:
            return "unified"
        try:
            return eng.get_param("role")
        except (KeyError, AttributeError):
            return "unified"

    def _prefill_depth(self, name: str) -> float:
        eng = self._engines.get(name)
        if eng is None:
            return self._load_of(name)
        return float(eng.scheduler.prefill_queue_tokens)

    def _disagg_pick(self, names: list[str], msg: Optional[Message]):
        """Shallowest prefill queue among prefill-capable engines; when
        the pick is a dedicated prefill engine, pre-pin its decode pair
        (lowest decode slot utilization) so the handoff can start
        streaming before the first token.  None when no engine can
        prefill (caller falls back to plain least-loaded)."""
        pre = [n for n in names if self._role_of(n) != "decode"]
        if not pre:
            return None
        src = min(pre, key=lambda n: (self._prefill_depth(n),
                                      self._load_of(n)))
        if self._role_of(src) == "prefill":
            dst = pick_decode_engine(
                {n: self._engines[n] for n in names if n in self._engines},
                exclude=src)
            if dst is not None and msg is not None and msg.task_id:
                self._pairs[msg.task_id] = (src, dst)
                # pins are consumed by pair_for right after deliver;
                # bound the table so a caller that never consumes them
                # (e.g. this policy on a plain router) cannot leak
                while len(self._pairs) > 512:
                    self._pairs.pop(next(iter(self._pairs)))
        self.disagg_routed += 1
        return src

    def pair_for(self, task_id: str):
        """Consume the (prefill, decode) pre-pin made for a task by the
        ``disagg`` policy; None when the pick decodes in place."""
        return self._pairs.pop(task_id, None)

    def _tier_pick(self, names: list[str], msg: Optional[Message]):
        """Least-loaded instance of the tier the message asks for; None
        when the message carries no tier or no instance matches (caller
        falls back to plain least-loaded)."""
        want = (msg.payload or {}).get("tier") if msg is not None else None
        if want is None:
            return None
        match = [n for n in names if self._tiers.get(n) == want]
        if not match:
            return None
        self.tier_routed += 1
        return min(match, key=self._load_of)

    def _fallback(self, session: str, msg: Optional[Message] = None) -> str:
        names = sorted(self.instances)
        if not names:
            raise RuntimeError(f"{self.name}: no instances")
        if self.policy == "disagg":
            pick = self._disagg_pick(names, msg)
            if pick is not None:
                return pick
            return min(names, key=self._load_of)
        if self.policy == "stage_aware":
            pick = self._tier_pick(names, msg)
            if pick is not None:
                return pick
            return min(names, key=self._load_of)
        if self.policy == "cache_aware":
            pick = self._cache_pick(names, msg)
            if pick is not None:
                return pick
            return min(names, key=self._load_of)
        if self.policy == "least_loaded":
            return min(names, key=self._load_of)
        if session not in self._session_pin:
            h = zlib.crc32(session.encode())        # deterministic hash
            self._session_pin[session] = names[h % len(names)]
        return self._session_pin[session]

    def pick(self, msg: Message) -> str:
        ruled = self.rules.route_for(msg)
        if ruled is not None and ruled in self.instances:
            return ruled
        session = (msg.payload or {}).get("session") or msg.task_id or ""
        return self._fallback(session, msg)

    # -- tenancy meter (ahead of the rule/policy pick) -----------------------
    def _tenant_admit(self, msg: Message) -> bool:
        """Meter the message through its tenant's token bucket.  False =
        held: the message sits in ``_throttled`` until the bucket
        refills (timer) or a tenant knob moves (directory release
        hook).  Held messages are never dropped."""
        cost = max(msg.tokens, 1)
        now = self.loop.now()
        was_held = msg.msg_id in self._throttle_seen
        # a tenant's older held messages drain first: a fresh arrival
        # may not steal the refill out from under a large held message
        # (which would starve it behind a stream of small ones)
        jumps_queue = (not was_held
                       and self._held_tenants.get(msg.tenant, 0) > 0)
        if not jumps_queue and self.tenants.try_take(msg.tenant, cost, now):
            if was_held:
                self._throttle_seen.discard(msg.msg_id)
                self._held_tenants[msg.tenant] -= 1
                self._trace_hold(msg, now)
            self._metered.add(msg.msg_id)
            self.tenants.note_admitted(msg.tenant, cost, now)
            return True
        if not was_held:
            # count each message once, not once per re-check
            self._throttle_seen.add(msg.msg_id)
            self._held_tenants[msg.tenant] = (
                self._held_tenants.get(msg.tenant, 0) + 1)
            self.tenants.note_throttled(msg.tenant, now)
            if self.tracer is not None:
                self._hold_t0[msg.msg_id] = now
        self._throttled.append(msg)
        self._gauge_throttled()
        wait = self.tenants.time_until(msg.tenant, cost, now)
        if wait != float("inf"):
            # paused / zero-rate tenants have no refill horizon; their
            # release rides the directory's knob-change hook instead.
            # ONE pending pump per router: a flood of held messages must
            # not schedule a timer (and a full re-scan) per message
            at = now + max(wait, 1e-3)
            if at < self._pump_at - 1e-12:
                self._pump_at = at
                self.loop.call_after(at - now, self._timed_pump)
        return False

    def _timed_pump(self) -> None:
        self._pump_at = float("inf")
        self._pump_throttled()

    def _trace_hold(self, msg: Message, now: float) -> None:
        """A held message just cleared the meter: record its
        throttle-hold as a standalone segment span.  The span has no
        parent yet — when the request reaches an engine and gets a root
        span, ``trace_pre`` re-parents it under that root (spans are
        mutable); the hold window tiles the gap between pool arrival
        and engine submission."""
        t0 = self._hold_t0.pop(msg.msg_id, None)
        if self.tracer is None or t0 is None:
            return
        tid = msg.task_id or msg.msg_id
        if not self.tracer.decide(tid, tenant=msg.tenant):
            return
        sp = self.tracer.record("throttle_hold", tid, t0, now,
                                cat="segment", router=self.name,
                                tenant=msg.tenant)
        req = (msg.payload or {}).get("request")
        if req is not None:
            req.meta.setdefault("trace_pre", []).append(sp)

    def exempt(self, msg_id: str) -> None:
        """Mark a message as already metered, so delivering it bypasses
        the tenant bucket — for traffic the fabric re-routes internally
        (role-flip bounces), which was charged on first admission."""
        self._metered.add(msg_id)

    def _pump_throttled(self) -> None:
        throttled, self._throttled = self._throttled, []
        blocked: set[str] = set()
        for msg in throttled:
            if msg.tenant in blocked:
                # this tenant's bucket already refused a message this
                # round: keep FIFO order, skip the redundant re-meter
                self._throttled.append(msg)
                continue
            before = len(self._throttled)
            self.deliver(msg)
            if len(self._throttled) > before:
                blocked.add(msg.tenant)
        self._gauge_throttled()

    @property
    def throttled_count(self) -> int:
        return len(self._throttled)

    def _gauge_throttled(self) -> None:
        if self.collector is not None:
            self.collector.gauge(f"{self.name}.throttled_count",
                                 len(self._throttled), self.loop.now())

    def deliver(self, msg: Message) -> None:
        if self._rules_seen != self.rules.version:
            self._rules_seen = self.rules.version
            self._pump()
        if (self.tenants is not None and msg.msg_id not in self._metered
                and not self._tenant_admit(msg)):
            return
        if self.rules.blocked(msg) or not self.instances:
            # blocked by rule, or the fleet is momentarily empty
            # (remove-last-then-add): hold until something can take it
            # (already metered — a later re-check must not charge again)
            self._held.append(msg)
            self._gauge_held()
            return
        self._metered.discard(msg.msg_id)
        inst = self.pick(msg)
        self.routed[inst] += 1
        if self.collector is not None:
            self.collector.counter(f"{self.name}.routed.{inst}", 1,
                                   self.loop.now())
        self.instances[inst].deliver(msg)
        if self.on_dispatch is not None:
            # post-deliver so callers observe the same synchronous order
            # as a direct deliver (engine submitted, then the hook)
            self.on_dispatch(msg, inst)

    def _pump(self) -> None:
        held, self._held = self._held, []
        self._gauge_held()
        for msg in held:
            self.deliver(msg)

    @property
    def held_count(self) -> int:
        return len(self._held)

    def _gauge_held(self) -> None:
        if self.collector is not None:
            self.collector.gauge(f"{self.name}.held_count",
                                 len(self._held), self.loop.now())
