"""Multi-instance router — the request-level control point.

Sits between a channel and a group of agent instances.  Routing order:

1. an installed **request-level rule** (controller's ``ctx.route``) wins;
2. otherwise the router's own fallback policy applies: `static` session
   hash, `least_loaded`, `cache_aware` — score instances by the
   estimated prefix-cache hit (via the controller-visible
   ``CacheDirectory``) and break ties by load, so fan-out requests land
   where their shared prefix is already resident — or `stage_aware` —
   Aragog-style per-stage model tiering: instances register with a
   model-size ``tier`` label, messages carry the desired tier (stamped
   from the issuing stage's ``model_tier`` knob), and the router keeps
   the call on a matching-tier instance (least-loaded within the tier,
   full least-loaded fallback when no instance of that tier exists).

Session affinity matters because the tester instances hold per-session
KV state; the controller's LoadBalancePolicy re-pins sessions and pairs
each re-pin with a KV transfer (serving/kv_transfer.py).

Blocked messages (request rules with ``block=True``) are held and
re-checked whenever the rule table version changes — and whenever an
instance is removed, so held traffic never targets a dead instance.
"""
from __future__ import annotations

import zlib
from typing import Callable, Optional

from repro.core.dataplane import Endpoint
from repro.core.knobs import ControlSurface, KnobSpec
from repro.core.rules import RuleTable
from repro.core.types import Message
from repro.sim.clock import EventLoop


class Router(ControlSurface):
    kind = "router"
    CAPABILITIES = ("route",)
    KNOB_SPECS = (
        KnobSpec("policy", kind="str",
                 choices=("static", "least_loaded", "cache_aware",
                          "stage_aware"),
                 doc="fallback routing policy when no rule matches"),
    )

    def __init__(self, loop: EventLoop, name: str = "router",
                 rules: Optional[RuleTable] = None, policy: str = "static",
                 collector=None, cache_dir=None,
                 prefix_fn: Optional[Callable[[Message], object]] = None):
        self.loop = loop
        self.name = name
        self.rules = rules or RuleTable()
        self.policy = policy
        self.collector = collector
        self.cache_dir = cache_dir               # CacheDirectory | None
        self.prefix_fn = prefix_fn               # Message -> prefix source
        self.instances: dict[str, Endpoint] = {}
        self._loads: dict[str, object] = {}      # name -> load() callable
        self._tiers: dict[str, str] = {}         # name -> model-size tier
        self._session_pin: dict[str, str] = {}   # fallback stickiness
        self._held: list[Message] = []
        self._rules_seen = -1
        self.routed: dict[str, int] = {}
        self.cache_routed = 0                    # picks won on prefix score
        self.tier_routed = 0                     # picks won on tier match

    # -- wiring ----------------------------------------------------------------
    def add_instance(self, agent, load_fn=None,
                     tier: Optional[str] = None) -> None:
        self.instances[agent.name] = agent
        self._loads[agent.name] = load_fn or getattr(agent, "load", None)
        if tier is not None:
            self._tiers[agent.name] = tier
        self.routed.setdefault(agent.name, 0)
        # messages held while the fleet was empty (remove-last-then-add)
        # get their first chance at the new instance here
        self._pump()

    def remove_instance(self, name: str) -> None:
        self.instances.pop(name, None)
        self._loads.pop(name, None)
        self._tiers.pop(name, None)
        # stale fallback pins would re-route sessions to the dead name
        self._session_pin = {s: i for s, i in self._session_pin.items()
                             if i != name}
        # held/blocked messages re-evaluate against the surviving set
        # (their block rule may have been removed without a new deliver)
        if self.instances:
            self._pump()

    # -- set/reset shim: derived from ControlSurface -------------------------
    def card_metrics(self) -> tuple:
        return tuple(f"routed.{n}" for n in self.instances)

    # -- routing ------------------------------------------------------------------
    def _load_of(self, name: str) -> float:
        fn = self._loads.get(name)
        return fn() if callable(fn) else 0.0

    def _cache_pick(self, names: list[str], msg: Optional[Message]):
        """Best estimated prefix hit, ties broken by load; None when the
        directory has no signal (caller falls back to load)."""
        if self.cache_dir is None or self.prefix_fn is None or msg is None:
            return None
        source = self.prefix_fn(msg)
        if source is None:
            return None
        scores = {n: self.cache_dir.estimate_hit(source, n) for n in names}
        best = max(scores.values())
        if best <= 0:
            return None
        top = [n for n in names if scores[n] == best]
        self.cache_routed += 1
        return min(top, key=self._load_of)

    def _tier_pick(self, names: list[str], msg: Optional[Message]):
        """Least-loaded instance of the tier the message asks for; None
        when the message carries no tier or no instance matches (caller
        falls back to plain least-loaded)."""
        want = (msg.payload or {}).get("tier") if msg is not None else None
        if want is None:
            return None
        match = [n for n in names if self._tiers.get(n) == want]
        if not match:
            return None
        self.tier_routed += 1
        return min(match, key=self._load_of)

    def _fallback(self, session: str, msg: Optional[Message] = None) -> str:
        names = sorted(self.instances)
        if not names:
            raise RuntimeError(f"{self.name}: no instances")
        if self.policy == "stage_aware":
            pick = self._tier_pick(names, msg)
            if pick is not None:
                return pick
            return min(names, key=self._load_of)
        if self.policy == "cache_aware":
            pick = self._cache_pick(names, msg)
            if pick is not None:
                return pick
            return min(names, key=self._load_of)
        if self.policy == "least_loaded":
            return min(names, key=self._load_of)
        if session not in self._session_pin:
            h = zlib.crc32(session.encode())        # deterministic hash
            self._session_pin[session] = names[h % len(names)]
        return self._session_pin[session]

    def pick(self, msg: Message) -> str:
        ruled = self.rules.route_for(msg)
        if ruled is not None and ruled in self.instances:
            return ruled
        session = (msg.payload or {}).get("session") or msg.task_id or ""
        return self._fallback(session, msg)

    def deliver(self, msg: Message) -> None:
        if self._rules_seen != self.rules.version:
            self._rules_seen = self.rules.version
            self._pump()
        if self.rules.blocked(msg):
            self._held.append(msg)
            return
        inst = self.pick(msg)
        self.routed[inst] += 1
        if self.collector is not None:
            self.collector.counter(f"{self.name}.routed.{inst}", 1,
                                   self.loop.now())
        self.instances[inst].deliver(msg)

    def _pump(self) -> None:
        held, self._held = self._held, []
        for msg in held:
            self.deliver(msg)
