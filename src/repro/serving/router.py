"""Multi-instance router — the request-level control point.

Sits between a channel and a group of agent instances.  Routing order:

1. an installed **request-level rule** (controller's ``ctx.route``) wins;
2. otherwise the router's own fallback policy (`static` session hash or
   `least_loaded`) applies.

Session affinity matters because the tester instances hold per-session
KV state; the controller's LoadBalancePolicy re-pins sessions and pairs
each re-pin with a KV transfer (serving/kv_transfer.py).

Blocked messages (request rules with ``block=True``) are held and
re-checked whenever the rule table version changes.
"""
from __future__ import annotations

import zlib
from typing import Optional

from repro.core.dataplane import Endpoint
from repro.core.knobs import ControlSurface, KnobSpec
from repro.core.rules import RuleTable
from repro.core.types import Message
from repro.sim.clock import EventLoop


class Router(ControlSurface):
    kind = "router"
    CAPABILITIES = ("route",)
    KNOB_SPECS = (
        KnobSpec("policy", kind="str", choices=("static", "least_loaded"),
                 doc="fallback routing policy when no rule matches"),
    )

    def __init__(self, loop: EventLoop, name: str = "router",
                 rules: Optional[RuleTable] = None, policy: str = "static",
                 collector=None):
        self.loop = loop
        self.name = name
        self.rules = rules or RuleTable()
        self.policy = policy
        self.collector = collector
        self.instances: dict[str, Endpoint] = {}
        self._loads: dict[str, object] = {}      # name -> load() callable
        self._session_pin: dict[str, str] = {}   # fallback stickiness
        self._held: list[Message] = []
        self._rules_seen = -1
        self.routed: dict[str, int] = {}

    # -- wiring ----------------------------------------------------------------
    def add_instance(self, agent, load_fn=None) -> None:
        self.instances[agent.name] = agent
        self._loads[agent.name] = load_fn or getattr(agent, "load", None)
        self.routed.setdefault(agent.name, 0)

    def remove_instance(self, name: str) -> None:
        self.instances.pop(name, None)
        self._loads.pop(name, None)
        self._session_pin = {s: i for s, i in self._session_pin.items()
                             if i != name}

    # -- set/reset shim: derived from ControlSurface -------------------------
    def card_metrics(self) -> tuple:
        return tuple(f"routed.{n}" for n in self.instances)

    # -- routing ------------------------------------------------------------------
    def _fallback(self, session: str) -> str:
        names = sorted(self.instances)
        if not names:
            raise RuntimeError(f"{self.name}: no instances")
        if self.policy == "least_loaded":
            def load(n):
                fn = self._loads.get(n)
                return fn() if callable(fn) else 0.0
            return min(names, key=load)
        if session not in self._session_pin:
            h = zlib.crc32(session.encode())        # deterministic hash
            self._session_pin[session] = names[h % len(names)]
        return self._session_pin[session]

    def pick(self, msg: Message) -> str:
        ruled = self.rules.route_for(msg)
        if ruled is not None and ruled in self.instances:
            return ruled
        session = (msg.payload or {}).get("session") or msg.task_id or ""
        return self._fallback(session)

    def deliver(self, msg: Message) -> None:
        if self._rules_seen != self.rules.version:
            self._rules_seen = self.rules.version
            self._pump()
        if self.rules.blocked(msg):
            self._held.append(msg)
            return
        inst = self.pick(msg)
        self.routed[inst] += 1
        if self.collector is not None:
            self.collector.counter(f"{self.name}.routed.{inst}", 1,
                                   self.loop.now())
        self.instances[inst].deliver(msg)

    def _pump(self) -> None:
        held, self._held = self._held, []
        for msg in held:
            self.deliver(msg)
