"""Engine core: request lifecycle + scheduler interplay shared by the
real-JAX engine and the virtual-clock sim engine.

Subclasses implement ``_exec_prefill`` / ``_exec_decode`` (returning step
duration and sampled tokens) and drive ``apply_*`` bookkeeping.  The
controller talks to every engine through the paper's two-function
``set()/reset()`` surface (Table 1) — ``knob_names`` is what the engine
advertises at registration.
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Optional

from repro.core.knobs import ControlSurface, KnobSpec
from repro.core.types import Request, RequestState
from repro.serving.scheduler import (PrefillWork, Scheduler, SchedulerConfig,
                                     StepKind, StepPlan)


class EngineCore(ControlSurface):
    """Lifecycle + metrics + knobs; time/token mechanics in subclasses.

    Scheduler knobs are *delegated*: the engine advertises them on its
    card and forwards set/get to its scheduler's own ControlSurface —
    the uniform knob name maps onto the engine-internal API with no
    per-knob shim code (the paper's vLLM ``max_num_seqs`` example).
    """

    kind = "llm"
    CAPABILITIES = ("kv_transfer", "pause", "priority", "role")
    METRICS = ("queue_len", "num_running", "page_util", "step_time",
               "mean_step_time", "ttft", "latency", "tpt", "itl_p95",
               "throughput", "prefill_queue_tokens", "decode_slot_util",
               "suspended_seqs", "host_pages_used", "restore_hit_rate",
               "restore_ttft")

    ITL_WINDOW = 256                 # rolling inter-token-latency samples
    KNOB_SPECS = tuple(
        s.delegated("scheduler", clamp="_clamp_max_num_seqs")
        if s.name == "max_num_seqs" else s.delegated("scheduler")
        for s in Scheduler.KNOB_SPECS
    ) + (
        KnobSpec("temperature", kind="float", lo=0.0,
                 doc="sampling temperature; 0 = greedy"),
        KnobSpec("paused", kind="bool", on_change="_paused_changed",
                 doc="freeze the step loop (resume kicks it)"),
        KnobSpec("offload", kind="str",
                 choices=("off", "auto", "aggressive"),
                 doc="tool-call suspend policy: off pins the slot for the "
                     "tool's duration; auto offloads KV to the host tier "
                     "when predicted tool latency under queue pressure "
                     "beats the offload+restore cost; aggressive always "
                     "offloads"),
    )

    def __init__(self, name: str, model_name: str, sched_cfg: SchedulerConfig,
                 collector=None):
        self.name = name
        self.model_name = model_name
        self._physical_slots = sched_cfg.max_slots   # hardware capacity
        self.scheduler = Scheduler(sched_cfg, name=f"{name}.scheduler")
        self.collector = collector
        self.temperature = 0.0
        self.paused = False
        self.steps = 0
        self.prefill_steps = 0
        self.decode_steps = 0
        # measured step time (EWMA + total): the hardware-honesty gauge —
        # the calibration plane compares CostModel predictions against
        # this instead of trusting hand-set roofline constants
        self.mean_step_time = 0.0
        self.step_time_total = 0.0
        self.tokens_generated = 0
        # rolling inter-token-latency samples (per-request gaps between
        # consecutive emitted tokens): the decode-stall signal — a long
        # serialized prefill shows up here as a batch-wide ITL spike,
        # which is exactly what adaptive chunk policies trigger on
        self._itl_samples: deque[float] = deque(maxlen=self.ITL_WINDOW)
        self.finished: list[Request] = []
        self.on_finish: Optional[Callable[[Request, float], None]] = None
        self.on_token: Optional[Callable[[Request, int, float], None]] = None
        # tracing plane (wired by the owning pipeline/fabric): the
        # scheduler reports admit/preempt instants so segment spans
        # open/close at the exact lifecycle transitions
        self.tracer = None
        self.scheduler.on_admit = self._trace_admit
        self.scheduler.on_preempt = self._trace_preempt
        # -- tool-call plane: suspend/resume with tiered KV offload --------
        self.offload = "auto"
        self._host_store: dict[str, dict] = {}  # req_id -> extracted KV
        self.suspend_count = 0
        self.demote_count = 0
        self.restore_ttfts: list[float] = []    # post-tool first-token gaps
        self.scheduler.on_resume = self._resume_landed
        self.scheduler.demote_fn = self._demote_starved_pin
        # -- disaggregation plane hooks (wired by a DisaggPool) ------------
        self.disagg = None                          # owning handoff fabric
        self.kv_ready_fn: Optional[Callable[[Request], float]] = None
        self.on_prefill_progress: Optional[
            Callable[[Request, float], None]] = None
        self.on_prefill_done: Optional[Callable[[Request, float], None]] = None

    # ------------------------------------------------------------------ knobs
    def _clamp_max_num_seqs(self, value: int) -> int:
        return min(int(value), self.physical_slots())

    def _paused_changed(self, old, new) -> None:
        if not new:
            self.kick()

    def on_knob_set(self, name: str, old, new) -> None:
        if name == "role" and old != new:
            self._role_changed(old, new)
        self.kick()                     # new headroom may unblock work

    @property
    def role(self) -> str:
        return self.scheduler.cfg.role

    def _role_changed(self, old: str, new: str) -> None:
        """Runtime role flip.  Specialized roles only make sense inside
        a disaggregation fabric (something must carry sequences across
        the prefill/decode boundary); the fabric drains this engine's
        now-role-inconsistent work — no request is lost, and no decode
        ever runs on a prefill-role engine."""
        if new != "unified" and self.disagg is None:
            self.scheduler.cfg.role = old           # revert before failing
            raise RuntimeError(
                f"{self.name}: role {new!r} needs a disaggregation "
                "fabric attached (see serving/disagg.py)")
        if self.disagg is not None:
            self.disagg.on_role_change(self, old, new)

    def physical_slots(self) -> int:
        return self._physical_slots

    def attach_cache(self, cache):
        """Wire a PrefixCache (sharing this engine's PageAllocator) into
        the scheduler's admission path.  (`scheduler.cache` is the
        handle; the real Engine keeps `self.cache` for its KV pytree.)"""
        self.scheduler.cache = cache
        return cache

    def _surface_now(self) -> float:
        return self.now()               # audit stamps use engine time

    # ---------------------------------------------------------------- queue
    def submit(self, req: Request) -> None:
        if self.role == "decode":
            if self.disagg is None:
                # no fabric to bounce through: the waiting queue would
                # never drain (decode role blocks admission) — fail loud
                raise RuntimeError(
                    f"{self.name}: decode-role engine cannot take fresh "
                    "prompts without a disaggregation fabric")
            # decode engines take no fresh prompts: bounce back through
            # the fabric's router to a prefill-capable engine
            self.disagg.resubmit(req)
            return
        req.meta.pop("disagg_reroutes", None)   # accepted: reset loop guard
        # stamp arrival only once: a preemption victim bounced back
        # through the fabric re-enters submit, and restamping would
        # erase its pre-preemption queueing from every latency metric
        if not req.meta.get("arrived"):
            req.meta["arrived"] = True
            req.arrival_time = self.now()
        self._trace_submit(req)
        self.scheduler.submit(req)
        self._gauge("queue_len", self.scheduler.queue_len)
        self._gauge("prefill_queue_tokens",
                    self.scheduler.prefill_queue_tokens)
        self.kick()

    def admit_handoff(self, req: Request) -> bool:
        """Decode-side admission of a prefill→decode handoff: the
        generalized ``admit_direct`` path, gated on KV residency — the
        request is only admitted once its transferred state has landed
        (``kv_ready_fn``, usually ``KVTransferManager.handoff_wait``)."""
        if self.kv_ready_fn is not None and self.kv_ready_fn(req) > 0:
            return False
        if not self.scheduler.admit_direct(req):
            return False
        self._gauge("num_running", self.scheduler.num_running)
        self.kick()
        return True

    def receive_handoff(self, req: Request, state: dict) -> bool:
        """Full decode-side arrival: residency-gated admission plus the
        subclass's state install (sim: bookkeeping; real engine: the
        transferred KV slice lands in the granted slot).  The
        DisaggPool's arrival/backlog paths route through here, so sim
        and real engines share one handoff admission sequence."""
        if not self.admit_handoff(req):
            return False
        self.inject_state(req, state)
        return True

    def release_for_handoff(self, req: Request) -> None:
        """Source-side release at prefill completion (or a role flip):
        slot and pages free immediately; the request's state rides the
        handoff transfer to its decode engine."""
        self.scheduler.release_for_handoff(req)
        self._trace_seg(req, "handoff_wait")
        self._gauge("num_running", self.scheduler.num_running)

    # ------------------------------------- tool-call suspend/resume plane
    @property
    def restore_hit_rate(self) -> float:
        return self.scheduler.restore_hit_rate

    def restore_cost(self, req: Request) -> float:
        """Modeled host→HBM refill delay a resume pays before landing.
        0 on the real engine (the DMA rides ``inject_state``'s measured
        wall clock); the sim engine prices it from the CostModel."""
        return 0.0

    def _offload_pays(self, req: Request, latency_est: float) -> bool:
        """The ``auto`` rule: offload only when there is queue pressure
        for the freed capacity AND the predicted tool latency beats the
        round-trip spill cost (unknown estimates default to offloading
        under pressure — a pinned slot can never pay for itself)."""
        s = self.scheduler
        pressured = (s.queue_len > 0 or not s._free_slots
                     or bool(s._resume_pending))
        if not pressured:
            return False
        cm = getattr(self, "cm", None)
        if cm is None or latency_est <= 0:
            return True
        cost = (cm.offload_time(req.total_len)
                + cm.restore_time(req.total_len))
        return latency_est > 2.0 * cost

    def suspend_request(self, req: Request, offload: bool | None = None,
                        latency_est: float = 0.0) -> str:
        """Park a RUNNING request for an external wait (a tool call).
        ``offload=None`` lets the engine's ``offload`` knob decide; the
        KV is extracted *before* the scheduler frees its pages so the
        host copy rides the live block table.  Returns the tier:
        ``pin`` | ``host`` | ``drop`` | ``none``."""
        if offload is None:
            offload = (self.offload == "aggressive"
                       or (self.offload == "auto"
                           and self._offload_pays(req, latency_est)))
        want_host = offload and self.scheduler.alloc.host_room_for(req.req_id)
        state = self.extract_state(req) if want_host else None
        tier = self.scheduler.suspend(req, offload=offload)
        if tier == "none":
            return tier
        if tier == "host" and state is not None:
            self._host_store[req.req_id] = state
        self.suspend_count += 1
        req.meta["engine"] = self
        self._trace_seg(req, "suspended")
        self._suspend_gauges()
        self.kick()                     # the freed slot may admit work
        return tier

    def _demote_starved_pin(self) -> None:
        """Scheduler's pin-deadlock breaker: every slot-holder is a
        parked pin and work is waiting.  Demote the oldest pin to a real
        offload — this runs regardless of the ``offload`` knob, because
        it is a liveness guarantee, not a policy choice."""
        victim = self.scheduler.pin_starved()
        if victim is None:
            return
        want_host = self.scheduler.alloc.host_room_for(victim.req_id)
        state = self.extract_state(victim) if want_host else None
        tier = self.scheduler.offload_pinned(victim)
        if tier == "none":
            return
        if tier == "host" and state is not None:
            self._host_store[victim.req_id] = state
        self.demote_count += 1
        victim.meta["engine"] = self
        self._trace_seg(victim, "suspended")
        self._suspend_gauges()

    def resume_suspended(self, req: Request) -> str:
        """Bring a suspended request back: ``pin``/``hit`` land now (the
        scheduler's ``on_resume`` hook re-injects host KV), ``wait``
        queues it ahead of fresh admissions, ``recompute`` re-enters
        normal admission with the tail folded into the prompt."""
        out = self.scheduler.resume(req)
        self._suspend_gauges()
        self.kick()
        return out

    def migrate_suspended(self, req: Request, dest: "EngineCore") -> bool:
        """Cross-engine resume — cache-aware placement when the home
        engine is out of capacity: the host KV copy lands on ``dest``
        through the same ``admit_direct``/``inject_state`` sequence a
        disaggregation handoff uses.  Only offloaded-with-state suspends
        migrate (a pinned request already holds its home slot)."""
        if req.state != RequestState.SUSPENDED \
                or req in self.scheduler.running:
            return False
        state = self._host_store.get(req.req_id)
        if state is None:
            return False
        if not dest.scheduler.admit_direct(req):
            return False
        self.scheduler.forget_suspended(req)
        self._host_store.pop(req.req_id, None)
        dest.inject_state(req, state)
        dest.scheduler.resume_hits += 1
        req.meta["engine"] = dest
        self._suspend_gauges()
        dest._suspend_gauges()
        self.kick()
        dest.kick()
        return True

    def finish_suspended(self, req: Request) -> None:
        """Abandon a held-open suspended request (its continuation went
        to a sibling): release the parked state and account it done."""
        t = self.now()
        self._host_store.pop(req.req_id, None)
        self.scheduler.finish_suspended(req, t)
        self.finished.append(req)
        self._observe("latency", t - req.arrival_time)
        self._trace_finish(req, t)
        self._suspend_gauges()
        self.kick()

    def _resume_landed(self, req: Request, outcome: str) -> None:
        """Scheduler hook: a resume reached its terminal path."""
        state = self._host_store.pop(req.req_id, None)
        if outcome == "hit" and state is not None:
            self.inject_state(req, state)
        elif outcome == "pin":
            self._trace_seg(req, "decode")
        self._suspend_gauges()

    def _suspend_gauges(self) -> None:
        s = self.scheduler
        self._gauge("suspended_seqs", s.suspended_seqs)
        self._gauge("host_pages_used", s.alloc.host_pages)
        self._gauge("restore_hit_rate", s.restore_hit_rate)

    # subclasses provide the actual KV movement (sim: bookkeeping; real
    # engine: the paged_extract/paged_insert batch-1 bridge)
    def extract_state(self, req: Request) -> dict:
        raise NotImplementedError

    def inject_state(self, req: Request, state: dict) -> None:
        raise NotImplementedError

    # ------------------------------------------------------------- tracing
    # Segment spans tile [arrival, finish] exactly: each lifecycle
    # transition closes the open segment and opens the next at the same
    # timestamp, so the per-request decomposition sums to the measured
    # end-to-end latency (the acceptance check in tests/test_trace.py).
    def _trace_submit(self, req: Request) -> None:
        tr = self.tracer
        if tr is None:
            return
        if "traced" not in req.meta:
            tid = req.meta.get("task") or req.req_id
            traced = tr.decide(tid, tenant=req.tenant, stage=req.stage)
            req.meta["traced"] = traced
            if traced:
                parent = req.meta.get("trace_parent") or tr.task_span(tid)
                root = tr.begin(
                    f"request:{req.req_id}", tid, cat="request",
                    parent=parent, t=req.arrival_time, engine=self.name,
                    req_id=req.req_id, stage=req.stage or "",
                    tenant=req.tenant)
                req.meta["trace_root"] = root
                # throttle-hold spans recorded upstream by the router
                # (before a root existed) become children of the root
                for sp in req.meta.pop("trace_pre", []):
                    sp.parent_id = root.span_id
        self._trace_seg(req, "queue_wait")

    def _trace_seg(self, req: Request, name: str) -> None:
        """Roll the request's open segment over to ``name`` at now."""
        tr = self.tracer
        if tr is None or not req.meta.get("traced"):
            return
        t = self.now()
        cur = req.meta.get("trace_seg")
        if cur is not None and cur.t1 is None:
            if cur.name == name and cur.attrs.get("engine") == self.name:
                return                  # same segment, same engine: keep it
            tr.end(cur, t)
        root = req.meta.get("trace_root")
        if root is None or root.t1 is not None:
            req.meta["trace_seg"] = None
            return
        req.meta["trace_seg"] = tr.begin(name, root.trace_id, cat="segment",
                                         parent=root, t=t, engine=self.name,
                                         req_id=req.req_id)

    def _trace_admit(self, req: Request) -> None:
        # admit_direct lands straight in RUNNING (handoff/migration →
        # decode); _admit lands in PREFILL
        self._trace_seg(req, "decode" if req.state is RequestState.RUNNING
                        else "prefill")

    def _trace_preempt(self, req: Request) -> None:
        self._trace_seg(req, "queue_wait")

    def _trace_finish(self, req: Request, t: float) -> None:
        tr = self.tracer
        if tr is None or not req.meta.get("traced"):
            return
        tr.end(req.meta.get("trace_seg"), t)
        req.meta["trace_seg"] = None
        root = req.meta.get("trace_root")
        if root is not None:
            root.attrs["latency"] = t - req.arrival_time
            root.attrs["tokens"] = req.generated
            tr.end(root, t)

    # -------------------------------------------------------------- metrics
    def _gauge(self, name: str, value: float) -> None:
        if self.collector is not None:
            self.collector.gauge(f"{self.name}.{name}", value, self.now())

    def _observe(self, name: str, value: float) -> None:
        if self.collector is not None:
            self.collector.observe(f"{self.name}.{name}", value, self.now())

    def _step_metrics(self, duration: float) -> None:
        s = self.scheduler
        self._gauge("queue_len", s.queue_len)
        self._gauge("num_running", s.num_running)
        self._gauge("page_util", s.alloc.utilization)
        self._observe("step_time", duration)
        self.step_time_total += duration
        self.mean_step_time = (duration if self.steps <= 1 else
                               0.9 * self.mean_step_time + 0.1 * duration)
        self._gauge("mean_step_time", self.mean_step_time)
        self._gauge("tokens_total", self.tokens_generated)
        self._gauge("itl_p95", self.itl_p95)
        self._gauge("prefill_queue_tokens", s.prefill_queue_tokens)
        self._gauge("decode_slot_util", s.decode_slot_util)

    # ------------------------------------------------------ plan bookkeeping
    def apply_prefill(self, works: list[PrefillWork], first_tokens,
                      t: float) -> None:
        """first_tokens: per-work sampled token or None (chunk not final)."""
        self.prefill_steps += 1
        for work, tok in zip(works, first_tokens):
            r = work.req
            if r not in self.scheduler.running:
                continue          # preempted / drained mid-flight
            r.prefilled += work.chunk
            # fairness accounting charges actually-processed tokens
            self.scheduler.charge(r, work.chunk, t)
            if r.prefilled < r.prompt_len:
                if self.on_prefill_progress is not None:
                    # chunk-streamed handoff: push the KV computed so far
                    # while the rest of the prompt is still prefilling
                    self.on_prefill_progress(r, t)
                continue
            r.state = RequestState.RUNNING
            self.scheduler.commit_prefix(r)
            if self.role != "prefill":
                # prefill-role engines skip the zero-length decode span:
                # their prefill segment rolls directly to handoff_wait
                self._trace_seg(r, "decode")
            if tok is not None:
                self._emit_token(r, int(tok), t)
                if r.first_token_time is None:
                    r.first_token_time = t
                    # one ttft sample per request: a preempted victim
                    # resets first_token_time (its output restarts) but
                    # must not contribute a second observation
                    if not r.meta.get("ttft_observed"):
                        r.meta["ttft_observed"] = True
                        self._observe("ttft", t - r.arrival_time)
                        if self.scheduler.tenants is not None:
                            self.scheduler.tenants.observe_ttft(
                                r.tenant, t - r.arrival_time, t)
            if r.state is RequestState.RUNNING and self.role == "prefill":
                if self.on_prefill_done is None:
                    # no handoff sink: the sequence could never decode
                    # (prefill role plans no DECODE steps) — fail loud
                    # instead of holding its slot forever
                    raise RuntimeError(
                        f"{self.name}: prefill-role engine finished "
                        f"{r.req_id} with no disaggregation fabric "
                        "attached to hand it to")
                # first token came from prefill; the decode tail belongs
                # to the paired decode engine — release and hand off
                self.on_prefill_done(r, t)

    def apply_decode(self, reqs: list[Request], tokens, t: float) -> None:
        self.decode_steps += 1
        for r, tok in zip(reqs, tokens):
            if r.state != RequestState.RUNNING \
                    or r not in self.scheduler.running:
                # preempted or handed off mid-flight — the state check
                # alone is not enough: a migrated request can already be
                # RUNNING again on its *destination* engine by the time
                # this stale step lands, and emitting here would decode
                # on an engine that no longer owns the sequence
                continue
            self._emit_token(r, int(tok), t)

    @property
    def itl_p95(self) -> float:
        """Windowed p95 inter-token latency over the engine's recent
        emissions (0.0 until two tokens of one request have landed)."""
        if not self._itl_samples:
            return 0.0
        xs = sorted(self._itl_samples)
        return xs[min(int(0.95 * len(xs)), len(xs) - 1)]

    def _note_itl(self, r: Request, t: float) -> None:
        prev = r.meta.get("last_token_t")
        r.meta["last_token_t"] = t
        if prev is not None and t >= prev:
            self._itl_samples.append(t - prev)

    def _emit_token(self, r: Request, tok: int, t: float) -> None:
        self._note_itl(r, t)
        r.generated += 1
        r.output_tokens.append(tok)
        self.tokens_generated += 1
        self.scheduler.charge(r, 1, t)
        t0 = r.meta.pop("post_tool_t0", None)
        if t0 is not None:
            # post-tool TTFT: tool completion -> first resumed token
            # (restore/recompute latency + any capacity wait)
            self._observe("restore_ttft", t - t0)
            self.restore_ttfts.append(t - t0)
        if self.on_token is not None:
            self.on_token(r, tok, t)
        if r.done:
            if r.meta.pop("hold_open", False):
                # the *call* is complete but the sequence lives on: park
                # it for the tool's duration instead of finishing, so the
                # post-tool turn resumes on a warm cache.  Stage
                # bookkeeping still advances through on_finish.
                self.suspend_request(
                    r, latency_est=float(r.meta.get("tool_latency_est", 0.0)))
                if self.on_finish is not None:
                    self.on_finish(r, t)
                return
            self.scheduler.finish(r, t)
            self.finished.append(r)
            self._observe("latency", t - r.arrival_time)
            if r.generated > 1 and r.first_token_time is not None:
                tpt = (t - r.first_token_time) / max(r.generated - 1, 1)
                self._observe("tpt", tpt)
            self._trace_finish(r, t)
            if self.on_finish is not None:
                self.on_finish(r, t)

    # ----------------------------------------------------------- abstract
    def now(self) -> float:
        raise NotImplementedError

    def kick(self) -> None:
        """Called when new work may be available."""

    @property
    def busy(self) -> bool:
        return (self.scheduler.queue_len > 0
                or self.scheduler.num_running > 0)

    # current load signal used by routing policies
    def load(self) -> float:
        return self.scheduler.queue_len + self.scheduler.num_running
