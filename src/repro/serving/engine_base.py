"""Engine core: request lifecycle + scheduler interplay shared by the
real-JAX engine and the virtual-clock sim engine.

Subclasses implement ``_exec_prefill`` / ``_exec_decode`` (returning step
duration and sampled tokens) and drive ``apply_*`` bookkeeping.  The
controller talks to every engine through the paper's two-function
``set()/reset()`` surface (Table 1) — ``knob_names`` is what the engine
advertises at registration.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.core.knobs import ControlSurface, KnobSpec
from repro.core.types import Priority, Request, RequestState, fresh_id
from repro.serving.scheduler import (PrefillWork, Scheduler, SchedulerConfig,
                                     StepKind, StepPlan)


class EngineCore(ControlSurface):
    """Lifecycle + metrics + knobs; time/token mechanics in subclasses.

    Scheduler knobs are *delegated*: the engine advertises them on its
    card and forwards set/get to its scheduler's own ControlSurface —
    the uniform knob name maps onto the engine-internal API with no
    per-knob shim code (the paper's vLLM ``max_num_seqs`` example).
    """

    kind = "llm"
    CAPABILITIES = ("kv_transfer", "pause", "priority")
    METRICS = ("queue_len", "num_running", "page_util", "step_time",
               "ttft", "latency", "tpt", "throughput")
    KNOB_SPECS = tuple(
        s.delegated("scheduler", clamp="_clamp_max_num_seqs")
        if s.name == "max_num_seqs" else s.delegated("scheduler")
        for s in Scheduler.KNOB_SPECS
    ) + (
        KnobSpec("temperature", kind="float", lo=0.0,
                 doc="sampling temperature; 0 = greedy"),
        KnobSpec("paused", kind="bool", on_change="_paused_changed",
                 doc="freeze the step loop (resume kicks it)"),
    )

    def __init__(self, name: str, model_name: str, sched_cfg: SchedulerConfig,
                 collector=None):
        self.name = name
        self.model_name = model_name
        self._physical_slots = sched_cfg.max_slots   # hardware capacity
        self.scheduler = Scheduler(sched_cfg, name=f"{name}.scheduler")
        self.collector = collector
        self.temperature = 0.0
        self.paused = False
        self.steps = 0
        self.tokens_generated = 0
        self.finished: list[Request] = []
        self.on_finish: Optional[Callable[[Request, float], None]] = None
        self.on_token: Optional[Callable[[Request, int, float], None]] = None

    # ------------------------------------------------------------------ knobs
    def _clamp_max_num_seqs(self, value: int) -> int:
        return min(int(value), self.physical_slots())

    def _paused_changed(self, old, new) -> None:
        if not new:
            self.kick()

    def on_knob_set(self, name: str, old, new) -> None:
        self.kick()                     # new headroom may unblock work

    def physical_slots(self) -> int:
        return self._physical_slots

    def attach_cache(self, cache):
        """Wire a PrefixCache (sharing this engine's PageAllocator) into
        the scheduler's admission path.  (`scheduler.cache` is the
        handle; the real Engine keeps `self.cache` for its KV pytree.)"""
        self.scheduler.cache = cache
        return cache

    def _surface_now(self) -> float:
        return self.now()               # audit stamps use engine time

    # ---------------------------------------------------------------- queue
    def submit(self, req: Request) -> None:
        req.arrival_time = self.now()
        self.scheduler.submit(req)
        self._gauge("queue_len", self.scheduler.queue_len)
        self.kick()

    # -------------------------------------------------------------- metrics
    def _gauge(self, name: str, value: float) -> None:
        if self.collector is not None:
            self.collector.gauge(f"{self.name}.{name}", value, self.now())

    def _observe(self, name: str, value: float) -> None:
        if self.collector is not None:
            self.collector.observe(f"{self.name}.{name}", value, self.now())

    def _step_metrics(self, duration: float) -> None:
        s = self.scheduler
        self._gauge("queue_len", s.queue_len)
        self._gauge("num_running", s.num_running)
        self._gauge("page_util", s.alloc.utilization)
        self._observe("step_time", duration)
        self._gauge("tokens_total", self.tokens_generated)

    # ------------------------------------------------------ plan bookkeeping
    def apply_prefill(self, works: list[PrefillWork], first_tokens,
                      t: float) -> None:
        """first_tokens: per-work sampled token or None (chunk not final)."""
        for work, tok in zip(works, first_tokens):
            r = work.req
            r.prefilled += work.chunk
            if r.prefilled >= r.prompt_len:
                r.state = RequestState.RUNNING
                self.scheduler.commit_prefix(r)
                if tok is not None:
                    self._emit_token(r, int(tok), t)
                    if r.first_token_time is None:
                        r.first_token_time = t
                        self._observe("ttft", t - r.arrival_time)

    def apply_decode(self, reqs: list[Request], tokens, t: float) -> None:
        for r, tok in zip(reqs, tokens):
            if r.state != RequestState.RUNNING:
                continue          # preempted mid-flight
            self._emit_token(r, int(tok), t)

    def _emit_token(self, r: Request, tok: int, t: float) -> None:
        r.generated += 1
        r.output_tokens.append(tok)
        self.tokens_generated += 1
        if self.on_token is not None:
            self.on_token(r, tok, t)
        if r.done:
            self.scheduler.finish(r, t)
            self.finished.append(r)
            self._observe("latency", t - r.arrival_time)
            if r.generated > 1 and r.first_token_time is not None:
                tpt = (t - r.first_token_time) / max(r.generated - 1, 1)
                self._observe("tpt", tpt)
            if self.on_finish is not None:
                self.on_finish(r, t)

    # ----------------------------------------------------------- abstract
    def now(self) -> float:
        raise NotImplementedError

    def kick(self) -> None:
        """Called when new work may be available."""

    @property
    def busy(self) -> bool:
        return (self.scheduler.queue_len > 0
                or self.scheduler.num_running > 0)

    # current load signal used by routing policies
    def load(self) -> float:
        return self.scheduler.queue_len + self.scheduler.num_running
