"""Engine core: request lifecycle + scheduler interplay shared by the
real-JAX engine and the virtual-clock sim engine.

Subclasses implement ``_exec_prefill`` / ``_exec_decode`` (returning step
duration and sampled tokens) and drive ``apply_*`` bookkeeping.  The
controller talks to every engine through the paper's two-function
``set()/reset()`` surface (Table 1) — ``knob_names`` is what the engine
advertises at registration.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.core.types import (AgentCard, Priority, Request, RequestState,
                              fresh_id)
from repro.serving.scheduler import (PrefillWork, Scheduler, SchedulerConfig,
                                     StepKind, StepPlan)


class EngineCore:
    """Lifecycle + metrics + knobs; time/token mechanics in subclasses."""

    def __init__(self, name: str, model_name: str, sched_cfg: SchedulerConfig,
                 collector=None):
        self.name = name
        self.model_name = model_name
        self._physical_slots = sched_cfg.max_slots   # hardware capacity
        self.scheduler = Scheduler(sched_cfg)
        self.collector = collector
        self.temperature = 0.0
        self.paused = False
        self.steps = 0
        self.tokens_generated = 0
        self.finished: list[Request] = []
        self._defaults: dict[str, object] = {}
        self.on_finish: Optional[Callable[[Request, float], None]] = None
        self.on_token: Optional[Callable[[Request, int, float], None]] = None

    # ------------------------------------------------------------------ knobs
    KNOBS = Scheduler.KNOBS + ("temperature", "paused")

    def knob_names(self) -> tuple[str, ...]:
        return self.KNOBS

    def card(self) -> AgentCard:
        return AgentCard(
            name=self.name, kind="llm",
            knobs={k: self.get_param(k) for k in self.knob_names()},
            metrics=("queue_len", "num_running", "page_util", "step_time",
                     "ttft", "latency", "tpt", "throughput"),
            capabilities=("kv_transfer", "pause", "priority"))

    def get_param(self, name: str):
        if name == "temperature":
            return self.temperature
        if name == "paused":
            return self.paused
        if name == "max_num_seqs":
            return self.scheduler.cfg.max_slots
        return getattr(self.scheduler.cfg, name)

    def set_param(self, name: str, value) -> None:
        """The paper's ``set()`` — map the uniform knob name onto the
        engine-internal API (this method IS the per-agent shim layer)."""
        if name not in self.KNOBS:
            raise KeyError(f"{self.name}: unknown knob {name!r}")
        self._defaults.setdefault(name, self.get_param(name))
        if name == "temperature":
            self.temperature = float(value)
        elif name == "paused":
            self.paused = bool(value)
            if not self.paused:
                self.kick()
        else:
            if name == "max_num_seqs":
                value = min(int(value), self.physical_slots())
            self.scheduler.set_knob(name, value)
        self.kick()

    def reset_param(self, name: str) -> None:
        """The paper's ``reset()`` — restore the registered default."""
        if name in self._defaults:
            self.set_param(name, self._defaults[name])

    def physical_slots(self) -> int:
        return self._physical_slots

    # ---------------------------------------------------------------- queue
    def submit(self, req: Request) -> None:
        req.arrival_time = self.now()
        self.scheduler.submit(req)
        self._gauge("queue_len", self.scheduler.queue_len)
        self.kick()

    # -------------------------------------------------------------- metrics
    def _gauge(self, name: str, value: float) -> None:
        if self.collector is not None:
            self.collector.gauge(f"{self.name}.{name}", value, self.now())

    def _observe(self, name: str, value: float) -> None:
        if self.collector is not None:
            self.collector.observe(f"{self.name}.{name}", value, self.now())

    def _step_metrics(self, duration: float) -> None:
        s = self.scheduler
        self._gauge("queue_len", s.queue_len)
        self._gauge("num_running", s.num_running)
        self._gauge("page_util", s.alloc.utilization)
        self._observe("step_time", duration)
        self._gauge("tokens_total", self.tokens_generated)

    # ------------------------------------------------------ plan bookkeeping
    def apply_prefill(self, works: list[PrefillWork], first_tokens,
                      t: float) -> None:
        """first_tokens: per-work sampled token or None (chunk not final)."""
        for work, tok in zip(works, first_tokens):
            r = work.req
            r.prefilled += work.chunk
            if r.prefilled >= r.prompt_len:
                r.state = RequestState.RUNNING
                if tok is not None:
                    self._emit_token(r, int(tok), t)
                    if r.first_token_time is None:
                        r.first_token_time = t
                        self._observe("ttft", t - r.arrival_time)

    def apply_decode(self, reqs: list[Request], tokens, t: float) -> None:
        for r, tok in zip(reqs, tokens):
            if r.state != RequestState.RUNNING:
                continue          # preempted mid-flight
            self._emit_token(r, int(tok), t)

    def _emit_token(self, r: Request, tok: int, t: float) -> None:
        r.generated += 1
        r.output_tokens.append(tok)
        self.tokens_generated += 1
        if self.on_token is not None:
            self.on_token(r, tok, t)
        if r.done:
            self.scheduler.finish(r, t)
            self.finished.append(r)
            self._observe("latency", t - r.arrival_time)
            if r.generated > 1 and r.first_token_time is not None:
                tpt = (t - r.first_token_time) / max(r.generated - 1, 1)
                self._observe("tpt", tpt)
            if self.on_finish is not None:
                self.on_finish(r, t)

    # ----------------------------------------------------------- abstract
    def now(self) -> float:
        raise NotImplementedError

    def kick(self) -> None:
        """Called when new work may be available."""

    @property
    def busy(self) -> bool:
        return (self.scheduler.queue_len > 0
                or self.scheduler.num_running > 0)

    # current load signal used by routing policies
    def load(self) -> float:
        return self.scheduler.queue_len + self.scheduler.num_running
