"""Page-granular KV accounting (vLLM-style allocator, TPU-adapted).

On TPU the physical decode state lives in slot-contiguous ring buffers
inside the jitted step (fixed shapes, no per-page gathers on the hot
path — see DESIGN.md §3); this allocator provides the *scheduling*
semantics of paging: admission control, growth-on-decode, preemption
pressure, and per-sequence accounting that the controller's policies and
the KV-transfer cost model read.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class PageAllocator:
    num_pages: int
    page_size: int = 128
    _used: dict[str, int] = field(default_factory=dict)   # seq -> pages

    # -- queries --------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return self.num_pages - sum(self._used.values())

    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size) if tokens > 0 else 0

    def holds(self, seq_id: str) -> int:
        return self._used.get(seq_id, 0)

    def can_allocate(self, tokens: int) -> bool:
        return self.pages_for(tokens) <= self.free_pages

    @property
    def utilization(self) -> float:
        return 1.0 - self.free_pages / max(self.num_pages, 1)

    # -- mutation ---------------------------------------------------------------
    def allocate(self, seq_id: str, tokens: int) -> bool:
        need = self.pages_for(tokens)
        have = self._used.get(seq_id, 0)
        grow = max(0, need - have)
        if grow > self.free_pages:
            return False
        self._used[seq_id] = max(need, have)
        return True

    def grow_to(self, seq_id: str, total_tokens: int) -> bool:
        """Ensure capacity for total_tokens; False => caller must preempt."""
        return self.allocate(seq_id, total_tokens)

    def free(self, seq_id: str) -> int:
        return self._used.pop(seq_id, 0)

    def reset(self) -> None:
        self._used.clear()
