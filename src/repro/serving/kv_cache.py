"""Page-granular KV accounting (vLLM-style allocator, TPU-adapted).

On TPU the physical decode state lives in slot-contiguous ring buffers
inside the jitted step (fixed shapes, no per-page gathers on the hot
path — see DESIGN.md §3); this allocator provides the *scheduling*
semantics of paging: admission control, growth-on-decode, preemption
pressure, and per-sequence accounting that the controller's policies and
the KV-transfer cost model read.

Two page classes:

* **private** pages — owned by exactly one sequence (`allocate`/`grow_to`
  /`free`), the original accounting.
* **shared** blocks — refcounted groups of pages holding a cached token
  prefix (serving/prefix_cache.py).  A sequence *acquires* a resident
  block instead of re-allocating it; freeing the sequence only drops the
  block's refcount, and the pages themselves stay resident (refcount 0
  ⇒ *idle*, i.e. evictable by the prefix cache's policy) until
  ``drop_block`` reclaims them.

Invariant (the hypothesis property tests pin this down):

    free_pages + private_pages + shared_pages == num_pages
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class SharedBlock:
    """One refcounted shared page group (a cached prefix block)."""

    block_id: str
    pages: int
    refs: int = 0


@dataclass
class PageAllocator:
    num_pages: int
    page_size: int = 128
    _used: dict[str, int] = field(default_factory=dict)   # seq -> pages
    _blocks: dict[str, SharedBlock] = field(default_factory=dict)
    _seq_blocks: dict[str, list[str]] = field(default_factory=dict)

    # -- queries --------------------------------------------------------------
    @property
    def private_pages(self) -> int:
        return sum(self._used.values())

    @property
    def shared_pages(self) -> int:
        return sum(b.pages for b in self._blocks.values())

    @property
    def free_pages(self) -> int:
        return self.num_pages - self.private_pages - self.shared_pages

    @property
    def idle_pages(self) -> int:
        """Shared pages held only by the cache (refcount 0): reclaimable."""
        return sum(b.pages for b in self._blocks.values() if b.refs == 0)

    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size) if tokens > 0 else 0

    def holds(self, seq_id: str) -> int:
        return self._used.get(seq_id, 0)

    def can_allocate(self, tokens: int) -> bool:
        return self.pages_for(tokens) <= self.free_pages

    @property
    def utilization(self) -> float:
        return 1.0 - self.free_pages / max(self.num_pages, 1)

    # -- private-page mutation -------------------------------------------------
    def allocate(self, seq_id: str, tokens: int) -> bool:
        need = self.pages_for(tokens)
        have = self._used.get(seq_id, 0)
        grow = max(0, need - have)
        if grow > self.free_pages:
            return False
        self._used[seq_id] = max(need, have)
        return True

    def grow_to(self, seq_id: str, total_tokens: int) -> bool:
        """Ensure capacity for total_tokens; False => caller must preempt."""
        return self.allocate(seq_id, total_tokens)

    def free(self, seq_id: str) -> int:
        """Release a sequence: private pages are returned to the pool;
        shared blocks are only decref'd — their pages stay resident until
        the prefix cache evicts them (``drop_block``)."""
        for bid in self._seq_blocks.pop(seq_id, ()):
            blk = self._blocks.get(bid)
            if blk is not None and blk.refs > 0:
                blk.refs -= 1
        return self._used.pop(seq_id, 0)

    # -- shared-block mutation -------------------------------------------------
    def share(self, block_id: str, pages: int) -> bool:
        """Make a block resident with refcount 0 (cache-owned).  No-op if
        already resident; False if the pool has no room."""
        if block_id in self._blocks:
            return True
        if pages > self.free_pages:
            return False
        self._blocks[block_id] = SharedBlock(block_id, pages)
        return True

    def block_resident(self, block_id: str) -> bool:
        return block_id in self._blocks

    def block_refs(self, block_id: str) -> int:
        blk = self._blocks.get(block_id)
        return blk.refs if blk is not None else 0

    def acquire(self, seq_id: str, block_id: str) -> bool:
        """Reference a resident block from a sequence (idempotent per
        seq/block pair)."""
        blk = self._blocks.get(block_id)
        if blk is None:
            return False
        held = self._seq_blocks.setdefault(seq_id, [])
        if block_id in held:
            return True
        held.append(block_id)
        blk.refs += 1
        return True

    def promote(self, seq_id: str, block_id: str, pages: int) -> bool:
        """Convert ``pages`` of a sequence's *private* pages into a new
        shared block referenced by that sequence — how freshly-prefilled
        prefix blocks enter the cache without double-counting."""
        if block_id in self._blocks:
            return self.acquire(seq_id, block_id)
        have = self._used.get(seq_id, 0)
        if pages > have:
            return False
        self._used[seq_id] = have - pages
        self._blocks[block_id] = SharedBlock(block_id, pages, refs=0)
        return self.acquire(seq_id, block_id)

    def drop_block(self, block_id: str) -> bool:
        """Evict an idle (refcount-0) block; its pages return to the pool."""
        blk = self._blocks.get(block_id)
        if blk is None or blk.refs > 0:
            return False
        del self._blocks[block_id]
        return True

    def reset(self) -> None:
        self._used.clear()
        self._blocks.clear()
        self._seq_blocks.clear()
