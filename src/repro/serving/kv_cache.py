"""Page-granular KV accounting (vLLM-style allocator, TPU-adapted).

On TPU the physical decode state lives in slot-contiguous ring buffers
inside the jitted step (fixed shapes, no per-page gathers on the hot
path — see DESIGN.md §3); this allocator provides the *scheduling*
semantics of paging: admission control, growth-on-decode, preemption
pressure, and per-sequence accounting that the controller's policies and
the KV-transfer cost model read.

Two page classes:

* **private** pages — owned by exactly one sequence (`allocate`/`grow_to`
  /`free`), the original accounting.
* **shared** blocks — refcounted groups of pages holding a cached token
  prefix (serving/prefix_cache.py).  A sequence *acquires* a resident
  block instead of re-allocating it; freeing the sequence only drops the
  block's refcount, and the pages themselves stay resident (refcount 0
  ⇒ *idle*, i.e. evictable by the prefix cache's policy) until
  ``drop_block`` reclaims them.

* **host** pages — a spill tier for tool-call suspend/resume
  (serving/scheduler.py): ``suspend`` moves a live sequence's private
  pages HBM→host and releases its shared blocks (decref only, so
  sharers keep the prefix hot), ``restore`` reclaims fresh HBM pages
  and re-acquires the remembered blocks, and ``drop_suspended`` is the
  bottom rung of the eviction ladder HBM → host → drop-and-recompute.
  Host pages get physical ids in their own range ``[num_pages,
  num_pages + host_capacity_pages)`` so the two tiers never alias.

Invariant (the hypothesis property tests pin this down):

    free_pages + private_pages + shared_pages == num_pages
    host_free + host_used                     == host_capacity_pages

Beyond the page *counts*, the allocator assigns every page a concrete
**physical id** in ``[0, num_pages)``: each sequence holds an ordered
list of private ids, each shared block an ordered id group, and
``page_table(seq_id)`` lays them out in logical order (acquired shared
blocks first — the prefix — then private pages).  That list is exactly
the block-table row ``kernels/paged_decode_attention.py`` gathers
through, so the scheduling-plane layout and the kernel's memory-access
pattern are one structure: shared prefixes appear as the *same*
physical ids in every sharer's table.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class SharedBlock:
    """One refcounted shared page group (a cached prefix block)."""

    block_id: str
    pages: int
    refs: int = 0


@dataclass
class PageAllocator:
    num_pages: int
    page_size: int = 128
    host_capacity_pages: int = 0
    _used: dict[str, int] = field(default_factory=dict)   # seq -> pages
    _blocks: dict[str, SharedBlock] = field(default_factory=dict)
    _seq_blocks: dict[str, list[str]] = field(default_factory=dict)
    # physical page ids (same partition as the counts above)
    _free_ids: list[int] = field(default_factory=list)
    _seq_ids: dict[str, list[int]] = field(default_factory=dict)
    _block_ids: dict[str, list[int]] = field(default_factory=dict)
    # host spill tier: ids live in [num_pages, num_pages + capacity)
    _host_free_ids: list[int] = field(default_factory=list)
    _host_ids: dict[str, list[int]] = field(default_factory=dict)
    _host_blocks: dict[str, list[str]] = field(default_factory=dict)

    def __post_init__(self):
        if not self._free_ids and not self._seq_ids and not self._block_ids:
            self._free_ids = list(range(self.num_pages))
        if not self._host_free_ids and not self._host_ids:
            self._host_free_ids = list(
                range(self.num_pages,
                      self.num_pages + self.host_capacity_pages))
        self._host_next = self.num_pages + self.host_capacity_pages

    # -- queries --------------------------------------------------------------
    @property
    def private_pages(self) -> int:
        return sum(self._used.values())

    @property
    def shared_pages(self) -> int:
        return sum(b.pages for b in self._blocks.values())

    @property
    def free_pages(self) -> int:
        return self.num_pages - self.private_pages - self.shared_pages

    @property
    def idle_pages(self) -> int:
        """Shared pages held only by the cache (refcount 0): reclaimable."""
        return sum(b.pages for b in self._blocks.values() if b.refs == 0)

    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size) if tokens > 0 else 0

    def holds(self, seq_id: str) -> int:
        return self._used.get(seq_id, 0)

    def can_allocate(self, tokens: int) -> bool:
        return self.pages_for(tokens) <= self.free_pages

    @property
    def utilization(self) -> float:
        return 1.0 - self.free_pages / max(self.num_pages, 1)

    @property
    def host_pages(self) -> int:
        return sum(len(ids) for ids in self._host_ids.values())

    @property
    def host_free_pages(self) -> int:
        return len(self._host_free_ids)

    def is_suspended(self, seq_id: str) -> bool:
        return seq_id in self._host_ids

    def host_room_for(self, seq_id: str) -> bool:
        """Would ``suspend(seq_id)`` land on the host tier (vs drop)?"""
        return self._used.get(seq_id, 0) <= len(self._host_free_ids)

    # -- private-page mutation -------------------------------------------------
    def allocate(self, seq_id: str, tokens: int) -> bool:
        if seq_id in self._host_ids:          # suspended sequences can't grow
            return False
        need = self.pages_for(tokens)
        have = self._used.get(seq_id, 0)
        grow = max(0, need - have)
        if grow > self.free_pages:
            return False
        self._used[seq_id] = max(need, have)
        if grow:
            ids = self._seq_ids.setdefault(seq_id, [])
            ids.extend(self._free_ids[:grow])
            del self._free_ids[:grow]
        return True

    def grow_to(self, seq_id: str, total_tokens: int) -> bool:
        """Ensure capacity for total_tokens; False => caller must preempt."""
        return self.allocate(seq_id, total_tokens)

    def free(self, seq_id: str) -> int:
        """Release a sequence: private pages are returned to the pool;
        shared blocks are only decref'd — their pages stay resident until
        the prefix cache evicts them (``drop_block``)."""
        for bid in self._seq_blocks.pop(seq_id, ()):
            blk = self._blocks.get(bid)
            if blk is not None and blk.refs > 0:
                blk.refs -= 1
        self._free_ids.extend(self._seq_ids.pop(seq_id, ()))
        return self._used.pop(seq_id, 0)

    # -- host spill tier (tool-call suspend/resume) ----------------------------
    def suspend(self, seq_id: str) -> str:
        """Spill a live sequence for an external wait.  Private pages move
        HBM→host (fresh ids from the host range); acquired shared blocks
        are decref'd — sharers keep them hot — but remembered so
        ``restore`` can re-acquire the exact prefix chain.  Returns
        ``"host"`` on a successful spill or ``"drop"`` when the host tier
        has no room (the sequence's state is simply released and resume
        must recompute)."""
        if seq_id in self._host_ids:
            return "host"
        blocks = self._seq_blocks.pop(seq_id, [])
        for bid in blocks:
            blk = self._blocks.get(bid)
            if blk is not None and blk.refs > 0:
                blk.refs -= 1
        ids = self._seq_ids.pop(seq_id, [])
        self._used.pop(seq_id, None)
        self._free_ids.extend(ids)
        n = len(ids)
        if n > len(self._host_free_ids):
            return "drop"
        self._host_ids[seq_id] = self._host_free_ids[:n]
        del self._host_free_ids[:n]
        self._host_blocks[seq_id] = blocks
        return "host"

    def host_holds(self, seq_id: str) -> int:
        return len(self._host_ids.get(seq_id, ()))

    def restore_ready(self, seq_id: str) -> str:
        """Why (or whether) a warm restore can proceed right now:
        ``ok`` | ``no_pages`` (HBM full — transient) | ``no_blocks``
        (prefix chain partially evicted — recompute) | ``gone`` (no host
        copy — recompute)."""
        ids = self._host_ids.get(seq_id)
        if ids is None:
            return "gone"
        if any(b not in self._blocks
               for b in self._host_blocks.get(seq_id, ())):
            return "no_blocks"
        return "ok" if len(ids) <= len(self._free_ids) else "no_pages"

    def can_restore(self, seq_id: str) -> bool:
        """True iff a host-suspended sequence can come back warm: the host
        copy exists, every remembered prefix block is still resident, and
        the HBM pool has room for its private pages."""
        return self.restore_ready(seq_id) == "ok"

    def restore(self, seq_id: str) -> bool:
        """Reclaim HBM pages for a host-suspended sequence and re-acquire
        its prefix blocks (all-or-nothing: a partially evicted chain means
        recompute, not a broken prefix)."""
        if not self.can_restore(seq_id):
            return False
        host = self._host_ids.pop(seq_id)   # un-suspend first: acquire()
        for bid in self._host_blocks.pop(seq_id, ()):   # refuses parked seqs
            self.acquire(seq_id, bid)
        n = len(host)
        if n:
            self._used[seq_id] = n
            self._seq_ids[seq_id] = self._free_ids[:n]
            del self._free_ids[:n]
        self._host_free_ids.extend(host)
        return True

    def drop_suspended(self, seq_id: str) -> int:
        """Bottom of the eviction ladder: discard the host copy (resume
        will drop-and-recompute).  Returns the host pages reclaimed."""
        self._host_blocks.pop(seq_id, None)
        ids = self._host_ids.pop(seq_id, ())
        self._host_free_ids.extend(ids)
        return len(ids)

    def set_host_capacity(self, pages: int) -> int:
        """Grow/shrink the host tier; shrink is clamped above the pages
        currently holding spilled sequences.  Returns the capacity that
        actually took effect."""
        pages = max(0, int(pages))
        cur = self.host_capacity_pages
        if pages > cur:
            grow = pages - cur
            self._host_free_ids.extend(
                range(self._host_next, self._host_next + grow))
            self._host_next += grow
        elif pages < cur:
            drop = min(cur - pages, len(self._host_free_ids))
            if drop:
                del self._host_free_ids[-drop:]
            pages = cur - drop
        self.host_capacity_pages = pages
        return pages

    # -- shared-block mutation -------------------------------------------------
    def share(self, block_id: str, pages: int) -> bool:
        """Make a block resident with refcount 0 (cache-owned).  No-op if
        already resident; False if the pool has no room."""
        if block_id in self._blocks:
            return True
        if pages > self.free_pages:
            return False
        self._blocks[block_id] = SharedBlock(block_id, pages)
        self._block_ids[block_id] = self._free_ids[:pages]
        del self._free_ids[:pages]
        return True

    def block_resident(self, block_id: str) -> bool:
        return block_id in self._blocks

    def block_refs(self, block_id: str) -> int:
        blk = self._blocks.get(block_id)
        return blk.refs if blk is not None else 0

    def acquire(self, seq_id: str, block_id: str) -> bool:
        """Reference a resident block from a sequence (idempotent per
        seq/block pair)."""
        blk = self._blocks.get(block_id)
        if blk is None or seq_id in self._host_ids:
            return False                  # suspended: no HBM references
        held = self._seq_blocks.setdefault(seq_id, [])
        if block_id in held:
            return True
        held.append(block_id)
        blk.refs += 1
        return True

    def promote(self, seq_id: str, block_id: str, pages: int) -> bool:
        """Convert ``pages`` of a sequence's *private* pages into a new
        shared block referenced by that sequence — how freshly-prefilled
        prefix blocks enter the cache without double-counting."""
        if block_id in self._blocks:
            return self.acquire(seq_id, block_id)
        if seq_id in self._host_ids:
            return False                  # suspended: no HBM references
        have = self._used.get(seq_id, 0)
        if pages > have:
            return False
        self._used[seq_id] = have - pages
        # the promoted pages are the *front* of the private region: a
        # sequence's private pages cover its tokens in order and commit
        # promotes prefix blocks front-to-back, so the physical ids move
        # with the tokens they hold
        ids = self._seq_ids.get(seq_id, [])
        self._block_ids[block_id] = ids[:pages]
        del ids[:pages]
        self._blocks[block_id] = SharedBlock(block_id, pages, refs=0)
        return self.acquire(seq_id, block_id)

    def drop_block(self, block_id: str) -> bool:
        """Evict an idle (refcount-0) block; its pages return to the pool."""
        blk = self._blocks.get(block_id)
        if blk is None or blk.refs > 0:
            return False
        del self._blocks[block_id]
        self._free_ids.extend(self._block_ids.pop(block_id, ()))
        return True

    # -- kernel block tables ---------------------------------------------------
    def block_pages(self, block_id: str) -> list[int]:
        """Physical page ids of a resident shared block, in token order."""
        return list(self._block_ids.get(block_id, ()))

    def page_table(self, seq_id: str) -> list[int]:
        """Physical page ids of ``seq_id`` in logical (token) order:
        acquired shared blocks first — the cached prefix, in acquisition
        order, which is chain order — then private pages.  This row is
        what the paged decode-attention kernel's block table gathers
        through; sequences sharing a prefix block repeat the same
        physical ids."""
        ids: list[int] = []
        for bid in self._seq_blocks.get(seq_id, ()):
            ids.extend(self._block_ids.get(bid, ()))
        ids.extend(self._seq_ids.get(seq_id, ()))
        return ids

    def reset(self) -> None:
        self._used.clear()
        self._blocks.clear()
        self._seq_blocks.clear()
        self._free_ids = list(range(self.num_pages))
        self._seq_ids.clear()
        self._block_ids.clear()
        self._host_ids.clear()
        self._host_blocks.clear()
        self._host_free_ids = list(
            range(self.num_pages, self.num_pages + self.host_capacity_pages))
        self._host_next = self.num_pages + self.host_capacity_pages


def block_tables(alloc: PageAllocator, seq_ids,
                 pad_to: int = 0, width: int | None = None) -> list[list[int]]:
    """Batched kernel block tables: one row per sequence, physical page
    ids in logical order, right-padded with -1 to a rectangle (at least
    ``pad_to`` columns).  Feed directly to
    ``kernels.ops.paged_decode_attention``.

    ``width`` pins the exact column count (the engine's jitted step
    traces a fixed (slots, P_max) table so page churn never recompiles);
    a row longer than ``width`` means the allocator granted a sequence
    more context than the engine compiled for — a real invariant
    violation, so it raises."""
    rows = [alloc.page_table(s) for s in seq_ids]
    if width is not None:
        for s, r in zip(seq_ids, rows):
            if len(r) > width:
                raise ValueError(
                    f"page table for {s!r} has {len(r)} pages > fixed "
                    f"width {width}")
    else:
        width = max([len(r) for r in rows] + [pad_to, 1])
    return [r + [-1] * (width - len(r)) for r in rows]
