"""Real JAX engine: actual forward passes with slot-batched ring caches.

The decode hot path is ONE fixed-shape jitted step over all slots
(continuous batching, TPU-style: inactive slots ride along as padding so
the compiled executable never changes shape).  Sampling is **fused into
the step**: the jitted function runs forward pass → logits →
greedy/temperature sample and returns int32 token ids, so the (B, V)
logits never leave the device and the only host transfer per step is
the sampled tokens themselves.  Prefill runs per request at its exact
prompt length (CPU container: a handful of lengths per test/example; on
TPU you'd bucket).  Slot state surgery uses serving/cache_utils; KV
migration uses serving/kv_transfer.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs.base import ModelConfig
from repro.core.types import Request, RequestState
from repro.serving import cache_utils, sampler
from repro.serving.engine_base import EngineCore
from repro.serving.scheduler import SchedulerConfig, StepKind


class Engine(EngineCore):
    def __init__(self, cfg: ModelConfig, params, sched_cfg: SchedulerConfig,
                 name: str = "engine", collector=None, seed: int = 0):
        sched_cfg.require_complete_prompt = True   # one-shot real prefill
        super().__init__(name, cfg.name, sched_cfg, collector)
        self.cfg = cfg
        self.params = params
        self._t0 = time.monotonic()
        self._key = jax.random.key(seed)
        self._axes = cache_utils.batch_axes(cfg, sched_cfg.max_context)
        self.cache = models.init_cache(cfg, sched_cfg.max_slots,
                                       sched_cfg.max_context)
        self._last_token = np.zeros((sched_cfg.max_slots,), np.int32)

        @jax.jit
        def _prefill(params, tokens, cache, key, temperature):
            # forward + first-token sample in one program: logits are
            # consumed on-device, only the token id comes back
            logits, cache = models.prefill(params, cfg, tokens, cache)
            tok = sampler.sample(logits, key, temperature)
            return tok, cache

        @jax.jit
        def _decode(params, tokens, cache, key, temperature):
            logits, cache = models.decode_step(params, cfg, tokens, cache)
            tok = sampler.sample(logits, key, temperature)
            return tok, cache

        @jax.jit
        def _insert(cache, sub, slot):
            return cache_utils.cache_insert(cache, sub, slot, self._axes)

        @jax.jit
        def _extract(cache, slot):
            return cache_utils.cache_extract(cache, slot, self._axes)

        self._prefill_fn = _prefill
        self._decode_fn = _decode
        self._insert_fn = _insert
        self._extract_fn = _extract

    # ------------------------------------------------------------------ time
    def now(self) -> float:
        return time.monotonic() - self._t0

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    # ------------------------------------------------------------------ step
    def step(self) -> StepKind:
        """Run one scheduler plan synchronously.  Returns the plan kind."""
        if self.paused:
            return StepKind.IDLE
        t_start = time.monotonic()
        plan = self.scheduler.plan_step()
        if plan.kind == StepKind.PREFILL:
            firsts = []
            for work in plan.prefills:
                firsts.append(self._run_prefill(work.req))
                work.chunk = work.req.prompt_len       # real engine: one shot
            self.apply_prefill(plan.prefills, firsts, self.now())
        elif plan.kind == StepKind.DECODE:
            live = [r for r in plan.decodes
                    if self.scheduler.ensure_decode_capacity(r)]
            if live:
                toks = self._run_decode(live)
                self.apply_decode(live, toks, self.now())
        self.steps += 1
        self._step_metrics(time.monotonic() - t_start)
        return plan.kind

    def run_until_idle(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if not self.busy:
                break
            self.step()

    # ---------------------------------------------------------------- prefill
    def _run_prefill(self, req: Request) -> int:
        tokens = jnp.asarray(req.prompt_tokens, jnp.int32)[None, :]
        sub_cache = models.init_cache(self.cfg, 1,
                                      self.scheduler.cfg.max_context)
        tok, sub_cache = self._prefill_fn(self.params, tokens, sub_cache,
                                          self._next_key(),
                                          jnp.float32(self.temperature))
        self.cache = self._insert_fn(self.cache, sub_cache,
                                     jnp.int32(req.slot))
        self._last_token[req.slot] = int(tok[0])
        return int(tok[0])

    # ----------------------------------------------------------------- decode
    def _run_decode(self, reqs: list[Request]) -> list[int]:
        tokens = jnp.asarray(self._last_token[:, None])
        toks, self.cache = self._decode_fn(self.params, tokens, self.cache,
                                           self._next_key(),
                                           jnp.float32(self.temperature))
        toks = np.asarray(toks)
        out = []
        for r in reqs:
            t = int(toks[r.slot])
            self._last_token[r.slot] = t
            out.append(t)
        return out

    # ------------------------------------------------------------ kv transfer
    def extract_state(self, req: Request):
        """(cache-slice pytree, last_token, nbytes) for migration."""
        sub = self._extract_fn(self.cache, jnp.int32(req.slot))
        return {"cache": jax.device_get(sub),
                "last_token": int(self._last_token[req.slot]),
                "nbytes": cache_utils.cache_nbytes(sub)}

    def inject_state(self, req: Request, state: dict) -> None:
        """Install a migrated request into a fresh slot (already admitted:
        req.slot assigned, scheduler pages reserved)."""
        self.cache = self._insert_fn(self.cache, state["cache"],
                                     jnp.int32(req.slot))
        self._last_token[req.slot] = state["last_token"]
        req.state = RequestState.RUNNING
        req.prefilled = req.prompt_len
