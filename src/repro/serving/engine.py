"""Real JAX engine: actual forward passes with slot-batched KV caches.

The decode hot path is ONE fixed-shape jitted step over all slots
(continuous batching, TPU-style: inactive slots ride along as padding so
the compiled executable never changes shape).  Sampling is **fused into
the step**: the jitted function runs forward pass → logits →
greedy/temperature sample and returns int32 token ids, so the (B, V)
logits never leave the device and the only host transfer per step is
the sampled tokens themselves.

Two KV layouts, selected by the ``cache_layout`` knob:

* ``ring``  — the classic slot-contiguous ring buffers.  Prefill always
  recomputes the full prompt into a fresh batch-1 sub-cache, then the
  slice is inserted into the batched cache (serving/cache_utils).
* ``paged`` — one shared page pool per layer, sized by the scheduler's
  ``PageAllocator`` (pool page *i* IS allocator page *i*).  The jitted
  decode step takes the live block tables as a **traced** ``(slots,
  P_max) int32`` input, so admission/eviction/preemption churn never
  recompiles, and decode attention runs ``ops.paged_decode_attention``
  straight over allocator state when ``cfg.use_pallas`` is set.
  Prefill computes only the *uncached suffix* of the prompt: a shared
  prefix acquired from the prefix cache is just page ids in the block
  table — zero KV copies at admission.

Serialized prefill runs per request at its exact suffix length (CPU
container: a handful of lengths per test/example; on TPU you'd bucket).
With the scheduler's ``mixed`` knob on (paged layout only), prefill
stops serializing against decode entirely: one jitted ``_mixed_step``
co-runs every live decode slot with one padded prefill chunk —
fixed-capacity chunk buffer, traced valid length, traced block tables —
so the executable compiles exactly once per engine and a long prompt
never stalls the decode batch.  Slot state surgery uses
serving/cache_utils (ring) or the transformer's
paged_extract/paged_insert bridge (paged); KV migration uses
serving/kv_transfer in both layouts.
"""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs.base import ModelConfig
from repro.core.knobs import KnobSpec
from repro.core.types import Request, RequestState
from repro.serving import cache_utils, sampler
from repro.serving.engine_base import EngineCore
from repro.serving.kv_cache import block_tables
from repro.serving.scheduler import SchedulerConfig, StepKind


class Engine(EngineCore):
    KNOB_SPECS = EngineCore.KNOB_SPECS + (
        KnobSpec("cache_layout", kind="str", choices=("ring", "paged"),
                 attr="_cache_layout", on_change="_cache_layout_changed",
                 doc="KV cache layout: 'ring' slot-contiguous buffers or "
                     "'paged' shared page pool driven by live allocator "
                     "block tables (the Pallas fast path)"),
    )

    def __init__(self, cfg: ModelConfig, params, sched_cfg: SchedulerConfig,
                 name: str = "engine", collector=None, seed: int = 0,
                 cache_layout: str | None = None):
        sched_cfg.require_complete_prompt = True   # one-shot real prefill
        super().__init__(name, cfg.name, sched_cfg, collector)
        self.cfg = cfg
        self.params = params
        self._t0 = time.monotonic()
        self._key = jax.random.key(seed)
        self._axes = cache_utils.batch_axes(cfg, sched_cfg.max_context)
        # fixed block-table width: the allocator can never hand a live
        # sequence more pages than a max_context footprint
        self._p_max = self.scheduler.alloc.pages_for(sched_cfg.max_context)
        if cache_layout is None:
            cache_layout = "paged" if cfg.use_pallas else "ring"
        self._cache_layout = cache_layout
        self._last_token = np.zeros((sched_cfg.max_slots,), np.int32)
        self._build_cache()

        # every step consumes the previous cache and returns the next one,
        # so the cache buffers are donated: the in-place update XLA can do
        # then is what makes the shared page pool (one big buffer per
        # layer, scatter-written every step) cost the same as the ring
        @partial(jax.jit, donate_argnums=(2,))
        def _prefill(params, tokens, cache, key, temperature):
            # forward + first-token sample in one program: logits are
            # consumed on-device, only the token id comes back
            logits, cache = models.prefill(params, cfg, tokens, cache)
            tok = sampler.sample(logits, key, temperature)
            return tok, cache

        @partial(jax.jit, donate_argnums=(2,))
        def _decode(params, tokens, cache, key, temperature):
            logits, cache = models.decode_step(params, cfg, tokens, cache)
            tok = sampler.sample(logits, key, temperature)
            return tok, cache

        @partial(jax.jit, donate_argnums=(2,))
        def _prefill_paged(params, tokens, cache, tables, start, slot, key,
                           temperature):
            logits, cache = models.prefill_paged(params, cfg, tokens, cache,
                                                 tables, start, slot)
            tok = sampler.sample(logits, key, temperature)
            return tok, cache

        @partial(jax.jit, donate_argnums=(2,))
        def _decode_paged(params, tokens, cache, tables, key, temperature):
            logits, cache = models.decode_step(params, cfg, tokens, cache,
                                               tables)
            tok = sampler.sample(logits, key, temperature)
            return tok, cache

        # stall-free mixed step: ALL decode slots + one padded prefill
        # chunk in a single jitted program.  Every input is shape-stable
        # (fixed slot count, fixed chunk capacity, fixed-width block
        # tables; start/valid-length/slot are traced scalars), so the
        # executable compiles exactly once per engine — allocator churn,
        # admission, and varying chunk fill never retrace.  The decode
        # sub-forward runs first (its writes land in the decode
        # sequences' own pages); the prefill chunk then attends into its
        # resident prefix pages and sets its slot's pos absolutely,
        # overwriting the blanket pos+1 the decode bookkeeping applied.
        @partial(jax.jit, donate_argnums=(3,))
        def _mixed_step(params, dec_tokens, pf_tokens, cache, dec_tables,
                        pf_tables, pf_start, pf_n, pf_slot, key, temperature):
            self.mixed_step_traces += 1     # python side effect: runs per
            #                                 TRACE, not per call — the
            #                                 compile-once acceptance gate
            dec_logits, cache = models.decode_step(params, cfg, dec_tokens,
                                                   cache, dec_tables)
            pf_logits, cache = models.prefill_paged_padded(
                params, cfg, pf_tokens, cache, pf_tables, pf_start, pf_slot,
                pf_n)
            kd, kp = jax.random.split(key)
            dec_tok = sampler.sample(dec_logits, kd, temperature)
            pf_tok = sampler.sample(pf_logits, kp, temperature)
            return dec_tok, pf_tok, cache

        @partial(jax.jit, donate_argnums=(0,))
        def _insert(cache, sub, slot):
            return cache_utils.cache_insert(cache, sub, slot, self._axes)

        @jax.jit
        def _extract(cache, slot):
            return cache_utils.cache_extract(cache, slot, self._axes)

        self._prefill_fn = _prefill
        self._decode_fn = _decode
        self._prefill_paged_fn = _prefill_paged
        self._decode_paged_fn = _decode_paged
        self._mixed_fn = _mixed_step
        self._insert_fn = _insert
        self._extract_fn = _extract
        # fixed chunk-buffer capacity for the mixed step, set once at
        # construction so retuning the prefill_chunk knob never changes
        # the compiled shape (knob values above the cap are clamped)
        self._mixed_cap = min(sched_cfg.max_batch_tokens,
                              sched_cfg.max_context)
        self.mixed_step_traces = 0
        if sched_cfg.mixed and self._cache_layout != "paged":
            raise RuntimeError(
                f"{name}: mixed batching needs the paged cache layout "
                f"(got {self._cache_layout!r})")

    # ----------------------------------------------------------- cache layout
    @property
    def cache_layout(self) -> str:
        return self._cache_layout

    def _build_cache(self) -> None:
        sc = self.scheduler.cfg
        if self._cache_layout == "paged":
            self.cache = models.init_cache(
                self.cfg, sc.max_slots, sc.max_context, layout="paged",
                num_pages=sc.num_pages, page_size=sc.page_size)
        else:
            self.cache = models.init_cache(self.cfg, sc.max_slots,
                                           sc.max_context)

    def _cache_layout_changed(self, old: str, new: str) -> None:
        if old == new:
            return
        if self.scheduler.num_running > 0:
            self._cache_layout = old            # revert before failing
            raise RuntimeError(
                f"{self.name}: cache_layout flip needs an idle engine "
                f"({self.scheduler.num_running} sequences running)")
        if new == "ring" and self.scheduler.cfg.mixed:
            self._cache_layout = old            # revert before failing
            raise RuntimeError(
                f"{self.name}: cache_layout 'ring' is incompatible with "
                "mixed batching — set mixed false first")
        self._build_cache()

    def on_knob_set(self, name: str, old, new) -> None:
        if name == "mixed" and new and self._cache_layout != "paged":
            self.scheduler.cfg.mixed = old      # revert before failing
            raise RuntimeError(
                f"{self.name}: mixed batching needs the paged cache "
                f"layout (current: {self._cache_layout!r})")
        super().on_knob_set(name, old, new)

    def _block_table_rows(self, reqs: list[Request]) -> np.ndarray:
        """(max_slots, P_max) int32 table for the jitted step: live rows
        come straight from ``PageAllocator.page_table`` (physical ids in
        logical order); inactive slots are all -1 (their writes land in
        the pool's sink page, their reads mask out)."""
        slots = self.scheduler.cfg.max_slots
        out = np.full((slots, self._p_max), -1, np.int32)
        live = [r for r in reqs if 0 <= r.slot < slots]
        if live:
            rows = block_tables(self.scheduler.alloc,
                                [r.req_id for r in live], width=self._p_max)
            for r, row in zip(live, rows):
                out[r.slot] = row
        return out

    # ------------------------------------------------------------------ time
    def now(self) -> float:
        return time.monotonic() - self._t0

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    # ------------------------------------------------------------------ step
    def step(self) -> StepKind:
        """Run one scheduler plan synchronously.  Returns the plan kind."""
        if self.paused:
            return StepKind.IDLE
        t_start = time.monotonic()
        plan = self.scheduler.plan_step()
        if plan.kind == StepKind.PREFILL:
            firsts = []
            for work in plan.prefills:
                if self._cache_layout == "paged":
                    # the scheduler's chunk is honored as planned: a
                    # chunked prefill spans multiple steps (the
                    # prefill_chunk knob is live on real hardware, not
                    # just in the sim)
                    firsts.append(self._run_prefill_paged(work.req,
                                                          work.chunk))
                else:
                    work.chunk = work.req.prompt_len   # ring: one shot
                    firsts.append(self._run_prefill(work.req))
            self.apply_prefill(plan.prefills, firsts, self.now())
        elif plan.kind == StepKind.MIXED:
            self._run_mixed(plan)
        elif plan.kind == StepKind.DECODE:
            live = [r for r in plan.decodes
                    if self.scheduler.ensure_decode_capacity(r)]
            if live:
                toks = self._run_decode(live)
                self.apply_decode(live, toks, self.now())
        self.steps += 1
        self._step_metrics(time.monotonic() - t_start)
        return plan.kind

    def run_until_idle(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if not self.busy:
                break
            self.step()

    # ---------------------------------------------------------------- prefill
    def _run_prefill(self, req: Request) -> int:
        tokens = jnp.asarray(req.prompt_tokens, jnp.int32)[None, :]
        sub_cache = models.init_cache(self.cfg, 1,
                                      self.scheduler.cfg.max_context)
        tok, sub_cache = self._prefill_fn(self.params, tokens, sub_cache,
                                          self._next_key(),
                                          jnp.float32(self.temperature))
        self.cache = self._insert_fn(self.cache, sub_cache,
                                     jnp.int32(req.slot))
        self._last_token[req.slot] = int(tok[0])
        return int(tok[0])

    def _run_prefill_paged(self, req: Request, chunk: int):
        """Prefill ``chunk`` uncached prompt tokens straight into the
        shared pool, honoring the scheduler's chunked-prefill plan.

        ``req.prefilled`` tokens of prompt are already resident (shared
        prefix pages acquired by id at admission — never copied, or
        earlier chunks of this same prefill); the block-table row lays
        those pages first, so the chunk attends back into resident KV
        through the ordinary paged gather.  Returns the sampled first
        token when this chunk completes the prompt, else None (the
        request keeps prefilling next step)."""
        start = min(req.prefilled, req.prompt_len - 1)
        chunk = min(chunk, req.prompt_len - start)
        tokens = jnp.asarray(req.prompt_tokens[start:start + chunk],
                             jnp.int32)[None, :]
        row = self._block_table_rows([req])[req.slot][None, :]
        tok, self.cache = self._prefill_paged_fn(
            self.params, tokens, self.cache, jnp.asarray(row),
            jnp.full((1,), start, jnp.int32), jnp.int32(req.slot),
            self._next_key(), jnp.float32(self.temperature))
        if start + chunk < req.prompt_len:
            return None                     # chunk not final: no token yet
        self._last_token[req.slot] = int(tok[0])
        return int(tok[0])

    # ------------------------------------------------------------------ mixed
    def _run_mixed(self, plan) -> None:
        """One fused step: every live decode slot advances a token while
        one prefill chunk computes — the stall-free continuous-batching
        hot path.  All jit inputs are shape-stable; see ``_mixed_step``
        in ``__init__``."""
        if self._cache_layout != "paged":
            raise RuntimeError(
                f"{self.name}: mixed batching needs the paged cache "
                f"layout (current: {self._cache_layout!r})")
        work = plan.prefills[0]
        req = work.req
        live = [r for r in plan.decodes
                if self.scheduler.ensure_decode_capacity(r)]
        cap = self._mixed_cap
        chunk = min(work.chunk, cap)
        work.chunk = chunk                  # bookkeeping sees the clamp
        start = req.prefilled
        buf = np.zeros((1, cap), np.int32)
        buf[0, :chunk] = req.prompt_tokens[start:start + chunk]
        dec_tables = self._block_table_rows(live)
        pf_row = self._block_table_rows([req])[req.slot][None, :]
        dec_tok, pf_tok, self.cache = self._mixed_fn(
            self.params, jnp.asarray(self._last_token[:, None]),
            jnp.asarray(buf), self.cache, jnp.asarray(dec_tables),
            jnp.asarray(pf_row), jnp.int32(start), jnp.int32(chunk),
            jnp.int32(req.slot), self._next_key(),
            jnp.float32(self.temperature))
        dec_tok = np.asarray(dec_tok)
        toks = []
        for r in live:
            t = int(dec_tok[r.slot])
            self._last_token[r.slot] = t
            toks.append(t)
        final = (start + chunk) >= req.prompt_len
        first = int(pf_tok[0]) if final else None
        if final:
            self._last_token[req.slot] = first
        now = self.now()
        self.apply_prefill([work], [first], now)
        if live:
            self.apply_decode(live, toks, now)

    # ----------------------------------------------------------------- decode
    def _run_decode(self, reqs: list[Request]) -> list[int]:
        tokens = jnp.asarray(self._last_token[:, None])
        if self._cache_layout == "paged":
            tables = jnp.asarray(self._block_table_rows(reqs))
            toks, self.cache = self._decode_paged_fn(
                self.params, tokens, self.cache, tables, self._next_key(),
                jnp.float32(self.temperature))
        else:
            toks, self.cache = self._decode_fn(
                self.params, tokens, self.cache, self._next_key(),
                jnp.float32(self.temperature))
        toks = np.asarray(toks)
        out = []
        for r in reqs:
            t = int(toks[r.slot])
            self._last_token[r.slot] = t
            out.append(t)
        return out

    # ------------------------------------------------------------ kv transfer
    def extract_state(self, req: Request):
        """(cache-slice pytree, last_token, nbytes) for migration.  Both
        layouts export the same batch-1 ring-format pytree, so the
        transfer plane and the receiving engine never care which layout
        produced it."""
        if self._cache_layout == "paged":
            row = self._block_table_rows([req])[req.slot]
            ctx = int(jax.device_get(self.cache["pos"])[req.slot])
            sub = models.paged_extract(self.cfg, self.cache, row, ctx,
                                       self.scheduler.cfg.max_context,
                                       req.slot)
        else:
            sub = self._extract_fn(self.cache, jnp.int32(req.slot))
        return {"cache": jax.device_get(sub),
                "last_token": int(self._last_token[req.slot]),
                "nbytes": cache_utils.cache_nbytes(sub)}

    def inject_state(self, req: Request, state: dict) -> None:
        """Install a migrated request into a fresh slot (already admitted:
        req.slot assigned, scheduler pages reserved)."""
        if self._cache_layout == "paged":
            row = self._block_table_rows([req])[req.slot]
            self.cache = models.paged_insert(self.cfg, self.cache,
                                             state["cache"], row,
                                             req.slot)
        else:
            self.cache = self._insert_fn(self.cache, state["cache"],
                                         jnp.int32(req.slot))
        self._last_token[req.slot] = state["last_token"]
        req.state = RequestState.RUNNING
        req.prefilled = req.prompt_len
