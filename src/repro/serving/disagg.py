"""Disaggregated prefill/decode plane — software-defined engine roles.

The paper's thesis is that serving attributes should be programmable
from runtime state rather than statically encoded.  This module applies
that to *disaggregation itself*: instead of deploying a fixed prefill
fleet and a fixed decode fleet, every engine carries a ``role`` knob
(``unified | prefill | decode``) on the ordinary Table-1 surface, and a
``DisaggPool`` turns a fleet of role-knobbed engines into one serving
entry point:

* the pool's ``disagg`` router picks the prefill-capable engine with
  the shallowest prefill queue and — when that engine is prefill-role —
  pre-pins the paired decode engine, so the KV handoff session opens
  *before the first token exists*;
* as prefill advances, the ``KVTransferManager`` handoff pipeline
  streams KV chunks to the pinned decode engine (transfer overlaps the
  tail of prefill); at prefill completion the first token is emitted on
  the prefill engine (TTFT), the sequence is released, and the tail
  chunk rides the link;
* the decode engine admits through the generalized ``admit_direct``
  path, gated on KV residency, and carries the decode tail to
  completion — its ``on_finish`` chain fires exactly as if the request
  had lived there all along.

Because the role is a knob, a ``RoleBalancerPolicy`` (core/policies.py)
or an intent rule (``on cluster.prefill_pressure > 2 => set engine
e2.role prefill``) can re-partition the fleet at runtime; the pool
drains role-inconsistent work on every flip — RUNNING decodes migrate
off a newly-prefill engine, un-admitted prompts bounce off a
newly-decode engine — so no request is ever lost.
"""
from __future__ import annotations

from typing import Optional

from repro.core.metrics import FleetAggregate
from repro.core.types import Message, Request, RequestState
from repro.serving.engine_base import EngineCore
from repro.serving.kv_transfer import KVTransferManager
from repro.serving.router import Router, pick_decode_engine
from repro.sim.clock import EventLoop


class EngineEndpoint:
    """Router endpoint adapting one engine of the pool: messages carry a
    prebuilt ``Request`` (the pool's routing unit)."""

    def __init__(self, engine: EngineCore):
        self.engine = engine
        self.name = engine.name

    def deliver(self, msg: Message) -> None:
        self.engine.submit((msg.payload or {})["request"])

    def load(self) -> float:
        return self.engine.load()


class DisaggPool:
    """One serving entry point over a fleet of role-knobbed engines
    (see module docstring)."""

    def __init__(self, loop: EventLoop, engines: list[EngineCore],
                 kvx: KVTransferManager, collector=None,
                 name: str = "disagg", cluster_prefix: str = "cluster",
                 tenants=None, tracer=None):
        self.loop = loop
        self.name = name
        self.engines = {e.name: e for e in engines}
        self.kvx = kvx
        self.collector = collector
        self.tenants = tenants           # TenantDirectory | None
        self.tracer = tracer             # tracing plane | None
        self.router = Router(loop, f"{name}.router", policy="disagg",
                             collector=collector, tenants=tenants)
        self.router.on_dispatch = self._dispatched
        if tracer is not None:
            self.router.tracer = tracer
            kvx.tracer = tracer
            for e in engines:
                e.tracer = tracer
        if tenants is not None:
            # one directory serves the fleet: schedulers read fairness
            # weights, engines report per-tenant TTFT through it
            for e in engines:
                e.scheduler.attach_tenants(tenants)
        self._backlog: dict[str, list[tuple[Request, dict]]] = {}
        self.finished: list[Request] = []
        self.handoffs = 0
        self.migrations = 0          # role-flip drains (reactive handoffs)
        self.on_finish = None        # optional user callback (req, t)
        for e in engines:
            self._wire(e)
            self.router.add_instance(EngineEndpoint(e), engine=e)
        self._wire_cluster_gauges(cluster_prefix)

    # -- wiring -------------------------------------------------------------
    def _wire(self, e: EngineCore) -> None:
        e.disagg = self
        # a victim preempted on a decode-role engine could never be
        # re-admitted locally (decode role blocks waiting-queue
        # admission): bounce it back through the router to re-prefill
        e.scheduler.bounce_fn = self.resubmit
        e.kv_ready_fn = (
            lambda req, n=e.name: self.kvx.handoff_wait(req.req_id, n))
        e.on_prefill_progress = (
            lambda req, t: self.kvx.handoff_progress(req.req_id,
                                                     req.prefilled))
        e.on_prefill_done = (
            lambda req, t, e=e: self._prefill_done(e, req, t))
        prev_finish = e.on_finish
        def _fin(req, t, e=e, prev=prev_finish):
            self._finished(e, req, t)
            if prev is not None:
                prev(req, t)
        e.on_finish = _fin

    def _wire_cluster_gauges(self, prefix: str) -> None:
        """Fleet-level derived gauges the RoleBalancerPolicy / intent
        triggers consume: total prefill backlog, mean decode slot
        utilization, and the normalized prefill pressure (steps of
        backlog relative to the fleet's per-step prefill budget)."""
        if self.collector is None or self.collector.bus is None:
            self.fleet = None
            return
        names = list(self.engines)
        budget = sum(e.scheduler.cfg.max_batch_tokens
                     for e in self.engines.values())
        self.fleet = FleetAggregate(self.collector, prefix=prefix)
        self.fleet.watch("prefill_queue_tokens",
                         [f"{n}.prefill_queue_tokens" for n in names],
                         how="sum")
        self.fleet.watch("decode_slot_util",
                         [f"{n}.decode_slot_util" for n in names],
                         how="mean")
        self.fleet.watch("prefill_pressure",
                         [f"{n}.prefill_queue_tokens" for n in names],
                         how="sum", scale=1.0 / max(budget, 1))

    # -- role inventory -----------------------------------------------------
    def roles(self) -> dict[str, str]:
        return {n: e.role for n, e in self.engines.items()}

    def _pick_decode(self, exclude: Optional[str] = None) -> Optional[str]:
        # same criterion the router's pre-pin uses (router.py)
        return pick_decode_engine(self.engines, exclude=exclude)

    # -- workload entry -----------------------------------------------------
    def submit(self, req: Request, session: Optional[str] = None,
               _remeter: bool = True) -> None:
        msg = Message(src="client", dst=self.router.name,
                      payload={"request": req,
                               "session": session or req.req_id},
                      task_id=req.req_id, created_at=self.loop.now(),
                      tokens=req.prompt_len,      # meter by prompt size
                      tenant=req.tenant, slo_class=req.slo_class)
        if not _remeter:
            # internal re-route (role-flip bounce): already charged
            # through the tenant bucket on first admission
            self.router.exempt(msg.msg_id)
        # the clock starts at submission: time held by the tenant meter
        # is part of the request's TTFT/latency, not invisible to it
        if not req.meta.get("arrived"):
            req.meta["arrived"] = True
            req.arrival_time = self.loop.now()
        self.router.deliver(msg)

    def _dispatched(self, msg: Message, inst: str) -> None:
        """Router dispatch hook: runs when the message actually lands on
        an engine — including messages released from the throttle/held
        queues later, whose pre-pin would otherwise never be consumed
        (and the proactive handoff never opened)."""
        req = (msg.payload or {}).get("request")
        if req is None:
            return
        pair = self.router.pair_for(req.req_id)
        if pair is not None:
            src, dst = pair
            if src != dst:
                # pre-pinned decode engine: open the handoff session NOW
                # so prefill-progress chunks stream before first token
                self.kvx.start_handoff(req.req_id, src, dst)

    def resubmit(self, req: Request) -> None:
        """A decode-role engine bounced a fresh prompt back: route it to
        a prefill-capable engine.  Loud failure when the fleet has none
        (a misconfigured all-decode fleet would otherwise starve)."""
        if all(e.role == "decode" for e in self.engines.values()):
            raise RuntimeError(
                f"{self.name}: no prefill-capable engine for {req.req_id}")
        n = req.meta.get("disagg_reroutes", 0) + 1
        req.meta["disagg_reroutes"] = n
        if n > len(self.engines) + 1:
            # a routing rule keeps pinning this request to a decode
            # engine: surface the conflict instead of ping-ponging
            raise RuntimeError(
                f"{self.name}: {req.req_id} cannot reach a "
                "prefill-capable engine (conflicting route rule?)")
        self.kvx.end_handoff(req.req_id)     # stale pre-pin, if any
        self.submit(req, _remeter=False)

    # -- handoff state machine ----------------------------------------------
    def _prefill_done(self, eng: EngineCore, req: Request, t: float) -> None:
        """Prefill-role engine finished a prompt: release it there and
        finish the (possibly pre-streamed) handoff to its decode pair."""
        rec = self.kvx.handoff_records.get(req.req_id)
        dst_name = rec.dst if rec is not None else None
        if (dst_name is None or dst_name not in self.engines
                or self.engines[dst_name].role == "prefill"):
            dst_name = self._pick_decode(exclude=eng.name)
        if dst_name is None:
            raise RuntimeError(
                f"{self.name}: no decode-capable engine for {req.req_id}")
        self._handoff_to(eng, req, dst_name)
        self.handoffs += 1
        if self.collector is not None:
            self.collector.counter(f"{self.name}.handoffs", 1, t)

    def _handoff_to(self, eng: EngineCore, req: Request,
                    dst_name: str) -> None:
        state = eng.extract_state(req)
        eng.release_for_handoff(req)
        dst = self.engines[dst_name]
        self.kvx.finish_handoff(
            req.req_id, eng.name, dst_name, req.total_len,
            on_ready=lambda: self._arrive(dst, req, state))

    def _arrive(self, dst: EngineCore, req: Request, state: dict) -> None:
        """Handoff KV landed at the decode engine: admit (generalized
        admit_direct, residency-gated), queue for retry when a slot
        frees up, or re-home if the engine left decode duty while the
        tail was in flight."""
        if req.state is not RequestState.HANDOFF:
            self.kvx.end_handoff(req.req_id)   # finished/failed in flight
            return
        if dst.role == "prefill":
            # the pinned engine flipped while the KV was on the wire:
            # backlogging here would strand the request (a prefill-role
            # engine never admits decodes and rarely finishes anything)
            self._rehome(dst.name, req, state)
            return
        if dst.receive_handoff(req, state):
            self.kvx.end_handoff(req.req_id)
        else:
            self._backlog.setdefault(dst.name, []).append((req, state))

    def _rehome(self, old_name: str, req: Request, state: dict) -> None:
        """Re-target an in-flight/landed handoff whose decode engine is
        no longer decode-capable (its KV restreams to the new target)."""
        dst_name = self._pick_decode(exclude=old_name)
        if dst_name is None:
            raise RuntimeError(
                f"{self.name}: no decode-capable engine for {req.req_id}")
        dst = self.engines[dst_name]
        self.kvx.finish_handoff(
            req.req_id, old_name, dst_name, req.total_len,
            on_ready=lambda: self._arrive(dst, req, state))

    def _drain_backlog(self, eng: EngineCore) -> None:
        backlog = self._backlog.get(eng.name)
        if not backlog:
            return
        if eng.role == "prefill":
            # the engine left decode duty with arrivals still queued:
            # re-home them (their KV restreams to the new target)
            for req, state in backlog:
                self._rehome(eng.name, req, state)
            backlog.clear()
            return
        keep = []
        for req, state in backlog:
            if req.state is not RequestState.HANDOFF:
                self.kvx.end_handoff(req.req_id)   # abandoned in flight
                continue
            if eng.receive_handoff(req, state):
                self.kvx.end_handoff(req.req_id)
            else:
                keep.append((req, state))
        self._backlog[eng.name] = keep

    # -- engine callbacks ---------------------------------------------------
    def _finished(self, eng: EngineCore, req: Request, t: float) -> None:
        # any open handoff session for a finished request is moot — e.g.
        # a pre-pinned request done at its first token (max_new_tokens
        # == 1) never reaches the handoff path, but its record (and the
        # chunks already streamed) must not outlive it
        self.kvx.end_handoff(req.req_id)
        self.finished.append(req)
        if self.on_finish is not None:
            self.on_finish(req, t)
        self._drain_backlog(eng)         # a slot just freed up

    # -- role transitions ---------------------------------------------------
    def on_role_change(self, eng: EngineCore, old: str, new: str) -> None:
        """Drain work that is inconsistent with the engine's new role.
        Flipping to ``prefill``: RUNNING decodes migrate to a decode
        engine (reactive handoff of their full state) and queued
        arrivals re-home.  Flipping to ``decode``: un-admitted prompts
        bounce back through the router to a prefill-capable engine;
        admitted PREFILL sequences are grandfathered (they finish
        prefill here and decode in place — the KV is already local)."""
        if new == "prefill":
            running = [r for r in list(eng.scheduler.running)
                       if r.state is RequestState.RUNNING]
            for r in running:
                dst_name = self._pick_decode(exclude=eng.name)
                if dst_name is None:
                    raise RuntimeError(
                        f"{self.name}: cannot flip {eng.name} to prefill "
                        "— no decode-capable engine to drain to")
                # no start_handoff here: _handoff_to's finish_handoff
                # creates (or re-targets) the record itself
                self._handoff_to(eng, r, dst_name)
                self.migrations += 1
            self._drain_backlog(eng)
        elif new == "decode":
            waiting, eng.scheduler.waiting = eng.scheduler.waiting, []
            for r in waiting:
                self.resubmit(r)
            # admitted PREFILL sequences are grandfathered: they finish
            # prefill here and decode in place, so their open handoff
            # sessions are moot — same cleanup as the unified flip
            self._drop_local_handoffs(eng)
        elif new == "unified":
            # sequences mid-prefill here will now decode in place: any
            # handoff session opened for them is moot
            self._drop_local_handoffs(eng)

    def _drop_local_handoffs(self, eng: EngineCore) -> None:
        """Drop unfinished handoff records whose source sequences will
        now decode locally on ``eng`` — stops further chunk streaming to
        a stale destination and keeps the record table bounded."""
        local = {r.req_id for r in eng.scheduler.running}
        local |= {r.req_id for r in eng.scheduler.waiting}
        for req_id, rec in list(self.kvx.handoff_records.items()):
            if rec.src == eng.name and not rec.finished \
                    and req_id in local:
                self.kvx.end_handoff(req_id)
