"""Sim engine: identical scheduler/lifecycle semantics, virtual-clock
timing from the roofline cost model.

Every Fig-3/6/7 benchmark runs on this substrate: step durations are the
CostModel's three-term roofline for the *paper-scale* agent (7B-class by
default), so load sweeps are deterministic, hardware-honest, and fast on
the CPU container.  The controller cannot tell sim and real engines apart
— both expose the same knobs/metrics/transfer surface.
"""
from __future__ import annotations


from repro.core.types import Request, RequestState
from repro.serving.engine_base import EngineCore
from repro.serving.scheduler import SchedulerConfig, StepKind
from repro.sim.clock import EventLoop
from repro.sim.costmodel import CostModel


class SimEngine(EngineCore):
    def __init__(self, loop: EventLoop, costmodel: CostModel,
                 sched_cfg: SchedulerConfig, name: str = "sim-engine",
                 collector=None):
        super().__init__(name, costmodel.cfg.name, sched_cfg, collector)
        self.loop = loop
        self.cm = costmodel
        self._stepping = False
        self.busy_time = 0.0

    def now(self) -> float:
        return self.loop.now()

    # ------------------------------------------------------------------ drive
    def kick(self) -> None:
        if not self._stepping and not self.paused:
            self._begin_step()

    def _begin_step(self) -> None:
        plan = self.scheduler.plan_step()
        if plan.kind == StepKind.IDLE:
            return
        self._stepping = True
        if plan.kind == StepKind.PREFILL:
            # chunks cover only uncached tokens (the scheduler starts
            # ``prefilled`` past the cached prefix), so prefix-cache hits
            # shrink step time; the resident context still costs KV reads
            dur = sum(self.cm.prefill_time(w.chunk, context=w.req.prefilled)
                      for w in plan.prefills)
            self.loop.call_after(dur, lambda: self._finish_prefill(plan, dur))
        elif plan.kind == StepKind.MIXED:
            # fused prefill-chunk + decode-batch step, priced by the
            # CostModel's mixed roofline (weights read once) — the sim
            # substrate sees the same semantics as the real engine's
            # jitted mixed step
            live = [r for r in plan.decodes
                    if self.scheduler.ensure_decode_capacity(r)]
            w = plan.prefills[0]
            ctx = (sum(r.total_len for r in live) / len(live)
                   if live else 0.0)
            dur = self.cm.mixed_time(w.chunk, w.req.prefilled,
                                     len(live), ctx)
            self.loop.call_after(
                dur, lambda: self._finish_mixed(plan, live, dur))
        else:
            live = [r for r in plan.decodes
                    if self.scheduler.ensure_decode_capacity(r)]
            if not live:
                self._stepping = False
                return
            ctx = sum(r.total_len for r in live) / len(live)
            dur = self.cm.decode_time(len(live), ctx)
            self.loop.call_after(dur, lambda: self._finish_decode(live, dur))

    def _finish_prefill(self, plan, dur: float) -> None:
        firsts = []
        for w in plan.prefills:
            final = (w.req.prefilled + w.chunk) >= w.req.prompt_len
            firsts.append(w.req.generated if final else None)  # synthetic id
        self.apply_prefill(plan.prefills, firsts, self.now())
        self._end_step(dur)

    def _finish_mixed(self, plan, live, dur: float) -> None:
        firsts = []
        for w in plan.prefills:
            final = (w.req.prefilled + w.chunk) >= w.req.prompt_len
            firsts.append(w.req.generated if final else None)
        self.apply_prefill(plan.prefills, firsts, self.now())
        if live:
            self.apply_decode(live, [r.generated for r in live], self.now())
        self._end_step(dur)

    def _finish_decode(self, reqs, dur: float) -> None:
        toks = [r.generated for r in reqs]        # synthetic token ids
        self.apply_decode(reqs, toks, self.now())
        self._end_step(dur)

    def _end_step(self, dur: float) -> None:
        self.steps += 1
        self.busy_time += dur
        self._step_metrics(dur)
        self._stepping = False
        if not self.paused:
            self._begin_step()

    # ------------------------------------------------------------ kv transfer
    def extract_state(self, req: Request) -> dict:
        return {"cache": None, "last_token": 0,
                "nbytes": self.cm.kv_transfer_bytes(req.total_len)}

    def inject_state(self, req: Request, state: dict) -> None:
        # continuation resumes may carry appended, un-prefilled prompt
        # tokens (a tool result): keep ``prefilled`` where the suspend
        # left it and land in PREFILL so their ingestion is charged;
        # handoffs arrive fully prefilled and go straight to RUNNING
        req.prefilled = min(req.prefilled, req.prompt_len)
        req.state = (RequestState.PREFILL
                     if req.prefilled < req.prompt_len
                     else RequestState.RUNNING)
        self.kick()

    # ----------------------------------------------------- tool-call plane
    def restore_cost(self, req: Request) -> float:
        """Virtual-clock price of the host→HBM refill a warm resume pays
        (pinned or recompute resumes move no host KV)."""
        if req.req_id in self._host_store \
                or self.scheduler.alloc.is_suspended(req.req_id):
            return self.cm.restore_time(req.total_len)
        return 0.0
