"""Prefix-sharing KV cache plane (cross-request prefill reuse).

Agentic pipelines send near-identical system/task prefixes to the same
engines thousands of times; re-prefilling them from scratch is the
dominant wasted work in agent serving.  This module makes the prefix
cache a *programmable plane* in the paper's sense: reuse is the
mechanism, but eviction, pinning and reservation are **knobs**, hit rate
is a **metric** on the bus, and pin/unpin are **intent actions**.

* ``PrefixCache`` — per-engine block-hash radix index over token
  prefixes, layered on the refcount-capable ``PageAllocator``
  (serving/kv_cache.py).  Blocks are page-aligned; a request *acquires*
  every resident block of its prompt prefix at admission (the scheduler
  then charges only uncached tokens against its prefill budget) and new
  blocks are *promoted* out of the sequence's private pages when prefill
  completes.  Pluggable eviction (LRU / LFU over idle blocks; pinned
  blocks are never evicted), a ``reserve_frac`` cap on idle cache pages,
  and a ControlSurface with the paper's Table-1 knobs.
* ``CacheDirectory`` — the controller-visible map prefix digest →
  instances where the blocks are resident (mirror of ``SessionDirectory``
  in kv_transfer.py).  The router's ``cache_aware`` policy and the intent
  language's ``pin``/``unpin`` actions go through it.

Prefix identity is a digest chain.  Real engines hash actual token-id
blocks; the sim (which carries token *counts*, not contents) describes a
prompt as labelled segments — ``(("system-prompt", 512), ("sess:a", 96))``
— and the chain is derived from the labels covering each block, so two
prompts share exactly the blocks whose covering spans agree.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Callable, Iterable, Optional, Sequence

from repro.core.knobs import ControlSurface, KnobSpec
from repro.serving.kv_cache import PageAllocator

# A prefix source is either labelled segments ((label, n_tokens), ...)
# or a concrete token-id sequence (real engine path).
PrefixSource = Sequence


def _digest(parent: str, payload: str) -> str:
    return hashlib.sha1((parent + "|" + payload).encode()).hexdigest()[:16]


@dataclass(frozen=True)
class BlockId:
    """One block of the digest chain: identity + the segment labels that
    cover it (labels are what ``pin system-prompt`` matches against)."""

    digest: str
    labels: tuple[str, ...]


def chain_for(source: PrefixSource, block_tokens: int) -> list[BlockId]:
    """Digest chain over full blocks of ``source``.

    Segments path: block ``i`` covers token span [i·B, (i+1)·B); its
    digest hashes the (label, in-segment offset) spans covering it, so
    equality holds exactly when the labelled content agrees position by
    position.  Token path: digest hashes the raw ids in the block.
    """
    if not source:
        return []
    first = source[0]
    if isinstance(first, (tuple, list)) and len(first) == 2 \
            and isinstance(first[0], str):
        return _chain_segments(source, block_tokens)
    return _chain_tokens(source, block_tokens)


def _chain_tokens(tokens: Sequence[int], block_tokens: int) -> list[BlockId]:
    out, parent = [], ""
    for i in range(0, (len(tokens) // block_tokens) * block_tokens,
                   block_tokens):
        blk = ",".join(str(int(t)) for t in tokens[i:i + block_tokens])
        parent = _digest(parent, blk)
        out.append(BlockId(parent, ()))
    return out


def _chain_segments(segments: Iterable, block_tokens: int) -> list[BlockId]:
    # materialize (label, offset_in_segment) span boundaries per block
    spans: list[tuple[str, int, int]] = []     # (label, seg_start, seg_end)
    total = 0
    for label, n in segments:
        n = int(n)
        if n <= 0:
            continue
        spans.append((str(label), total, total + n))
        total += n
    out, parent = [], ""
    for i in range(total // block_tokens):
        lo, hi = i * block_tokens, (i + 1) * block_tokens
        cover = [(lab, max(lo, s) - s, min(hi, e) - s)
                 for lab, s, e in spans if s < hi and e > lo]
        payload = ";".join(f"{lab}:{a}:{b}" for lab, a, b in cover)
        parent = _digest(parent, payload)
        out.append(BlockId(parent, tuple(lab for lab, _, _ in cover)))
    return out


@dataclass
class CacheEntry:
    """Metadata for one resident block (residency itself lives in the
    allocator; this is what eviction policies rank)."""

    block: BlockId
    parent: Optional[str]
    pages: int
    tokens: int
    last_used: float = 0.0
    uses: int = 0
    pinned: bool = False


class PrefixCache(ControlSurface):
    """Per-engine prefix index + eviction policy + control surface."""

    kind = "cache"
    CAPABILITIES = ("pin", "evict")
    METRICS = ("hit_rate", "saved_prefill_tokens", "shared_pages")
    KNOB_SPECS = (
        KnobSpec("enabled", kind="bool",
                 doc="prefix reuse on/off (off: admission never matches)"),
        KnobSpec("evict_policy", kind="str", choices=("lru", "lfu"),
                 doc="ranking for idle-block eviction"),
        KnobSpec("reserve_frac", kind="float", lo=0.0, hi=1.0,
                 on_change="_reserve_changed",
                 doc="max fraction of the page pool idle cache blocks "
                     "may occupy"),
        KnobSpec("min_block_tokens", kind="int", lo=1, attr="block_tokens",
                 doc="requested block size; effective size is the next "
                     "page multiple"),
    )

    def __init__(self, alloc: PageAllocator, name: str = "cache",
                 instance: str = "", block_tokens: int = 64,
                 enabled: bool = True, evict_policy: str = "lru",
                 reserve_frac: float = 0.5,
                 directory: Optional["CacheDirectory"] = None,
                 collector=None, clock: Optional[Callable[[], float]] = None):
        self.alloc = alloc
        self.name = name
        self.instance = instance or name
        self.block_tokens = int(block_tokens)
        self.enabled = bool(enabled)
        self.evict_policy = evict_policy
        self.reserve_frac = float(reserve_frac)
        self.directory = directory
        self.collector = collector
        self._clock = clock or (lambda: 0.0)
        self._entries: dict[str, CacheEntry] = {}
        self._children: dict[str, set[str]] = {}
        self._inflight: dict[str, list[BlockId]] = {}   # seq -> full chain
        self._hit_blocks: dict[str, int] = {}           # seq -> blocks hit
        self._seq_shared: dict[str, int] = {}           # seq -> shared tokens
        self._pinned_labels: set[str] = set()
        self.hit_tokens = 0
        self.miss_tokens = 0
        self.lookups = 0
        self.evictions = 0
        if directory is not None:
            directory.attach(self)

    # -- knob hooks ---------------------------------------------------------
    def _surface_now(self) -> float:
        return self._clock()

    def _reserve_changed(self, old, new) -> None:
        self.enforce_reserve()

    # -- geometry -----------------------------------------------------------
    @property
    def eff_block_tokens(self) -> int:
        """Blocks are page-aligned so shared pages never straddle a
        private page: the requested size rounds up to a page multiple."""
        ps = self.alloc.page_size
        return -(-max(self.block_tokens, 1) // ps) * ps

    @property
    def pages_per_block(self) -> int:
        return self.eff_block_tokens // self.alloc.page_size

    # -- prefix identity -----------------------------------------------------
    @staticmethod
    def request_source(req) -> Optional[PrefixSource]:
        src = (req.meta or {}).get("prefix")
        if src is not None:
            return src
        if req.prompt_tokens is not None:
            return list(req.prompt_tokens)
        return None

    def chain(self, source: PrefixSource) -> list[BlockId]:
        return chain_for(source, self.eff_block_tokens)

    # -- lookups -------------------------------------------------------------
    def probe(self, source: Optional[PrefixSource],
              limit: Optional[int] = None) -> int:
        """Tokens of ``source``'s prefix resident here (no side effects)."""
        if not self.enabled or source is None:
            return 0
        bt, hit = self.eff_block_tokens, 0
        for i, blk in enumerate(self.chain(source)):
            end = (i + 1) * bt
            if limit is not None and end > limit:
                break
            if not self.alloc.block_resident(blk.digest):
                break
            hit = end
        return hit

    def probe_request(self, req, limit: Optional[int] = None) -> int:
        return self.probe(self.request_source(req), limit=limit)

    # -- admission-side ------------------------------------------------------
    def begin(self, req, limit: Optional[int] = None) -> int:
        """Match + acquire at admission.  Returns cached prefix tokens;
        the scheduler starts ``req.prefilled`` there and charges only the
        remainder.  The full chain is remembered for ``commit``."""
        source = self.request_source(req)
        if not self.enabled or source is None:
            return 0
        now = self._clock()
        chain = self.chain(source)
        bt, hit_blocks = self.eff_block_tokens, 0
        for i, blk in enumerate(chain):
            end = (i + 1) * bt
            if limit is not None and end > limit:
                break
            if not self.alloc.block_resident(blk.digest):
                break
            self.alloc.acquire(req.req_id, blk.digest)
            ent = self._entries.get(blk.digest)
            if ent is not None:
                ent.last_used = now
                ent.uses += 1
            hit_blocks = i + 1
        self._inflight[req.req_id] = chain
        self._hit_blocks[req.req_id] = hit_blocks
        hit = hit_blocks * bt
        self._seq_shared[req.req_id] = hit
        self.lookups += 1
        self.hit_tokens += hit
        self.miss_tokens += max(req.prompt_len - hit, 0)
        self._publish()
        return hit

    def commit(self, req) -> int:
        """Prefill finished: promote the freshly-computed full blocks out
        of the sequence's private pages into shared, refcounted blocks.
        Returns the number of blocks newly inserted."""
        chain = self._inflight.get(req.req_id)
        if chain is None or not self.enabled:
            return 0
        now = self._clock()
        bt, ppb = self.eff_block_tokens, self.pages_per_block
        inserted = 0
        parent = None
        start = self._hit_blocks.get(req.req_id, 0)
        for i, blk in enumerate(chain):
            if i < start:
                parent = blk.digest
                continue
            if (i + 1) * bt > req.prefilled:
                break
            if self.alloc.block_resident(blk.digest):
                # raced in via a sibling request: just reference it
                self.alloc.acquire(req.req_id, blk.digest)
            elif self.alloc.promote(req.req_id, blk.digest, ppb):
                self._entries[blk.digest] = CacheEntry(
                    blk, parent, ppb, bt, last_used=now, uses=1,
                    pinned=any(l in self._pinned_labels for l in blk.labels))
                if parent is not None:
                    self._children.setdefault(parent, set()).add(blk.digest)
                if self.directory is not None:
                    self.directory.note_insert(blk.digest, self.instance)
                inserted += 1
            else:
                break                    # private pages exhausted — stop
            self._seq_shared[req.req_id] = (i + 1) * bt
            parent = blk.digest
        self._publish()
        return inserted

    def shared_tokens(self, seq_id: str) -> int:
        """Prompt tokens of ``seq_id`` living in shared blocks — the
        scheduler subtracts these when sizing private page growth."""
        return self._seq_shared.get(seq_id, 0)

    def seq_done(self, seq_id: str) -> None:
        """Sequence released (finish/preempt): drop per-seq state and
        trim idle pages back under the reservation cap."""
        self._inflight.pop(seq_id, None)
        self._hit_blocks.pop(seq_id, None)
        self._seq_shared.pop(seq_id, None)
        self.enforce_reserve()

    # -- eviction ------------------------------------------------------------
    def _evictable(self) -> list[CacheEntry]:
        out = []
        for d, ent in self._entries.items():
            if ent.pinned or self.alloc.block_refs(d) > 0:
                continue
            kids = self._children.get(d)
            if kids and any(k in self._entries for k in kids):
                continue                 # leaf-first: keep chains walkable
            out.append(ent)
        return out

    def evict_one(self) -> bool:
        cands = self._evictable()
        if not cands:
            return False
        if self.evict_policy == "lfu":
            victim = min(cands, key=lambda e: (e.uses, e.last_used))
        else:                            # lru
            victim = min(cands, key=lambda e: (e.last_used, e.uses))
        d = victim.block.digest
        if not self.alloc.drop_block(d):
            return False
        del self._entries[d]
        if victim.parent is not None:
            kids = self._children.get(victim.parent)
            if kids:
                kids.discard(d)
        self._children.pop(d, None)
        if self.directory is not None:
            self.directory.note_evict(d, self.instance)
        self.evictions += 1
        self._publish()
        return True

    def make_room(self, tokens: int) -> bool:
        """Evict idle blocks until ``tokens`` fit; False if impossible."""
        while not self.alloc.can_allocate(tokens):
            if not self.evict_one():
                return False
        return True

    def enforce_reserve(self) -> None:
        cap = int(self.reserve_frac * self.alloc.num_pages)
        while self.alloc.idle_pages > cap:
            if not self.evict_one():
                break

    def clear(self) -> None:
        while self.evict_one():
            pass

    # -- pinning (intent `pin`/`unpin` actions) -----------------------------
    def pin(self, label: str) -> int:
        """Pin every block covered by segment ``label`` (and blocks that
        arrive later carrying it): exempt from eviction."""
        self._pinned_labels.add(label)
        n = 0
        for ent in self._entries.values():
            if label in ent.block.labels and not ent.pinned:
                ent.pinned = True
                n += 1
        return n

    def unpin(self, label: str) -> int:
        self._pinned_labels.discard(label)
        n = 0
        for ent in self._entries.values():
            if label in ent.block.labels and ent.pinned:
                ent.pinned = any(l in self._pinned_labels
                                 for l in ent.block.labels)
                n += not ent.pinned
        return n

    # -- metrics -------------------------------------------------------------
    @property
    def hit_rate(self) -> float:
        seen = self.hit_tokens + self.miss_tokens
        return self.hit_tokens / seen if seen else 0.0

    @property
    def saved_prefill_tokens(self) -> int:
        return self.hit_tokens

    @property
    def blocks_resident(self) -> int:
        return len(self._entries)

    def _publish(self) -> None:
        if self.collector is None:
            return
        t = self._clock()
        self.collector.gauge(f"{self.name}.hit_rate", self.hit_rate, t)
        self.collector.gauge(f"{self.name}.saved_prefill_tokens",
                             self.saved_prefill_tokens, t)
        self.collector.gauge(f"{self.name}.shared_pages",
                             self.alloc.shared_pages, t)


class CacheDirectory:
    """Controller-visible residency map: prefix digest → instances where
    the block is resident (the ``SessionDirectory`` of the cache plane).

    The ``cache_aware`` router policy scores placements through it.
    (The intent actions ``pin PREFIX`` / ``unpin PREFIX`` reach the
    instance caches directly via the registry's ``pin`` capability —
    see ``ControlContext.pin`` in core/controller.py.)"""

    def __init__(self):
        self.caches: dict[str, PrefixCache] = {}
        self._where: dict[str, set[str]] = {}

    # -- membership ----------------------------------------------------------
    def attach(self, cache: PrefixCache) -> None:
        self.caches[cache.instance] = cache
        cache.directory = self

    def detach(self, instance: str) -> None:
        self.caches.pop(instance, None)
        for insts in self._where.values():
            insts.discard(instance)

    # -- residency bookkeeping (called by instance caches) ------------------
    def note_insert(self, digest: str, instance: str) -> None:
        self._where.setdefault(digest, set()).add(instance)

    def note_evict(self, digest: str, instance: str) -> None:
        insts = self._where.get(digest)
        if insts is not None:
            insts.discard(instance)
            if not insts:
                del self._where[digest]

    def where(self, digest: str) -> set[str]:
        return set(self._where.get(digest, ()))

    def resident_blocks(self, instance: str) -> int:
        cache = self.caches.get(instance)
        return cache.blocks_resident if cache is not None else 0

    # -- routing / control queries -------------------------------------------
    def estimate_hit(self, source: Optional[PrefixSource],
                     instance: str) -> int:
        """Prefix tokens of ``source`` already resident at ``instance`` —
        the cache-aware router's placement score."""
        cache = self.caches.get(instance)
        if cache is None:
            return 0
        return cache.probe(source)
