"""Continuous-batching scheduler (shared by the real and sim engines).

Each engine step executes one ``StepPlan``:
  * PREFILL — one or more waiting/preempted requests get (a chunk of)
    their prompt processed, bounded by ``max_batch_tokens``;
  * DECODE  — every running sequence advances one token (fixed-shape
    batched step on TPU).

Admission takes page capacity (PageAllocator) and the priority floor into
account; decode-time page growth failures preempt the lowest-priority
youngest sequence (its pages are freed, the request re-queues — or the
controller migrates it to another instance via kv_transfer first).

When a ``PrefixCache`` (serving/prefix_cache.py) is attached, admission
consults the prefix index first: resident blocks are acquired (shared,
refcounted pages), ``req.prefilled`` starts past the cached prefix, and
only *uncached* prompt tokens are charged against ``max_batch_tokens``
and allocated privately.  New blocks are registered when prefill
completes (``commit_prefix``); capacity pressure evicts idle cache
blocks before preempting running sequences.

Who gets served next is itself a programmable attribute (the tenancy
plane): the waiting-queue order, the admission gate and the preemption
victim rule live in a pluggable ``QueueDiscipline`` selected by the
``discipline`` knob — ``fifo_priority`` reproduces the classic
priority/EDF order bit-exactly (the default), ``weighted_fair`` adds
start-time virtual-time fairness across tenants (weights from an
attached ``TenantDirectory``), with priority/EDF preserved *within* a
tenant.  Engines charge actually-processed prefill+decode tokens back
through ``Scheduler.charge`` so the fair-share accounting tracks real
work, not request counts.

All the ``set()``-able knobs the paper's Table-1 interface exposes live
here: max_num_seqs, max_batch_tokens, prefill_chunk, admit_priority_min,
discipline.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.knobs import ControlSurface, KnobSpec
from repro.core.types import Priority, Request, RequestState
from repro.serving.kv_cache import PageAllocator


class StepKind(str, enum.Enum):
    PREFILL = "prefill"
    DECODE = "decode"
    MIXED = "mixed"           # all live decodes + one chunked prefill
    IDLE = "idle"


@dataclass
class PrefillWork:
    req: Request
    chunk: int            # prompt tokens to process this step


@dataclass
class StepPlan:
    kind: StepKind
    prefills: list[PrefillWork] = field(default_factory=list)
    decodes: list[Request] = field(default_factory=list)


class QueueDiscipline:
    """Pluggable who-is-served-next policy: the waiting-queue sort key,
    the preemption victim rule, and (for fairness disciplines) the
    served-token accounting.  ``attach`` hands it the owning scheduler;
    ``dynamic`` disciplines have keys that move between submits (served
    tokens shift virtual time), so the scheduler re-sorts at every
    admission pass instead of only on enqueue."""

    name = "discipline"
    dynamic = False

    def attach(self, scheduler: "Scheduler") -> None:
        self.sched = scheduler

    def on_submit(self, req: Request) -> None:
        """Called before ``req`` joins the waiting queue."""

    def key(self, req: Request):
        """Ascending waiting-queue sort key."""
        raise NotImplementedError

    def victim_key(self, req: Request):
        """``min()`` over RUNNING candidates picks the preemption
        victim."""
        raise NotImplementedError

    def charge(self, req: Request, tokens: int) -> None:
        """Actual prefill/decode tokens processed for ``req``."""


class FifoPriorityDiscipline(QueueDiscipline):
    """The classic (pre-tenancy) order, bit-exact: priority first;
    within a priority class EDF over the workflow plane's
    edge-propagated deadlines, then longest-remaining-critical-path,
    then FIFO.  Requests without a graph behind them keep deadline=inf
    / cp=0, so the order degenerates to (-priority, arrival) for every
    pre-graph caller.  Preemption evicts the lowest-priority youngest
    running sequence."""

    name = "fifo_priority"

    def key(self, req: Request):
        return (-int(req.priority), req.deadline,
                -float(req.meta.get("cp_remaining", 0.0)), req.arrival_time)

    def victim_key(self, req: Request):
        return (int(req.priority), -req.arrival_time)


class WeightedFairDiscipline(QueueDiscipline):
    """Start-time virtual-time fair queueing over tenants (SFQ-style).

    Each tenant accrues virtual time at ``served_tokens / weight``
    (weights from the scheduler's attached ``TenantDirectory``; 1.0
    when none).  The waiting queue orders by tenant virtual time —
    the least-served-relative-to-weight tenant admits first — with the
    full priority/EDF/critical-path/FIFO order preserved *within* a
    tenant.  An idle tenant re-enters at the current virtual floor
    (start-time rule): sleeping never banks credit, and stale debt from
    a past solo-busy period is forgiven.  Preemption picks victims from
    the most-over-share tenant first."""

    name = "weighted_fair"
    dynamic = True

    def __init__(self):
        self.vtime: dict[str, float] = {}

    def _weight(self, tenant: str) -> float:
        d = getattr(self.sched, "tenants", None)
        if d is None:
            return 1.0
        return max(d.weight(tenant), 1e-3)

    def on_submit(self, req: Request) -> None:
        t = req.tenant
        active = {r.tenant for r in self.sched.waiting}
        active.update(r.tenant for r in self.sched.running)
        if t in active:
            # tenant already has queued/running work: its virtual time
            # is live — re-flooring here would erase an underserved
            # tenant's accrued lag (and neutralize the weight knob)
            return
        # idle -> active: re-enter AT the floor, both directions —
        # sleeping banks no credit, and a past solo-heavy tenant's
        # stale virtual-time debt is forgiven (fairness is about the
        # current backlogged period, not history)
        floor = min((self.vtime[u] for u in active if u in self.vtime),
                    default=0.0)
        self.vtime[t] = floor

    def key(self, req: Request):
        return (self.vtime.get(req.tenant, 0.0),
                -int(req.priority), req.deadline,
                -float(req.meta.get("cp_remaining", 0.0)), req.arrival_time)

    def victim_key(self, req: Request):
        return (-self.vtime.get(req.tenant, 0.0),
                int(req.priority), -req.arrival_time)

    def charge(self, req: Request, tokens: int) -> None:
        t = req.tenant
        self.vtime[t] = (self.vtime.get(t, 0.0)
                         + tokens / self._weight(t))


DISCIPLINES = {
    "fifo_priority": FifoPriorityDiscipline,
    "weighted_fair": WeightedFairDiscipline,
}


@dataclass
class SchedulerConfig:
    max_slots: int = 8
    max_batch_tokens: int = 2048
    prefill_chunk: int = 0            # 0 = whole prompt in one step
    mixed: bool = False               # co-run prefill chunk with decode batch
    max_context: int = 4096
    page_size: int = 128
    num_pages: int = 1024
    admit_priority_min: int = 0
    preempt: bool = True
    decode_first: bool = False        # prioritize decode over admission
    require_complete_prompt: bool = False  # real engine: no partial prefill
    # disaggregation plane: the engine's phase role.  `prefill` engines
    # never plan decode steps (sequences are released at prefill
    # completion and handed to a decode engine); `decode` engines never
    # admit from the waiting queue (arrivals come through the handoff
    # `admit_direct` path); `unified` is the classic both-phases loop.
    role: str = "unified"             # unified | prefill | decode
    # tenancy plane: the queue discipline deciding who is served next
    discipline: str = "fifo_priority"  # fifo_priority | weighted_fair
    # tool-call plane: host-memory spill tier for suspended sequences
    # (0 = no offload tier: suspend drops straight to recompute)
    host_capacity_pages: int = 0


class Scheduler(ControlSurface):
    # -- knobs (set()/reset() surface, derived from ControlSurface) --------
    kind = "scheduler"
    CAPABILITIES = ("priority", "preempt")
    METRICS = ("queue_len", "num_running", "page_util",
               "prefill_queue_tokens", "decode_slot_util",
               "suspended_seqs", "host_pages_used")
    KNOB_SPECS = (
        KnobSpec("max_num_seqs", kind="int", lo=1, attr="cfg.max_slots",
                 on_change="_resize_slots",
                 doc="continuous-batching slot count"),
        KnobSpec("max_batch_tokens", kind="int", lo=1,
                 attr="cfg.max_batch_tokens",
                 doc="prefill token budget per step"),
        KnobSpec("prefill_chunk", kind="int", lo=0, attr="cfg.prefill_chunk",
                 doc="chunked-prefill size; 0 = whole prompt"),
        KnobSpec("mixed", kind="bool", attr="cfg.mixed",
                 doc="stall-free continuous batching: co-run one chunked "
                     "prefill with all live decode slots in a single fused "
                     "step (unified role only)"),
        KnobSpec("admit_priority_min", kind="int",
                 attr="cfg.admit_priority_min",
                 doc="admission floor: requests below are not admitted"),
        KnobSpec("decode_first", kind="bool", attr="cfg.decode_first",
                 doc="prioritize decode over new admissions"),
        KnobSpec("role", kind="str",
                 choices=("unified", "prefill", "decode"), attr="cfg.role",
                 doc="engine phase role: unified | prefill | decode"),
        KnobSpec("discipline", kind="str",
                 choices=tuple(DISCIPLINES), attr="cfg.discipline",
                 on_change="_discipline_changed",
                 doc="queue discipline: fifo_priority | weighted_fair"),
        KnobSpec("host_capacity_pages", kind="int", lo=0,
                 attr="cfg.host_capacity_pages",
                 on_change="_host_capacity_changed",
                 doc="host-memory spill tier for tool-call suspend "
                     "(pages); 0 = no offload tier, suspended sequences "
                     "drop straight to recompute"),
    )

    def __init__(self, cfg: SchedulerConfig, name: str = "scheduler",
                 cache=None, tenants=None):
        self.name = name
        self.cfg = cfg
        self.alloc = PageAllocator(cfg.num_pages, cfg.page_size,
                                   host_capacity_pages=cfg.host_capacity_pages)
        self.cache = cache               # Optional[PrefixCache] over alloc
        self.tenants = tenants           # Optional[TenantDirectory]
        self.discipline = DISCIPLINES[cfg.discipline]()
        self.discipline.attach(self)
        self.waiting: list[Request] = []
        self.running: list[Request] = []
        self._free_slots = list(range(cfg.max_slots))
        self.preempt_count = 0
        # tool-call plane: offloaded (slotless) suspended requests, plus
        # the restore-capable ones waiting for a free slot/pages — those
        # are retried with priority over fresh admissions every plan_step
        self.suspended: list[Request] = []
        self._resume_pending: list[Request] = []
        self.resume_hits = 0
        self.resume_recomputes = 0
        # disaggregation fabric hook: where a decode-role scheduler
        # sends preempted victims (it can never re-admit them itself —
        # they need a fresh prefill on a prefill-capable engine)
        self.bounce_fn: Optional[Callable[[Request], None]] = None
        # tracing hooks: the owning engine stamps segment transitions
        # at the exact admit/preempt instants the spans must tile on
        self.on_admit: Optional[Callable[[Request], None]] = None
        self.on_preempt: Optional[Callable[[Request], None]] = None
        # resume hook: the owning engine re-injects host KV (or notes a
        # recompute) at the exact instant a suspended request lands back
        self.on_resume: Optional[Callable[[Request, str], None]] = None
        # pin-deadlock breaker: when every slot-holder is a parked pin
        # and work is waiting, plan_step asks the engine to demote one
        # pin down the eviction ladder (the engine owns the KV movement)
        self.demote_fn: Optional[Callable[[], None]] = None

    def _resize_slots(self, old: int, new: int) -> None:
        if new > old:
            self._free_slots.extend(range(old, new))
        elif new < old:
            self._free_slots = [s for s in self._free_slots if s < new]

    def _host_capacity_changed(self, old: int, new: int) -> None:
        # shrink is clamped above pages holding live spills: reflect the
        # capacity that actually took effect back into the knob value
        self.cfg.host_capacity_pages = self.alloc.set_host_capacity(new)

    def _discipline_changed(self, old: str, new: str) -> None:
        # fresh accounting on a switch: virtual time from a previous
        # discipline instance has no meaning under the new one
        self.discipline = DISCIPLINES[new]()
        self.discipline.attach(self)
        self._sort_waiting()

    def attach_tenants(self, directory) -> None:
        """Wire the fleet's TenantDirectory into the fairness path:
        weighted_fair reads per-tenant weights, charge() reports served
        tokens, and engines report per-tenant TTFT through it."""
        self.tenants = directory

    # -- queue ops ---------------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.state = RequestState.QUEUED
        if req.available < 0:
            req.available = req.prompt_len
        self.discipline.on_submit(req)
        self.waiting.append(req)
        self._sort_waiting()

    def _sort_waiting(self) -> None:
        # order is the discipline's call (sort is stable, so equal keys
        # keep insertion order — the FIFO tail of every discipline)
        self.waiting.sort(key=self.discipline.key)

    def charge(self, req: Request, tokens: int, now: float = 0.0) -> None:
        """Engines report actually-processed prefill/decode tokens here:
        the discipline's fair-share accounting and the tenancy plane's
        ``share`` rollups both track real work, not request counts."""
        if tokens <= 0:
            return
        if self.tenants is not None:
            self.tenants.note_served(req.tenant, tokens, now)
        self.discipline.charge(req, tokens)

    @property
    def queue_len(self) -> int:
        return len(self.waiting)

    @property
    def num_running(self) -> int:
        return len(self.running)

    def slots_in_use(self) -> int:
        return self.cfg.max_slots - len(self._free_slots)

    @property
    def suspended_seqs(self) -> int:
        """Requests parked on an external wait: offloaded (slotless) plus
        pinned-in-place ones still holding their slot."""
        pinned = sum(1 for r in self.running
                     if r.state == RequestState.SUSPENDED)
        return len(self.suspended) + pinned

    @property
    def host_pages_used(self) -> int:
        return self.alloc.host_pages

    @property
    def restore_hit_rate(self) -> float:
        """Warm-restore fraction of completed resumes (1.0 until any
        resume has gone the drop-and-recompute path)."""
        total = self.resume_hits + self.resume_recomputes
        return self.resume_hits / total if total else 1.0

    # -- disaggregation gauges (fleet policies aggregate these) -------------
    @property
    def prefill_queue_tokens(self) -> int:
        """Prompt tokens backed up behind prefill: everything waiting,
        plus the un-prefilled remainder of admitted PREFILL sequences."""
        backlog = sum(max(r.prompt_len - r.prefilled, 0)
                      for r in self.waiting)
        backlog += sum(max(r.prompt_len - r.prefilled, 0)
                       for r in self.running
                       if r.state == RequestState.PREFILL)
        return backlog

    @property
    def decode_slot_util(self) -> float:
        """Fraction of batching slots occupied by decoding sequences."""
        running = sum(1 for r in self.running
                      if r.state == RequestState.RUNNING)
        return running / max(self.cfg.max_slots, 1)

    # -- planning -----------------------------------------------------------------
    def _cache_limit(self, req: Request) -> int:
        """Cap on usable cached prefix: never the whole prompt (the last
        token is always recomputed to produce first-token logits) and
        never beyond the prompt tokens that have *arrived*."""
        lim = req.prompt_len - 1
        if req.available >= 0:
            lim = min(lim, req.available)
        return max(lim, 0)

    def _private_need(self, req: Request) -> int:
        """Tokens that must be privately allocated at admission: the full
        footprint minus the cached prefix resident in shared blocks."""
        need = min(req.prompt_len + req.max_new_tokens, self.cfg.max_context)
        if self.cache is None:
            return need
        cached = self.cache.probe_request(req, limit=self._cache_limit(req))
        return need - min(cached, need)

    def _admissible(self, req: Request) -> bool:
        if int(req.priority) < self.cfg.admit_priority_min:
            return False
        if not self._free_slots:
            return False
        need = self._private_need(req)
        if self.alloc.can_allocate(need):
            return True
        # reclaim idle cache blocks before refusing admission
        return self.cache is not None and self.cache.make_room(need)

    def _admit(self, req: Request) -> bool:
        req.slot = self._free_slots.pop(0)
        need = min(req.prompt_len + req.max_new_tokens, self.cfg.max_context)
        cached = 0
        if self.cache is not None:
            cached = self.cache.begin(req, limit=self._cache_limit(req))
            req.meta["cached_prompt_tokens"] = cached
        priv = need - min(cached, need)
        ok = self.alloc.allocate(req.req_id, priv)
        if not ok and self.cache is not None:
            # _admissible's probe can go stale — e.g. its make_room call
            # evicted this very request's idle prefix blocks — so retry
            # the eviction with the acquired chain now reference-held
            ok = self.cache.make_room(priv) \
                and self.alloc.allocate(req.req_id, priv)
        if not ok:
            # undo: release acquired blocks + slot, requeue at the front
            self.alloc.free(req.req_id)
            if self.cache is not None:
                self.cache.seq_done(req.req_id)
            self._free_slots.insert(0, req.slot)
            req.slot = -1
            req.state = RequestState.QUEUED
            self.waiting.insert(0, req)
            return False
        req.prefilled = max(req.prefilled, cached)
        req.state = RequestState.PREFILL
        self.running.append(req)
        if self.on_admit is not None:
            self.on_admit(req)
        return True

    def commit_prefix(self, req: Request) -> None:
        """Prefill done: register the prompt's new blocks in the cache."""
        if self.cache is not None:
            self.cache.commit(req)

    def _release(self, req: Request) -> None:
        self.alloc.free(req.req_id)
        if self.cache is not None:
            self.cache.seq_done(req.req_id)
        if req.slot >= 0 and req.slot < self.cfg.max_slots:
            self._free_slots.append(req.slot)
        req.slot = -1
        if req in self.running:
            self.running.remove(req)

    def finish(self, req: Request, now: float) -> None:
        req.state = RequestState.FINISHED
        req.finish_time = now
        self._release(req)

    def admit_direct(self, req: Request) -> bool:
        """Admit a request straight into RUNNING, no local prefill: its
        decode state arrives from elsewhere (a kv_transfer migration, or
        the disaggregation plane's prefill→decode handoff — engines gate
        this call on KV residency via ``EngineCore.admit_handoff``)."""
        if self.cfg.role == "prefill":
            return False              # prefill engines never decode
        if not self._free_slots:
            return False
        need = min(req.total_len + (req.max_new_tokens - req.generated),
                   self.cfg.max_context)
        if not self.alloc.allocate(req.req_id, need):
            return False
        req.slot = self._free_slots.pop(0)
        req.state = RequestState.RUNNING
        self.running.append(req)
        if self.on_admit is not None:
            self.on_admit(req)
        return True

    def release_for_handoff(self, req: Request) -> None:
        """Prefill complete on a prefill-role engine: free the slot and
        pages here — the KV rides the handoff pipeline to the paired
        decode engine, which re-admits via ``admit_direct``."""
        self._release(req)
        req.state = RequestState.HANDOFF

    # -- tool-call suspend/resume ------------------------------------------------
    def suspend(self, req: Request, offload: bool = True) -> str:
        """Park a RUNNING request on an external wait (a tool call).

        ``offload=False`` *pins*: the request keeps its slot and pages
        (it simply stops being planned into decode steps) — the
        baseline behavior this plane exists to beat.  ``offload=True``
        returns the slot to the pool immediately and spills private KV
        pages to the allocator's host tier (shared prefix blocks are
        only decref'd, so sharers keep them hot).  Returns the tier the
        request landed on: ``pin`` | ``host`` | ``drop`` (host tier
        full — resume will recompute) | ``none`` (not suspendable)."""
        if req.state != RequestState.RUNNING or req not in self.running:
            return "none"
        req.state = RequestState.SUSPENDED
        if not offload:
            req.meta["suspend_tier"] = "pin"
            return "pin"
        return self._spill(req)

    def _spill(self, req: Request) -> str:
        """Move a SUSPENDED slot-holder down the ladder: KV to the host
        tier (or dropped when it is full), slot back to the pool."""
        tier = self.alloc.suspend(req.req_id)
        if tier == "drop" and self.cache is not None:
            self.cache.seq_done(req.req_id)
        if 0 <= req.slot < self.cfg.max_slots:
            self._free_slots.append(req.slot)
        req.slot = -1
        self.running.remove(req)
        self.suspended.append(req)
        req.meta["suspend_tier"] = tier
        return tier

    def offload_pinned(self, req: Request) -> str:
        """Demote a *pinned* suspended request to a real offload — the
        anti-deadlock rung.  A pin is best-effort: if every slot-holder
        is parked on a tool wait and queued work includes the very calls
        those tools are waiting on (a fan-in like debate's pro/con ->
        factcheck), no slot would ever free.  The caller (the engine's
        ``demote_fn``) extracts KV first, exactly like a knob-driven
        offload."""
        if req.state != RequestState.SUSPENDED or req not in self.running:
            return "none"
        return self._spill(req)

    def pin_starved(self) -> Optional[Request]:
        """The demotion trigger — a *true* wedge, not mere pressure: no
        free slot, work waiting, and every slot-holder is a parked pin
        whose tool cannot even *start* until a queued sibling call runs
        (the workflow layer stamps those ``tool_blocked``).  If any
        occupant is still decoding, or is parked on a tool already in
        flight, the engine makes progress on its own — that is latency,
        not deadlock, and the pin baseline stays pinned through it."""
        if self._free_slots or not self.running:
            return None
        if not (self.waiting or self._resume_pending):
            return None
        for r in self.running:
            if (r.state != RequestState.SUSPENDED
                    or not r.meta.get("tool_blocked")):
                return None               # someone can still make progress
        return self.running[0]            # oldest blocked pin first

    def resume(self, req: Request) -> str:
        """Bring a SUSPENDED request back to RUNNING.

        Outcomes: ``pin`` (never left — state flip only), ``hit``
        (host pages reclaimed into HBM, prefix blocks re-acquired, slot
        granted; the engine's ``on_resume`` hook re-injects the KV),
        ``wait`` (restorable, but no slot/pages right now — queued on
        the resume-pending list, which ``plan_step`` retries *before*
        fresh admissions), or ``recompute`` (host copy or prefix chain
        gone: the eviction ladder's bottom rung — generated tokens fold
        into the prompt and the request re-enters normal admission)."""
        if req.state != RequestState.SUSPENDED:
            return "none"
        if req in self.running:               # pinned: slot never left
            req.state = self._resume_state(req)
            req.meta.pop("suspend_tier", None)
            if self.on_resume is not None:
                self.on_resume(req, "pin")
            return "pin"
        out = self._try_restore(req)
        if out == "wait" and req not in self._resume_pending:
            self._resume_pending.append(req)
        return out

    def _resume_state(self, req: Request) -> RequestState:
        """A resume lands in PREFILL when the continuation appended
        prompt tokens (a tool result) that still need prefilling on top
        of the restored context; plain resumes go straight to RUNNING."""
        if req.prefilled < min(req.prompt_len, max(req.available, 0)):
            return RequestState.PREFILL
        return RequestState.RUNNING

    def _try_restore(self, req: Request) -> str:
        ready = self.alloc.restore_ready(req.req_id)
        if ready == "no_pages" and self.cache is not None:
            # eviction ladder: reclaim idle cache blocks before forcing
            # a restorable spill down to recompute (or making it wait)
            if self.cache.make_room(self.alloc.host_holds(req.req_id)
                                    * self.cfg.page_size):
                ready = self.alloc.restore_ready(req.req_id)
        if ready == "ok":
            if not self._free_slots:
                return "wait"
            self.alloc.restore(req.req_id)
            req.slot = self._free_slots.pop(0)
            req.state = self._resume_state(req)
            req.meta.pop("suspend_tier", None)
            if req in self.suspended:
                self.suspended.remove(req)
            self.running.append(req)
            self.resume_hits += 1
            if self.on_admit is not None:
                self.on_admit(req)
            if self.on_resume is not None:
                self.on_resume(req, "hit")
            return "hit"
        if ready == "no_pages":
            return "wait"
        # gone / no_blocks: drop-and-recompute.  The generated tail's KV
        # is lost with the host copy, so it folds into the prompt and the
        # whole context re-prefills through normal admission (where the
        # prefix cache may still shortcut most of it).
        self.alloc.drop_suspended(req.req_id)
        if self.cache is not None:
            self.cache.seq_done(req.req_id)
        if req in self.suspended:
            self.suspended.remove(req)
        req.meta.pop("suspend_tier", None)
        if req.generated:
            if req.prompt_tokens is not None:
                req.prompt_tokens = (list(req.prompt_tokens)
                                     + list(req.output_tokens))
            req.prompt_len += req.generated
            req.max_new_tokens = max(req.max_new_tokens - req.generated, 1)
            req.generated = 0
        req.available = req.prompt_len
        req.prefilled = 0
        req.slot = -1
        self.resume_recomputes += 1
        if self.cfg.role == "decode" and self.bounce_fn is not None:
            # decode engines can't run the recompute prefill themselves
            self.bounce_fn(req)
        else:
            self.submit(req)
        if self.on_resume is not None:
            self.on_resume(req, "recompute")
        return "recompute"

    def _resume_pass(self) -> None:
        """Retry restore-pending resumes — before fresh admissions, so a
        returning tool call outranks new work for freed capacity."""
        if not self._resume_pending:
            return
        still = []
        for req in self._resume_pending:
            if req.state != RequestState.SUSPENDED:
                continue                  # finished/migrated meanwhile
            if self._try_restore(req) == "wait":
                still.append(req)
        self._resume_pending = still

    def forget_suspended(self, req: Request) -> None:
        """Strip every trace of a suspended request from this scheduler —
        the abandon path, and the source side of a cross-engine
        migration."""
        if req in self.running:           # pinned: slot + pages held
            self._release(req)
        else:
            self.alloc.drop_suspended(req.req_id)
            if self.cache is not None:
                self.cache.seq_done(req.req_id)
            if req in self.suspended:
                self.suspended.remove(req)
            if req in self._resume_pending:
                self._resume_pending.remove(req)
        req.meta.pop("suspend_tier", None)

    def finish_suspended(self, req: Request, now: float) -> None:
        """A suspended request whose continuation was abandoned: release
        its parked state (pinned slot+pages or host copy) and finish."""
        self.forget_suspended(req)
        req.state = RequestState.FINISHED
        req.finish_time = now

    def preempt_one(self) -> Optional[Request]:
        """Evict lowest-priority, youngest running sequence."""
        candidates = [r for r in self.running
                      if r.state == RequestState.RUNNING]
        if not candidates:
            return None
        victim = min(candidates, key=self.discipline.victim_key)
        self._release(victim)
        victim.state = RequestState.PREEMPTED
        # cache dropped: the victim restarts from scratch on re-admit, so
        # every per-request emission record resets with it — leaving
        # output_tokens/first_token_time populated would re-emit the same
        # tokens (duplicate output, double-counted ttft) after re-admission
        victim.prefilled = 0
        victim.generated = 0
        victim.output_tokens.clear()
        victim.first_token_time = None
        self.preempt_count += 1
        if self.on_preempt is not None:
            self.on_preempt(victim)
        if self.cfg.role == "decode" and self.bounce_fn is not None:
            # this scheduler never admits from waiting: re-route the
            # victim to a prefill-capable engine instead of stranding it
            self.bounce_fn(victim)
            return victim
        self.waiting.append(victim)
        self._sort_waiting()
        return victim

    def _admission_pass(self) -> None:
        """Admit from the head of the discipline-ordered waiting queue
        while capacity lasts.  Paused tenants' requests are skipped (not
        head-of-line blockers); with no TenantDirectory attached this
        loop is bit-exact with the classic admit-while-admissible."""
        if self.discipline.dynamic:
            self._sort_waiting()         # served tokens moved the keys
        held = []
        while self.waiting:
            head = self.waiting[0]
            if self.tenants is not None and self.tenants.paused(head.tenant):
                held.append(self.waiting.pop(0))
                continue
            if not self._admissible(head):
                break
            if not self._admit(self.waiting.pop(0)):
                break
        if held:
            # restore discipline order: a plain front-insert would leave
            # the skipped requests ahead of higher-priority work until
            # the next submit happens to re-sort
            self.waiting[:0] = held
            self._sort_waiting()

    def plan_step(self) -> StepPlan:
        # 0. liveness: a fully pin-parked engine with waiting work can
        #    never free a slot on its own — demote one pin down the
        #    ladder (the engine moves the KV) before planning anything
        if self.demote_fn is not None and self.pin_starved() is not None:
            self.demote_fn()
        #    returning tool calls first: restore-pending resumes get the
        #    freed capacity before any fresh admission sees it
        if self.cfg.role != "prefill":
            self._resume_pass()
        # 1. admit while capacity (decode engines only admit through the
        #    handoff path — their waiting queue is bounced by the fabric)
        if self.cfg.role != "decode" and (not self.cfg.decode_first
                                          or not self.running):
            self._admission_pass()
        # 2. prefill work pending?  (only tokens that have *arrived* —
        #    under STREAM granularity the prompt trickles in and prefill
        #    overlaps the upstream agent's generation)
        pending = [r for r in self.running
                   if r.state in (RequestState.PREFILL,)
                   and r.prefilled < min(r.prompt_len, r.available)]
        if self.cfg.require_complete_prompt:
            pending = [r for r in pending if r.available >= r.prompt_len]
        if pending and self.cfg.mixed and self.cfg.role == "unified":
            # stall-free continuous batching: the token budget is filled
            # with every live decode slot first (one token each), then
            # one head-of-line prefill chunk takes whatever remains —
            # a long prompt never serializes against the decode batch.
            decodes = [r for r in self.running
                       if r.state == RequestState.RUNNING]
            budget = self.cfg.max_batch_tokens - len(decodes)
            chunkcfg = self.cfg.prefill_chunk
            r = pending[0]
            remaining = min(r.prompt_len, r.available) - r.prefilled
            chunk = remaining if chunkcfg <= 0 else min(chunkcfg, remaining)
            chunk = min(chunk, budget)
            if chunk > 0:
                return StepPlan(StepKind.MIXED,
                                prefills=[PrefillWork(r, chunk)],
                                decodes=decodes)
            if decodes:          # budget exhausted by decode slots alone
                return StepPlan(StepKind.DECODE, decodes=decodes)
            return StepPlan(StepKind.IDLE)
        if pending:
            budget = self.cfg.max_batch_tokens
            chunkcfg = self.cfg.prefill_chunk
            plan = StepPlan(StepKind.PREFILL)
            for r in pending:
                if budget <= 0:
                    break
                remaining = min(r.prompt_len, r.available) - r.prefilled
                chunk = remaining if chunkcfg <= 0 else min(chunkcfg,
                                                            remaining)
                chunk = min(chunk, budget)
                if chunk <= 0:
                    continue
                plan.prefills.append(PrefillWork(r, chunk))
                budget -= chunk
            if plan.prefills:
                return plan
        # 3. decode everyone running — never on a prefill-role engine:
        #    its RUNNING sequences are awaiting handoff release, not a
        #    decode step (ISSUE 4's "prefill-only engines never decode")
        if self.cfg.role == "prefill":
            return StepPlan(StepKind.IDLE)
        decodes = [r for r in self.running if r.state == RequestState.RUNNING]
        if decodes:
            return StepPlan(StepKind.DECODE, decodes=decodes)
        return StepPlan(StepKind.IDLE)

    # -- decode-time growth ----------------------------------------------------------
    def ensure_decode_capacity(self, req: Request) -> bool:
        """Grow pages for the next token; evict idle cache blocks first,
        then preempt others if configured."""
        shared = (self.cache.shared_tokens(req.req_id)
                  if self.cache is not None else 0)
        target = max(min(req.total_len + 1, self.cfg.max_context) - shared, 0)
        while not self.alloc.grow_to(req.req_id, target):
            if self.cache is not None and self.cache.evict_one():
                continue
            if not self.cfg.preempt:
                return False
            victim = self.preempt_one()
            if victim is None or victim is req:
                return False
        return True
