"""Sharded AdamW with optional int8 (blockwise-quantized) moments.

Moments inherit the parameter's sharding (they are built leaf-for-leaf
from the param pytree, so the same PartitionSpecs apply), which is what
makes the optimizer ZeRO-sharded for free under the FSDP param rules.

``int8_moments=True`` stores m and v as int8 with per-128-block f32
scales (8-bit-Adam style): 2.25 bytes/param of optimizer state instead
of 8 — the difference between fitting and not fitting a 405B model on a
16 GB/chip pod (see DESIGN.md §4).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 128


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    int8_moments: bool = False
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


# ---------------------------------------------------------------------------
# Blockwise int8 quantization
# ---------------------------------------------------------------------------


class Q8(NamedTuple):
    q: jax.Array          # int8, original shape
    scale: jax.Array      # f32, shape (..., n_blocks) over the last dim


def _quantize(x: jax.Array) -> Q8:
    """Blockwise int8 over the LAST dim only.  All reshapes split/merge
    trailing dims exclusively, so GSPMD sharding on the leading dims
    (the FSDP/TP axes) propagates — flattening the whole tensor first
    would force XLA to materialize it replicated (hundreds of GB/device
    for a 405B moment tensor)."""
    shape = x.shape
    if not shape:
        return Q8(jnp.zeros((), jnp.int8),
                  jnp.maximum(jnp.abs(x), 1e-12).astype(jnp.float32)[None])
    n = shape[-1]
    pad = (-n) % BLOCK
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    blocks = xp.reshape(*shape[:-1], -1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    q = q.reshape(*shape[:-1], n + pad)[..., :n]
    return Q8(q, scale[..., 0].astype(jnp.float32))


def _dequantize(q8: Q8, shape) -> jax.Array:
    if not shape:
        return q8.q.astype(jnp.float32) * q8.scale[0]
    n = shape[-1]
    pad = (-n) % BLOCK
    qp = jnp.pad(q8.q.astype(jnp.float32),
                 [(0, 0)] * (len(shape) - 1) + [(0, pad)])
    blocks = qp.reshape(*shape[:-1], -1, BLOCK)
    out = blocks * q8.scale[..., None]
    return out.reshape(*shape[:-1], n + pad)[..., :n]


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any                 # pytree: f32 arrays or Q8
    v: Any


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    def zero(p):
        if cfg.int8_moments:
            z = jnp.zeros(p.shape, jnp.float32)
            return _quantize(z)
        return jnp.zeros(p.shape, jnp.float32)

    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zero, params),
                      v=jax.tree.map(zero, params))


def _lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1

    # global-norm clip
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9)) \
        if cfg.clip_norm > 0 else jnp.float32(1.0)

    b1t = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1 - cfg.b2 ** step.astype(jnp.float32)
    lr = _lr_at(cfg, step.astype(jnp.float32))

    is_q8 = lambda x: isinstance(x, Q8)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        if cfg.int8_moments:
            mf = _dequantize(m, p.shape)
            # v is stored in sqrt-domain: linear int8 on raw v loses the
            # small entries inside a block (max-scaled), and rsqrt then
            # explodes; sqrt halves the dynamic range (8-bit-Adam trick)
            vf = jnp.square(_dequantize(v, p.shape))
        else:
            mf, vf = m, v
        mf = cfg.b1 * mf + (1 - cfg.b1) * g
        vf = cfg.b2 * vf + (1 - cfg.b2) * jnp.square(g)
        update = (mf / b1t) / (jnp.sqrt(vf / b2t) + cfg.eps)
        newp = (p.astype(jnp.float32)
                - lr * (update + cfg.weight_decay * p.astype(jnp.float32)))
        m_out = _quantize(mf) if cfg.int8_moments else mf
        v_out = _quantize(jnp.sqrt(vf)) if cfg.int8_moments else vf
        return newp.astype(p.dtype), m_out, v_out

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m) if not cfg.int8_moments else \
        jax.tree.flatten(state.m, is_leaf=is_q8)[0]
    flat_v = treedef.flatten_up_to(state.v) if not cfg.int8_moments else \
        jax.tree.flatten(state.v, is_leaf=is_q8)[0]

    outs = [upd(p, g, m, v)
            for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in outs])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
