from repro.distributed.sharding import (ShardingRules, batch_pspec,
                                        cache_pspecs, maybe_constrain,
                                        param_pspecs, param_shardings,
                                        spec_for)

__all__ = [
    "ShardingRules", "batch_pspec", "cache_pspecs", "maybe_constrain",
    "param_pspecs", "param_shardings", "spec_for",
]
