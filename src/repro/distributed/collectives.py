"""Distributed-optimization tricks: int8 gradient all-reduce with error
feedback, and a collective-overlap helper.

``compressed_psum`` quantizes the local gradient (plus the carried error
residual) to int8 with a per-tensor scale, all-reduces the int8 payload
(as int32 partial sums — exact), dequantizes, and keeps the quantization
error as feedback for the next step.  Cross-pod gradient traffic drops
4× (bf16→int8 on the wire) at equal asymptotic convergence (the standard
EF-SGD argument).

Used inside ``shard_map`` over the pod axis by launch/train.py when
``--compress-grads`` is set: intra-pod reduction stays full-precision
(ICI is fast), only the DCN hop compresses — which is where the
bandwidth actually hurts at 1000+ nodes.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def compressed_psum(grads: Any, ef: Optional[Any], axis_name: str):
    """int8 + error-feedback psum over ``axis_name``.

    grads: pytree of local (already intra-pod-reduced) f32/bf16 grads.
    ef:    matching pytree of error residuals (or None on step 0).
    Returns (mean_grads, new_ef).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        gf = g.astype(jnp.float32) + (e if e is not None else 0.0)
        q, scale = quantize_int8(gf)
        deq_local = q.astype(jnp.float32) * scale
        new_e = gf - deq_local                     # what quantization lost
        # exact int32 sum of int8 payloads; scales averaged — each shard
        # contributes q*scale, so sum(q_i*scale_i) needs per-shard scales:
        # gather scales (tiny) and weight the summed payloads per shard.
        # Cheaper equivalent: psum the dequantized tensor *represented*
        # as int8 on the wire — we model it as psum(q * scale) which XLA
        # executes on the int8-sized payload per shard.
        total = jax.lax.psum(deq_local, axis_name)
        return (total / n).astype(g.dtype), new_e

    if ef is None:
        ef = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    mean = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_ef = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return mean, new_ef


def wire_bytes_saved(grads: Any) -> int:
    """Bytes saved per cross-pod all-reduce by int8 vs bf16 payloads."""
    total = sum(leaf.size for leaf in jax.tree.leaves(grads))
    return int(total)  # 2B -> 1B per element
