"""Sharding rules: logical parameter axes → mesh axes.

The model zoo annotates every parameter with logical axes
("embed", "heads", "kv_heads", "ff", "vocab", "experts" — see
models/params.P); this module turns those into ``PartitionSpec``s for a
concrete mesh:

* **TP** — heads / ff / vocab / experts shard over ``model``;
* **FSDP** — the embed dim shards over ``data`` (ZeRO-3: weights
  all-gather per layer inside the scan, grads reduce-scatter back);
* **EP** — expert tables shard their leading experts dim over ``model``;
* **DP** — the batch dim of activations shards over ``data`` (and
  ``pod`` on the multi-pod mesh: pure DP across the DCN link);
* **SP** — long-context decode shards the KV-cache *sequence* dim.

Every assignment is divisibility-checked with fallback (e.g. GQA with 8
KV heads on a 16-way model axis leaves KV-head dims replicated — the
Megatron-style KV replication for TP > n_kv_heads), and a mesh axis is
used at most once per tensor.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.base import ModelConfig
from repro.models import params as prm
from repro.models import transformer


@dataclass(frozen=True)
class ShardingRules:
    """Priority-ordered (logical axis → mesh-axis candidates)."""

    # candidates may be single mesh axes or tuples (joint sharding over
    # several axes — e.g. FSDP over pod×data on the multi-pod mesh cuts
    # per-device parameter state 2x at the price of DCN all-gathers)
    rules: tuple[tuple[str, tuple, ...], ...] = (
        ("experts", ("model",)),
        ("heads", ("model",)),
        ("kv_heads", ("model",)),
        ("ff", ("model",)),
        ("vocab", ("model",)),
        ("embed", (("pod", "data"), "data")),
    )
    # activation batch axes, outermost first
    batch_axes: tuple[str, ...] = ("pod", "data")
    seq_axis: str = "model"          # sequence-parallel activations
    cache_seq_axis: str = "data"     # long-context KV sequence sharding

    def lookup(self, logical: str) -> tuple[str, ...]:
        for name, axes in self.rules:
            if name == logical:
                return axes
        return ()


DEFAULT_RULES = ShardingRules()


def _mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape)) \
        if hasattr(mesh, "devices") else dict(mesh.shape)


def spec_for(axes: tuple[Optional[str], ...], shape: tuple[int, ...],
             mesh, rules: ShardingRules = DEFAULT_RULES) -> PartitionSpec:
    """PartitionSpec for one tensor: logical axes + divisibility + each
    mesh axis used at most once."""
    sizes = _mesh_axis_sizes(mesh)
    used: set[str] = set()
    out: list = []
    for logical, dim in zip(axes, shape):
        chosen = None
        if logical is not None:
            for cand in rules.lookup(logical):
                group = cand if isinstance(cand, tuple) else (cand,)
                total = 1
                ok = True
                for ax in group:
                    if ax not in sizes or ax in used:
                        ok = False
                        break
                    total *= sizes[ax]
                if ok and dim % total == 0:
                    chosen = cand if isinstance(cand, tuple) else cand
                    used.update(group)
                    break
        out.append(chosen)
    return PartitionSpec(*out)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def param_pspecs(cfg: ModelConfig, mesh,
                 rules: ShardingRules = DEFAULT_RULES):
    defs = transformer.model_defs(cfg)

    def go(p: prm.P):
        return spec_for(p.axes, p.shape, mesh, rules)

    return jax.tree.map(go, defs, is_leaf=lambda x: isinstance(x, prm.P))


def param_shardings(cfg: ModelConfig, mesh: Mesh,
                    rules: ShardingRules = DEFAULT_RULES):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(cfg, mesh, rules))


# ---------------------------------------------------------------------------
# Activations / batch
# ---------------------------------------------------------------------------


def batch_pspec(mesh, rules: ShardingRules = DEFAULT_RULES,
                batch_size: Optional[int] = None) -> PartitionSpec:
    """Leading-axis data-parallel spec: ('pod','data') when both exist."""
    sizes = _mesh_axis_sizes(mesh)
    axes = [a for a in rules.batch_axes if a in sizes]
    if batch_size is not None:
        total = int(np.prod([sizes[a] for a in axes])) if axes else 1
        while axes and batch_size % int(np.prod([sizes[a] for a in axes])):
            axes.pop(0)              # drop outermost until divisible
    return PartitionSpec(tuple(axes) if len(axes) > 1 else
                         (axes[0] if axes else None))


def maybe_constrain(x: jax.Array, spec: PartitionSpec) -> jax.Array:
    """with_sharding_constraint that degrades to a no-op when tracing
    without a mesh (single-device smoke tests)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty or not mesh.axis_names:
            return x
        names = set(mesh.axis_names)
        cleaned = []
        for entry in spec:
            if entry is None:
                cleaned.append(None)
            elif isinstance(entry, tuple):
                kept = tuple(a for a in entry if a in names)
                cleaned.append(kept if kept else None)
            else:
                cleaned.append(entry if entry in names else None)
        return jax.lax.with_sharding_constraint(x, PartitionSpec(*cleaned))
    except Exception:
        return x


def activation_pspec(mesh, seq: bool = True,
                     rules: ShardingRules = DEFAULT_RULES) -> PartitionSpec:
    """(B, S, D) layer-boundary activations: batch over data axes and —
    Megatron-style sequence parallelism — S over the model axis (the
    saved-for-backward residuals shrink by the TP degree)."""
    sizes = _mesh_axis_sizes(mesh)
    b_axes = tuple(a for a in rules.batch_axes if a in sizes)
    b_entry = b_axes if len(b_axes) > 1 else (b_axes[0] if b_axes else None)
    s_entry = rules.seq_axis if (seq and rules.seq_axis in sizes) else None
    return PartitionSpec(b_entry, s_entry, None)


# ---------------------------------------------------------------------------
# Decode cache
# ---------------------------------------------------------------------------


def _cache_batch_axes(cfg: ModelConfig, max_context: int, enc_len: int):
    """Locate the batch axis of every cache leaf by shape-diffing."""
    c1 = jax.eval_shape(lambda: transformer.init_cache(cfg, 1, max_context,
                                                       enc_len))
    c2 = jax.eval_shape(lambda: transformer.init_cache(cfg, 2, max_context,
                                                       enc_len))
    l1, treedef = jax.tree.flatten(c1)
    l2, _ = jax.tree.flatten(c2)

    def axis(a, b):
        for i, (x, y) in enumerate(zip(a.shape, b.shape)):
            if x != y:
                return i
        raise ValueError(f"no batch axis in cache leaf {a.shape}")

    return treedef, l1, [axis(a, b) for a, b in zip(l1, l2)]


def cache_pspecs(cfg: ModelConfig, batch: int, max_context: int, mesh,
                 enc_len: int = 0, rules: ShardingRules = DEFAULT_RULES,
                 shard_seq: bool = False):
    """PartitionSpec pytree for the decode cache.

    * batch over the data axes;
    * KV-head dim over ``model`` when divisible (TP);
    * otherwise the ring *sequence* dim over ``model`` — GQA caches with
      n_kv_heads < TP degree would replicate 16× and simply not fit
      (e.g. llama3-405B at 32k×128: 2.2 TB of KV); sequence sharding is
      the mesh-level flash-decoding layout (partial softmax combines);
    * ``shard_seq`` (long-context, batch=1): sequence over ``data`` too.
    """
    sizes = _mesh_axis_sizes(mesh)
    treedef, leaves, b_axes = _cache_batch_axes(cfg, max_context, enc_len)

    b_mesh = tuple(a for a in rules.batch_axes if a in sizes)
    while b_mesh and batch % int(np.prod([sizes[a] for a in b_mesh])):
        b_mesh = b_mesh[1:] if len(b_mesh) > 1 else ()
    b_entry = b_mesh if len(b_mesh) > 1 else (b_mesh[0] if b_mesh else None)

    specs = []
    for leaf, b_ax in zip(leaves, b_axes):
        # NOTE: `leaves` are the batch=1 skeleton, used for layout only —
        # never compare leaf.shape[b_ax] against the real batch size
        entries: list = [None] * leaf.ndim
        used: set[str] = set()
        if b_entry is not None:
            entries[b_ax] = b_entry
            used.update(b_mesh)
        # ring KV leaves look like (..., B, S_ring, H_kv, d_head)
        is_kv = (leaf.ndim - b_ax) == 4 and leaf.shape[b_ax + 2] in (
            cfg.n_kv_heads, cfg.n_heads)
        if is_kv:
            s_ax, h_ax = b_ax + 1, b_ax + 2
            if shard_seq:
                cand = rules.cache_seq_axis
                if cand in sizes and cand not in used \
                        and leaf.shape[s_ax] % sizes[cand] == 0:
                    entries[s_ax] = cand
                    used.add(cand)
            if "model" in sizes and "model" not in used \
                    and leaf.shape[h_ax] % sizes["model"] == 0:
                entries[h_ax] = "model"
                used.add("model")
            elif "model" in sizes and "model" not in used \
                    and entries[s_ax] is None \
                    and leaf.shape[s_ax] % sizes["model"] == 0:
                entries[s_ax] = "model"       # seq-TP fallback for GQA
                used.add("model")
        specs.append(PartitionSpec(*entries))
    return jax.tree.unflatten(treedef, specs)
