"""Parameter-definition trees.

Modules describe parameters as trees of ``P`` (shape + logical axes +
initializer).  Generic walkers produce:
  * initialized pytrees (``init_params``),
  * ``PartitionSpec`` pytrees for pjit (``param_pspecs``),
  * ``ShapeDtypeStruct`` pytrees for AOT lowering (``param_shapes``) —
    the dry-run never allocates real weights.

Logical-axis → mesh-axis mapping lives in ``repro.distributed.sharding``;
this module is mesh-agnostic.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class P:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]
    init: str = "normal"            # normal | zeros | ones
    scale: float = 1.0              # stddev multiplier for 'normal'
    dtype: Optional[str] = None     # override model dtype (e.g. f32 norms)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Tree = Any  # nested dict of P / arrays / specs


def stack(defs: Tree, *dims: int) -> Tree:
    """Prepend layer-stack dims (replicated axes) to every P in the tree."""
    def go(p: P) -> P:
        return P(tuple(dims) + p.shape, (None,) * len(dims) + p.axes,
                 p.init, p.scale, p.dtype)
    return jax.tree.map(go, defs, is_leaf=lambda x: isinstance(x, P))


def _init_one(p: P, key: jax.Array, dtype: jnp.dtype) -> jax.Array:
    dt = jnp.dtype(p.dtype) if p.dtype else dtype
    if p.init == "zeros":
        return jnp.zeros(p.shape, dt)
    if p.init == "ones":
        return jnp.ones(p.shape, dt)
    # fan-in scaled normal on the last-but-one "input" dim heuristic:
    fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
    std = p.scale / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(dt)


def init_params(defs: Tree, key: jax.Array, dtype: jnp.dtype) -> Tree:
    leaves, treedef = jax.tree.flatten(defs, is_leaf=lambda x: isinstance(x, P))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(p, k, dtype) for p, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def param_shapes(defs: Tree, dtype: jnp.dtype) -> Tree:
    def go(p: P):
        dt = jnp.dtype(p.dtype) if p.dtype else dtype
        return jax.ShapeDtypeStruct(p.shape, dt)
    return jax.tree.map(go, defs, is_leaf=lambda x: isinstance(x, P))


def param_axes(defs: Tree) -> Tree:
    """Tree of logical-axis tuples (consumed by distributed.sharding)."""
    return jax.tree.map(lambda p: p.axes, defs,
                        is_leaf=lambda x: isinstance(x, P))


def count_params(defs: Tree) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, P))
    return int(sum(np.prod(p.shape) for p in leaves))


def tp(w: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Pin a weight's tensor-parallel layout at its USE site.

    Under GSPMD, a contraction between a seq-sharded activation and a
    TP-sharded weight has two legal resolutions: gather the (huge)
    weight or gather the (small) activation slice.  The compiler's cost
    model sometimes picks the weight — for llama3-405b that is a 3.5 GB
    full w_out materialization per layer.  Constraining the weight here
    makes gathering it illegal, so the activation moves instead — the
    Megatron weight-stationary schedule.

    ``axes`` entries are 'model' or None (trailing stack dims are
    handled automatically).  No-op without a mesh, when the dim is not
    divisible, or when sharding is disabled.
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty or "model" not in mesh.axis_names:
            return w
        m = mesh.shape["model"]
        offset = w.ndim - len(axes)       # leading (scan-stack) dims
        entries: list[Optional[str]] = [None] * w.ndim
        for i, a in enumerate(axes):
            if a == "model" and w.shape[offset + i] % m == 0:
                entries[offset + i] = "model"
        if not any(entries):
            return w
        return jax.lax.with_sharding_constraint(
            w, jax.sharding.PartitionSpec(*entries))
    except Exception:
        return w
