"""Block-level assembly: one (defs, apply, cache) triple per BlockSpec kind.

``block_apply`` has three modes sharing parameters:
  'train'   — full sequence, no cache IO (losses / aux returned)
  'prefill' — full sequence, writes decode state (KV tail / final SSM state)
  'step'    — incremental: write-then-attend KV, O(1) recurrent updates

Cache pytrees are built by ``init_block_cache`` and mirrored as
ShapeDtypeStructs by the dry-run via ``jax.eval_shape``.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import BlockSpec, ModelConfig
from repro.models import ssm
from repro.models.attention import (CrossKV, PagedKVCache, attn_defs,
                                    cross_attention, cross_attention_cached,
                                    cross_kv_precompute, init_kv_cache,
                                    init_paged_kv_cache, kv_cache_size,
                                    self_attention, self_attention_cached,
                                    self_attention_paged,
                                    self_attention_prefill)
from repro.models.layers import mlp, mlp_defs, rmsnorm, rmsnorm_defs
from repro.models.moe import moe_defs, moe_ffn


# ---------------------------------------------------------------------------
# Defs
# ---------------------------------------------------------------------------


def block_defs(cfg: ModelConfig, spec: BlockSpec) -> dict:
    if spec.kind == "mlstm":
        return ssm.mlstm_defs(cfg)
    if spec.kind == "slstm":
        return ssm.slstm_defs(cfg)
    if spec.kind == "hymba":
        return {
            "norm1": rmsnorm_defs(cfg.d_model),
            "attn": attn_defs(cfg),
            "mamba": ssm.mamba_defs(cfg),
            "norm2": rmsnorm_defs(cfg.d_model),
            "mlp": mlp_defs(cfg.d_model, cfg.d_ff),
        }
    # attn / enc / dec
    defs: dict[str, Any] = {
        "norm1": rmsnorm_defs(cfg.d_model),
        "attn": attn_defs(cfg),
    }
    if spec.cross_attention:
        defs["norm_x"] = rmsnorm_defs(cfg.d_model)
        defs["cross"] = attn_defs(cfg, cross=True)
    if not spec.parallel_block:
        defs["norm2"] = rmsnorm_defs(cfg.d_model)
    if spec.moe:
        defs["moe"] = moe_defs(cfg)
        if spec.dense_residual:
            defs["mlp"] = mlp_defs(cfg.d_model, cfg.d_ff)
    else:
        defs["mlp"] = mlp_defs(cfg.d_model, cfg.d_ff)
    return defs


# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------


def init_block_cache(cfg: ModelConfig, spec: BlockSpec, batch: int,
                     max_context: int, dtype,
                     enc_len: int = 0):
    """Decode-state pytree for one layer of this block kind."""
    if spec.kind == "mlstm":
        return {"h": ssm.init_mlstm_state(batch, cfg)}
    if spec.kind == "slstm":
        return {"s": ssm.init_slstm_state(batch, cfg)}
    kvsize = kv_cache_size(spec, max_context, cfg.attn_chunk)
    kv = init_kv_cache(batch, kvsize, cfg.n_kv_heads, cfg.d_head, dtype)
    if spec.kind == "hymba":
        return {"kv": kv, "ssm": ssm.init_ssm_state(batch, cfg, dtype)}
    cache: dict[str, Any] = {"kv": kv}
    if spec.cross_attention:
        cache["cross"] = CrossKV(
            k=jnp.zeros((batch, enc_len, cfg.n_kv_heads, cfg.d_head), dtype),
            v=jnp.zeros((batch, enc_len, cfg.n_kv_heads, cfg.d_head), dtype))
    return cache


def init_paged_block_cache(cfg: ModelConfig, spec: BlockSpec,
                           num_pages: int, page_size: int, dtype):
    """Paged-pool decode state for one layer.  The pool is shared across
    slots (no batch axis) and sized by the *allocator's* page count, so
    PageAllocator accounting is the single source of truth for capacity.
    Only plain attention blocks page cleanly — recurrent state and cross
    KV have no page structure."""
    if spec.kind not in ("attn", "dec") or spec.cross_attention:
        raise ValueError(
            f"paged KV layout supports attention-only blocks, not "
            f"{spec.kind!r} (cross={spec.cross_attention})")
    return {"kv": init_paged_kv_cache(num_pages, page_size,
                                      cfg.n_kv_heads, cfg.d_head, dtype)}


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------


class BlockOut(NamedTuple):
    x: jax.Array
    cache: Any                 # updated cache (or None in train mode)
    aux: jax.Array             # scalar aux loss (MoE load balance)


def _ffn(params: dict, x: jax.Array, cfg: ModelConfig,
         spec: BlockSpec) -> tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    if spec.moe:
        y, stats = moe_ffn(params["moe"], x, cfg, spec)
        aux = stats.aux_loss
        if spec.dense_residual:
            y = y + mlp(params["mlp"], x)
        return y, aux
    return mlp(params["mlp"], x), aux


def block_apply(params: dict, x: jax.Array, cfg: ModelConfig,
                spec: BlockSpec, positions: jax.Array, mode: str,
                cache=None, memory: Optional[jax.Array] = None,
                tables: Optional[jax.Array] = None) -> BlockOut:
    """x: (B,S,d); positions: (B,S) or (B,S,3); tables: (B,P) physical
    page ids when the cache is paged (see attention.PagedKVCache)."""
    zero = jnp.zeros((), jnp.float32)

    if spec.kind == "mlstm":
        if mode == "step":
            y, h = ssm.mlstm_block_step(params, x, cache["h"], cfg)
            return BlockOut(y, {"h": h}, zero)
        y, h = ssm.mlstm_block(params, x, cfg)
        return BlockOut(y, {"h": h} if mode == "prefill" else None, zero)

    if spec.kind == "slstm":
        if mode == "step":
            y, s = ssm.slstm_block_step(params, x, cache["s"], cfg)
            return BlockOut(y, {"s": s}, zero)
        y, s = ssm.slstm_block(params, x, cfg)
        return BlockOut(y, {"s": s} if mode == "prefill" else None, zero)

    if spec.kind == "hymba":
        xr = rmsnorm(params["norm1"], x, cfg.norm_eps)
        if mode == "train":
            a = self_attention(params["attn"], xr, cfg, spec, positions)
            m, _ = ssm.mamba_branch(params["mamba"], xr, cfg)
            new_cache = None
        elif mode == "prefill":
            a, kv = self_attention_prefill(params["attn"], xr, cache["kv"],
                                           cfg, spec, positions)
            m, st = ssm.mamba_branch(params["mamba"], xr, cfg)
            new_cache = {"kv": kv, "ssm": st}
        else:
            a, kv = self_attention_cached(params["attn"], xr, cache["kv"],
                                          cfg, spec, positions)
            m, st = ssm.mamba_branch_step(params["mamba"], xr,
                                          cache["ssm"], cfg)
            new_cache = {"kv": kv, "ssm": st}
        x = x + 0.5 * (a + m)
        xr2 = rmsnorm(params["norm2"], x, cfg.norm_eps)
        y, aux = _ffn(params, xr2, cfg, spec)
        return BlockOut(x + y, new_cache, aux)

    # --- attn / enc / dec -----------------------------------------------
    causal = spec.kind != "enc"
    xr = rmsnorm(params["norm1"], x, cfg.norm_eps)
    new_cache = dict(cache) if cache is not None else None

    if mode == "train":
        a = self_attention(params["attn"], xr, cfg, spec, positions,
                           causal=causal)
    elif isinstance(cache.get("kv"), PagedKVCache):
        # paged pool: prefill and step are the same write-then-attend
        # gather (a suffix prefill must see a sibling's prefix pages)
        a, kv = self_attention_paged(params["attn"], xr, cache["kv"], cfg,
                                     spec, positions, tables)
        new_cache["kv"] = kv
    elif mode == "prefill":
        a, kv = self_attention_prefill(params["attn"], xr, cache["kv"], cfg,
                                       spec, positions)
        new_cache["kv"] = kv
    else:
        a, kv = self_attention_cached(params["attn"], xr, cache["kv"], cfg,
                                      spec, positions)
        new_cache["kv"] = kv

    if spec.parallel_block:
        # cohere: attention and FFN read the same normed input, summed
        y, aux = _ffn(params, xr, cfg, spec)
        return BlockOut(x + a + y, new_cache, aux)

    x = x + a

    if spec.cross_attention:
        xq = rmsnorm(params["norm_x"], x, cfg.norm_eps)
        if mode == "train":
            c = cross_attention(params["cross"], xq, memory, cfg)
        elif mode == "prefill":
            ckv = cross_kv_precompute(params["cross"], memory)
            c = cross_attention_cached(params["cross"], xq, ckv)
            new_cache["cross"] = ckv
        else:
            c = cross_attention_cached(params["cross"], xq, cache["cross"])
        x = x + c

    xr2 = rmsnorm(params["norm2"], x, cfg.norm_eps)
    y, aux = _ffn(params, xr2, cfg, spec)
    return BlockOut(x + y, new_cache, aux)
