"""Model assembly: embeddings → stack-plan segments (nested lax.scan) →
final norm → unembed.  Covers decoder-only LMs, the VLM stub path, and
encoder-decoder models, with train / prefill / decode_step entry points.

Layer stacks execute as ``lax.scan`` over stacked parameters so compile
time scales with the number of *distinct block types*, not layers — a
126-layer llama3-405b lowers as one scan.  ``cfg.remat`` wraps the scan
body in ``jax.checkpoint`` (nothing saved inside a layer), the standard
memory/recompute trade recorded in the roofline's MODEL_FLOPS/HLO_FLOPs
ratio.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, Segment
from repro.models import params as prm
from repro.models.blocks import (block_apply, block_defs, init_block_cache,
                                 init_paged_block_cache)
from repro.models.layers import (chunked_unembed_xent, embed, embed_defs,
                                 rmsnorm, rmsnorm_defs, softmax_xent,
                                 unembed, unembed_defs, unembed_tied)

# ---------------------------------------------------------------------------
# Defs
# ---------------------------------------------------------------------------


def _segment_defs(cfg: ModelConfig, seg: Segment) -> dict:
    out = {}
    for j, (spec, n) in enumerate(seg.pattern):
        d = block_defs(cfg, spec)
        dims = (seg.repeat, n) if seg.repeat > 1 else (n,)
        out[f"e{j}"] = prm.stack(d, *dims)
    return out


def model_defs(cfg: ModelConfig) -> dict:
    defs: dict[str, Any] = {
        "embed": embed_defs(cfg.vocab, cfg.d_model),
        "final_norm": rmsnorm_defs(cfg.d_model),
        "decoder": [_segment_defs(cfg, s) for s in cfg.plan()],
    }
    if not cfg.tie_embeddings:
        defs["unembed"] = unembed_defs(cfg.d_model, cfg.vocab)
    if cfg.is_encdec:
        defs["encoder"] = [_segment_defs(cfg, s) for s in cfg.enc_plan()]
        defs["enc_norm"] = rmsnorm_defs(cfg.d_model)
    return defs


def init(cfg: ModelConfig, key: jax.Array):
    return prm.init_params(model_defs(cfg), key, jnp.dtype(cfg.dtype))


def param_count(cfg: ModelConfig) -> int:
    return prm.count_params(model_defs(cfg))


# ---------------------------------------------------------------------------
# Stack execution
# ---------------------------------------------------------------------------


def _constrain_act(x: jax.Array, cfg: ModelConfig,
                   seq_sharded: bool = True) -> jax.Array:
    """Activation sharding constraints (no-op without a mesh or when
    ``cfg.act_sharding`` is off).

    ``seq_sharded=True`` — layer-BOUNDARY layout: batch over data axes
    and, Megatron-style sequence parallelism, seq over ``model``: the
    per-layer residuals saved for backward shrink by the TP degree.

    ``seq_sharded=False`` — block-INTERIOR layout: seq gathered (batch
    over data only).  Inside a block the weights are TP-sharded over
    ``model``; if the sequence were too, GSPMD resolves the conflict by
    all-gathering the *weights* (a full 53k×16k w_out per layer for
    llama3-405b).  Gathering the (much smaller) activations instead is
    exactly the Megatron-SP schedule; remat recomputes the gather in
    the backward pass."""
    if not cfg.act_sharding or x.ndim != 3 or x.shape[1] == 1:
        return x
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty or not mesh.axis_names:
            return x
        names = set(mesh.axis_names)
        b = tuple(a for a in ("pod", "data")
                  if a in names and x.shape[0] % mesh.shape[a] == 0)
        s = "model" if (seq_sharded and "model" in names
                        and x.shape[1] % mesh.shape["model"] == 0) else None
        if not b and s is None:
            return x
        spec = jax.sharding.PartitionSpec(b if len(b) > 1 else
                                          (b[0] if b else None), s, None)
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def _scan_blocks(p_stack, spec, x, cfg, positions, mode, cache_stack, memory,
                 tables=None):
    """Scan over one stacked run of identical blocks.

    ``cfg.scan_layers=False`` unrolls the stack into a python loop —
    mathematically identical, hugely slower to compile, but XLA's
    ``cost_analysis`` counts a while-loop body only ONCE, so the dry-run
    lowers the unrolled form when it needs honest FLOP/collective counts
    (see launch/dryrun.py)."""

    def body_train(xc, p):
        out = block_apply(p, xc, cfg, spec, positions, mode, None, memory)
        # Sequence-parallel boundary: the saved-for-backward residual
        # stack shrinks by the TP degree (16.9 GB -> 1.05 GB/dev for
        # llama3-405b), at the cost of seq<->TP resharding inside each
        # block's backward dots.  Measured against batch-only sharding
        # this wins by ~21 GB/dev (see EXPERIMENTS.md §Perf).
        return _constrain_act(out.x, cfg), out.aux

    def body_cached(xc, xs):
        p, c = xs
        out = block_apply(p, xc, cfg, spec, positions, mode, c, memory,
                          tables)
        return _constrain_act(out.x, cfg), (out.cache, out.aux)

    if mode == "train":
        body = jax.checkpoint(body_train) if cfg.remat else body_train
        if not cfg.scan_layers:
            n = jax.tree.leaves(p_stack)[0].shape[0]
            aux = jnp.zeros((), jnp.float32)
            for i in range(n):
                x, a = body(x, jax.tree.map(lambda t: t[i], p_stack))
                aux += a
            return x, None, aux
        x, auxes = jax.lax.scan(body, x, p_stack)
        return x, None, jnp.sum(auxes)
    if not cfg.scan_layers:
        n = jax.tree.leaves(p_stack)[0].shape[0]
        aux = jnp.zeros((), jnp.float32)
        caches = []
        for i in range(n):
            x, (c, a) = body_cached(
                x, (jax.tree.map(lambda t: t[i], p_stack),
                    jax.tree.map(lambda t: t[i], cache_stack)))
            caches.append(c)
            aux += a
        stacked = jax.tree.map(lambda *cs: jnp.stack(cs), *caches)
        return x, stacked, aux
    x, (caches, auxes) = jax.lax.scan(body_cached, x, (p_stack, cache_stack))
    return x, caches, jnp.sum(auxes)


def _run_segment(seg_params, seg: Segment, x, cfg, positions, mode,
                 seg_cache, memory, tables=None):
    aux_total = jnp.zeros((), jnp.float32)

    if seg.repeat == 1:
        new_cache = {}
        for j, (spec, n) in enumerate(seg.pattern):
            c = seg_cache[f"e{j}"] if seg_cache is not None else None
            x, nc, aux = _scan_blocks(seg_params[f"e{j}"], spec, x, cfg,
                                      positions, mode, c, memory, tables)
            new_cache[f"e{j}"] = nc
            aux_total += aux
        return x, (new_cache if mode != "train" else None), aux_total

    # nested: outer scan over `repeat`, inner scans over each element
    def outer_train(xc, ps):
        aux = jnp.zeros((), jnp.float32)
        for j, (spec, n) in enumerate(seg.pattern):
            xc, _, a = _scan_blocks(ps[f"e{j}"], spec, xc, cfg, positions,
                                    mode, None, memory)
            aux += a
        return xc, aux

    def outer_cached(xc, xs):
        ps, cs = xs
        aux = jnp.zeros((), jnp.float32)
        new_cs = {}
        for j, (spec, n) in enumerate(seg.pattern):
            xc, nc, a = _scan_blocks(ps[f"e{j}"], spec, xc, cfg, positions,
                                     mode, cs[f"e{j}"], memory, tables)
            new_cs[f"e{j}"] = nc
            aux += a
        return xc, (new_cs, aux)

    if not cfg.scan_layers:
        take = lambda tree, r: jax.tree.map(lambda t: t[r], tree)
        if mode == "train":
            for r in range(seg.repeat):
                x, a = outer_train(x, take(seg_params, r))
                aux_total += a
            return x, None, aux_total
        caches = []
        for r in range(seg.repeat):
            x, (c, a) = outer_cached(x, (take(seg_params, r),
                                         take(seg_cache, r)))
            caches.append(c)
            aux_total += a
        stacked = jax.tree.map(lambda *cs: jnp.stack(cs), *caches)
        return x, stacked, aux_total

    if mode == "train":
        x, auxes = jax.lax.scan(outer_train, x, seg_params)
        return x, None, aux_total + jnp.sum(auxes)
    x, (caches, auxes) = jax.lax.scan(outer_cached, x,
                                      (seg_params, seg_cache))
    return x, caches, aux_total + jnp.sum(auxes)


def _run_plan(plan, params_list, x, cfg, positions, mode, cache_list, memory,
              tables=None):
    aux = jnp.zeros((), jnp.float32)
    new_caches = []
    for i, seg in enumerate(plan):
        c = cache_list[i] if cache_list is not None else None
        x, nc, a = _run_segment(params_list[i], seg, x, cfg, positions,
                                mode, c, memory, tables)
        new_caches.append(nc)
        aux += a
    return x, (new_caches if mode != "train" else None), aux


# ---------------------------------------------------------------------------
# Positions / embeddings
# ---------------------------------------------------------------------------


def default_positions(cfg: ModelConfig, b: int, s: int,
                      offset=None) -> jax.Array:
    pos = jnp.arange(s, dtype=jnp.int32)[None, :]
    pos = jnp.broadcast_to(pos, (b, s))
    if offset is not None:
        pos = pos + offset[:, None]
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(pos[..., None], (b, s, len(cfg.mrope_sections)))
    return pos


def _embed_inputs(params, cfg, tokens, vision_embeds):
    x = embed(params["embed"], tokens)
    if vision_embeds is not None:
        # VLM stub: precomputed patch embeddings occupy the first P slots
        p = vision_embeds.shape[1]
        x = jax.lax.dynamic_update_slice_in_dim(
            x, vision_embeds.astype(x.dtype), 0, axis=1)
    return x


def _logits(params, cfg, x):
    if cfg.tie_embeddings:
        return unembed_tied(params["embed"], x, cfg.logit_softcap)
    return unembed(params["unembed"], x, cfg.logit_softcap)


# ---------------------------------------------------------------------------
# Encoder (enc-dec models; frontend stub provides frame embeddings)
# ---------------------------------------------------------------------------


def encode(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    b, t, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    x, _, _ = _run_plan(cfg.enc_plan(), params["encoder"], frames, cfg,
                        pos, "train", None, None)
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Train / forward
# ---------------------------------------------------------------------------


def forward(params, cfg: ModelConfig, tokens: jax.Array, *,
            positions=None, vision_embeds=None, frames=None):
    """Full-sequence forward -> (logits (B,S,V), aux)."""
    b, s = tokens.shape
    memory = encode(params, cfg, frames) if cfg.is_encdec else None
    if positions is None:
        positions = default_positions(cfg, b, s)
    x = _embed_inputs(params, cfg, tokens, vision_embeds)
    x, _, aux = _run_plan(cfg.plan(), params["decoder"], x, cfg, positions,
                          "train", None, memory)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, cfg, x), aux


AUX_WEIGHT = 0.01


def hidden_states(params, cfg: ModelConfig, tokens: jax.Array, *,
                  positions=None, vision_embeds=None, frames=None):
    """Trunk only: embeddings → stack → final norm.  (B, S, D)."""
    b, s = tokens.shape
    memory = encode(params, cfg, frames) if cfg.is_encdec else None
    if positions is None:
        positions = default_positions(cfg, b, s)
    x = _embed_inputs(params, cfg, tokens, vision_embeds)
    x, _, aux = _run_plan(cfg.plan(), params["decoder"], x, cfg, positions,
                          "train", None, memory)
    return rmsnorm(params["final_norm"], x, cfg.norm_eps), aux


def loss_fn(params, cfg: ModelConfig, batch: dict):
    """batch: tokens (B,S), labels (B,S) (-1 = pad), optional positions /
    vision_embeds / frames.  Returns (loss, metrics).

    The unembed+xent runs seq-chunked (cfg.loss_chunk) so the full
    (B, S, V) logits tensor never exists — for 128k-256k vocab configs
    this is the dominant activation saving of the whole step."""
    x, aux = hidden_states(
        params, cfg, batch["tokens"],
        positions=batch.get("positions"),
        vision_embeds=batch.get("vision_embeds"),
        frames=batch.get("frames"))
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    xent = chunked_unembed_xent(lambda xc: _logits(params, cfg, xc),
                                x, jnp.maximum(labels, 0), mask,
                                cfg.loss_chunk)
    loss = xent + AUX_WEIGHT * aux
    return loss, {"loss": loss, "xent": xent, "aux": aux}


# ---------------------------------------------------------------------------
# Cache + serving entry points
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_context: int,
               enc_len: int = 0, layout: str = "ring",
               num_pages: int = 0, page_size: int = 128) -> dict:
    """Decode-state pytree.  ``layout='ring'`` (default) builds the
    slot-contiguous ring buffers; ``layout='paged'`` builds one shared
    page pool per layer sized by the allocator's ``num_pages`` — block
    tables (traced per step) then map each slot onto its pages, so
    admission/eviction never changes the compiled shapes."""
    dtype = jnp.dtype(cfg.dtype)
    if layout == "paged" and num_pages <= 0:
        raise ValueError("paged cache layout needs num_pages > 0")

    def seg_cache(seg: Segment):
        out = {}
        for j, (spec, n) in enumerate(seg.pattern):
            if layout == "paged":
                one = init_paged_block_cache(cfg, spec, num_pages,
                                             page_size, dtype)
            else:
                one = init_block_cache(cfg, spec, batch, max_context, dtype,
                                       enc_len)
            dims = (seg.repeat, n) if seg.repeat > 1 else (n,)
            out[f"e{j}"] = jax.tree.map(
                lambda a: jnp.tile(a, dims + (1,) * a.ndim), one)
        return out

    return {
        "segments": [seg_cache(s) for s in cfg.plan()],
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def prefill(params, cfg: ModelConfig, tokens: jax.Array, cache: dict, *,
            positions=None, vision_embeds=None, frames=None):
    """One-shot prefill from position 0.  Returns (last-token logits (B,V),
    updated cache)."""
    b, s = tokens.shape
    memory = encode(params, cfg, frames) if cfg.is_encdec else None
    if positions is None:
        positions = default_positions(cfg, b, s)
    x = _embed_inputs(params, cfg, tokens, vision_embeds)
    x, segs, _ = _run_plan(cfg.plan(), params["decoder"], x, cfg, positions,
                           "prefill", cache["segments"], memory)
    x_last = rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = _logits(params, cfg, x_last)[:, 0]
    new_cache = {"segments": segs,
                 "pos": jnp.full((b,), s, jnp.int32)}
    return logits, new_cache


def decode_step(params, cfg: ModelConfig, tokens: jax.Array, cache: dict,
                tables=None):
    """tokens: (B, 1) — one new token per sequence.  ``tables`` (B, P)
    carries the live allocator block tables for a paged-layout cache
    (traced, so page churn never recompiles).  Returns
    (logits (B,V), updated cache)."""
    b = tokens.shape[0]
    pos = cache["pos"]                                   # (B,)
    positions = pos[:, None]
    if cfg.mrope_sections:
        positions = jnp.broadcast_to(positions[..., None],
                                     (b, 1, len(cfg.mrope_sections)))
    x = embed(params["embed"], tokens)
    x, segs, _ = _run_plan(cfg.plan(), params["decoder"], x, cfg, positions,
                           "step", cache["segments"], None, tables)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(params, cfg, x)[:, 0]
    return logits, {"segments": segs, "pos": pos + 1}


def prefill_paged(params, cfg: ModelConfig, tokens: jax.Array, cache: dict,
                  tables: jax.Array, start: jax.Array, slot: jax.Array):
    """Suffix prefill into the shared page pool.

    tokens (1, S): the *uncached* prompt suffix; start (1,) int32: the
    cached-prefix length (absolute position of tokens[0]); tables (1, P):
    the sequence's block-table row (prefix pages first — already holding
    a sibling's KV — then private pages); slot: the batch slot whose
    ``pos`` to advance.  The cached prefix is never recomputed and never
    copied: its pages are simply referenced by id, which is the
    zero-copy shared-prefix admission path.  Returns (last-token logits
    (1, V), updated cache)."""
    b, s = tokens.shape
    positions = default_positions(cfg, b, s, offset=start)
    x = _embed_inputs(params, cfg, tokens, None)
    x, segs, _ = _run_plan(cfg.plan(), params["decoder"], x, cfg, positions,
                           "prefill", cache["segments"], None, tables)
    x_last = rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = _logits(params, cfg, x_last)[:, 0]
    pos = cache["pos"].at[slot].set(start[0] + s)
    return logits, {"segments": segs, "pos": pos}


def prefill_paged_padded(params, cfg: ModelConfig, tokens: jax.Array,
                         cache: dict, tables: jax.Array, start: jax.Array,
                         slot: jax.Array, n: jax.Array):
    """Shape-stable suffix prefill: a fixed-capacity chunk buffer with a
    *traced* valid length.

    tokens (1, C): prompt-chunk buffer at fixed capacity C; only the
    first ``n`` tokens are real (``n`` is a traced int32, so varying
    chunk fill never retraces — the mixed step's contract).  Padded tail
    positions are -1: their KV writes route to the pool's sink page
    (``_phys_slots``) and their queries mask to nothing, so the padding
    is inert.  Logits are taken at index ``n - 1`` (the last *valid*
    token) and ``pos[slot]`` advances to ``start + n``.  ``start`` and
    ``slot`` are scalar traced int32."""
    b, c = tokens.shape
    idx = jnp.arange(c, dtype=jnp.int32)[None, :]
    positions = jnp.where(idx < n, start + idx, -1)
    if cfg.mrope_sections:
        positions = jnp.broadcast_to(positions[..., None],
                                     (b, c, len(cfg.mrope_sections)))
    x = _embed_inputs(params, cfg, tokens, None)
    x, segs, _ = _run_plan(cfg.plan(), params["decoder"], x, cfg, positions,
                           "prefill", cache["segments"], None, tables)
    x_last = jax.lax.dynamic_slice_in_dim(x, n - 1, 1, axis=1)
    x_last = rmsnorm(params["final_norm"], x_last, cfg.norm_eps)
    logits = _logits(params, cfg, x_last)[:, 0]
    pos = cache["pos"].at[slot].set(start + n)
    return logits, {"segments": segs, "pos": pos}


# ---------------------------------------------------------------------------
# Paged <-> ring state bridge (KV migration for paged engines)
# ---------------------------------------------------------------------------


def _map_paged_kv(cache: dict, fn):
    """Apply ``fn(paged_kv, stack_dims)`` to every PagedKVCache in the
    cache's segment tree (leaves carry leading layer-stack dims)."""
    from repro.models.attention import PagedKVCache

    def walk(node):
        if isinstance(node, PagedKVCache):
            return fn(node)
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node

    return walk(cache["segments"])


def paged_extract(cfg: ModelConfig, cache: dict, table_row, ctx: int,
                  max_context: int, slot: int) -> dict:
    """Pull one sequence out of the paged pool as a batch-1 *ring*-layout
    cache — the same format ring engines extract/inject, so KV migration
    is layout-agnostic (a paged engine can hand a sequence to a ring
    engine and vice versa)."""
    from repro.models import attention as attn

    tables = jnp.asarray(table_row, jnp.int32)[None]       # (1, P)

    def one(pkv):
        def leaf(k, v):
            # collapse layer-stack dims, gather per layer, re-stack
            stack = k.shape[:-4]
            kf = k.reshape((-1,) + k.shape[-4:])
            vf = v.reshape((-1,) + v.shape[-4:])
            outs = [_pool_to_ring(cfg, attn.PagedKVCache(kf[i], vf[i]),
                                  tables, ctx, max_context)
                    for i in range(kf.shape[0])]
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
            return jax.tree.map(
                lambda a: a.reshape(stack + a.shape[1:]), stacked)
        return leaf(pkv.k, pkv.v)

    segs = _map_paged_kv(cache, one)
    pos = jax.lax.dynamic_slice_in_dim(cache["pos"], slot, 1)
    return {"segments": segs, "pos": pos}


def _pool_to_ring(cfg, pkv, tables, ctx: int, max_context: int):
    from repro.models import attention as attn
    view = attn.paged_view(pkv, tables)                  # (1, P*page, ...)
    size = max_context
    ring = attn.init_kv_cache(1, size, pkv.k.shape[-2], pkv.k.shape[-1],
                              pkv.k.dtype)
    if ctx <= 0:
        return ring
    n = min(ctx, view.k.shape[1])
    return attn.cache_write(ring, view.k[:, :n], view.v[:, :n],
                            jnp.zeros((1,), jnp.int32))


def paged_insert(cfg: ModelConfig, cache: dict, sub: dict, table_row,
                 slot) -> dict:
    """Install a batch-1 ring-layout cache (from ``paged_extract`` or a
    ring engine's extract) into the paged pool at ``table_row``'s pages.
    Ring slots are scattered through their absolute ``kpos`` (wrapped
    SWA rings land at the right logical pages; empty slots hit the
    sink)."""
    from repro.models import attention as attn

    tables = jnp.asarray(table_row, jnp.int32)[None]       # (1, P)
    sub_leaves = []

    def collect(node):
        if isinstance(node, attn.KVCache):
            sub_leaves.append(node)
            return node
        if isinstance(node, dict):
            for v in node.values():
                collect(v)
        elif isinstance(node, list):
            for v in node:
                collect(v)
        return node

    collect(sub["segments"])
    it = iter(sub_leaves)

    def one(pkv):
        ring = next(it)

        def leaf(k, v, rk, rv, rpos):
            stack = k.shape[:-4]
            kf = k.reshape((-1,) + k.shape[-4:])
            vf = v.reshape((-1,) + v.shape[-4:])
            rkf = rk.reshape((-1,) + rk.shape[-4:])
            rvf = rv.reshape((-1,) + rv.shape[-4:])
            rpf = rpos.reshape((-1,) + rpos.shape[-2:])
            outs = [attn.paged_cache_write_at(
                        attn.PagedKVCache(kf[i], vf[i]),
                        rkf[i].astype(kf.dtype), rvf[i].astype(kf.dtype),
                        rpf[i], tables)
                    for i in range(kf.shape[0])]
            ks = jnp.stack([o.k for o in outs]).reshape(stack + k.shape[-4:])
            vs = jnp.stack([o.v for o in outs]).reshape(stack + v.shape[-4:])
            return attn.PagedKVCache(ks, vs)
        return leaf(pkv.k, pkv.v, ring.k, ring.v, ring.kpos)

    segs = _map_paged_kv(cache, one)
    pos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], sub["pos"].astype(jnp.int32), slot, axis=0)
    return {"segments": segs, "pos": pos}
