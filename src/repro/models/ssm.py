"""State-space / linear-recurrence blocks: mamba-2 (SSD) for hymba and
mLSTM / sLSTM for xLSTM.

All matrix-state recurrences reduce to ONE primitive (TPU adaptation —
see DESIGN.md §3: mamba-1's per-channel selective scan is restructured
into the mamba-2/SSD *chunked decayed linear attention* form so the inner
loops are MXU matmuls instead of elementwise scans):

    h_t = a_t * h_{t-1} + k_t ⊗ v_t          (state: (dk, dv) per head)
    y_t = q_t · h_t

``chunked_linear_attention`` evaluates it chunk-parallel (intra-chunk
masked matmuls + inter-chunk carry) — the same algorithm the
``ssm_scan`` Pallas kernel implements on TPU; ``recurrent_step`` is the
O(1) decode update.

Numerics adaptation (documented in DESIGN.md §8): xLSTM's exponential
gating is replaced with sigmoid gates + the normalizer column, keeping
the matrix-memory structure while avoiding the max-stabilizer state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rmsnorm, rmsnorm_defs
from repro.models.params import P

# ---------------------------------------------------------------------------
# Core primitive
# ---------------------------------------------------------------------------


def chunked_linear_attention(q, k, v, log_a, h0, chunk: int = 128,
                             unroll: bool = False):
    """q,k: (B,T,H,dk); v: (B,T,H,dv); log_a: (B,T,H) (<=0);
    h0: (B,H,dk,dv) f32.  Returns (y: (B,T,H,dv), hT)."""
    b, t, h, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk

    qf = q.astype(jnp.float32).reshape(b, nc, chunk, h, dk)
    kf = k.astype(jnp.float32).reshape(b, nc, chunk, h, dk)
    vf = v.astype(jnp.float32).reshape(b, nc, chunk, h, dv)
    la = log_a.astype(jnp.float32).reshape(b, nc, chunk, h)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(h_prev, xs):
        qc, kc, vc, lac = xs                       # (B,C,H,*)
        L = jnp.cumsum(lac, axis=1)                # inclusive, (B,C,H)
        Lh = jnp.moveaxis(L, -1, 1)                # (B,H,C)
        # intra-chunk: S_ij = (q_i.k_j) * exp(L_i - L_j), j<=i
        scores = jnp.einsum("bihd,bjhd->bhij", qc, kc)
        ldiff = Lh[:, :, :, None] - Lh[:, :, None, :]
        decay = jnp.exp(jnp.where(causal[None, None], ldiff, -jnp.inf))
        y_intra = jnp.einsum("bhij,bjhd->bihd", scores * decay, vc)
        # inter-chunk: y_i += exp(L_i) q_i . h_prev
        q_scaled = qc * jnp.exp(L)[..., None]
        y_inter = jnp.einsum("bihd,bhde->bihe", q_scaled, h_prev)
        # carry: h_new = exp(L_last) h_prev + sum_j exp(L_last - L_j) k_j v_j^T
        l_last = Lh[:, :, -1]                      # (B,H)
        rem = jnp.exp(l_last[:, None, :] - L)      # (B,C,H)
        kv = jnp.einsum("bjhd,bjhe->bhde", kc * rem[..., None], vc)
        h_new = jnp.exp(l_last)[..., None, None] * h_prev + kv
        return h_new, y_intra + y_inter

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (qf, kf, vf, la))
    # unroll=True: the dry-run's cost pass — XLA counts a while body
    # once, so honest FLOP totals need the chunk loop flattened
    h_t, ys = jax.lax.scan(body, h0.astype(jnp.float32), xs,
                           unroll=True if unroll else 1)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, t, h, dv)
    return y.astype(v.dtype), h_t


def recurrent_step(q, k, v, log_a, h):
    """Single-token update.  q,k: (B,1,H,dk); v: (B,1,H,dv); log_a (B,1,H);
    h: (B,H,dk,dv).  Returns (y (B,1,H,dv), h_new)."""
    a = jnp.exp(log_a.astype(jnp.float32))[:, 0, :, None, None]
    kv = jnp.einsum("bhd,bhe->bhde", k[:, 0].astype(jnp.float32),
                    v[:, 0].astype(jnp.float32))
    h_new = a * h + kv
    y = jnp.einsum("bhd,bhde->bhe", q[:, 0].astype(jnp.float32), h_new)
    return y[:, None].astype(v.dtype), h_new


# ---------------------------------------------------------------------------
# Causal depthwise conv (mamba front)
# ---------------------------------------------------------------------------


def causal_conv1d(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (B,T,D), w: (K,D) depthwise.  Causal (pads left)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return out


def conv_step(x_t: jax.Array, w: jax.Array, state: jax.Array):
    """x_t: (B,1,D); state: (B,K-1,D) last inputs.  Returns (y,(B,1,D), new_state)."""
    hist = jnp.concatenate([state, x_t], axis=1)        # (B,K,D)
    y = jnp.einsum("bkd,kd->bd", hist, w)[:, None]
    return y, hist[:, 1:]


# ---------------------------------------------------------------------------
# Mamba-2 (SSD) branch — used inside hymba blocks
# ---------------------------------------------------------------------------

SSM_HEAD_DIM = 64


def mamba_dims(cfg: ModelConfig) -> tuple[int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = max(1, d_inner // SSM_HEAD_DIM)
    d_inner = n_heads * SSM_HEAD_DIM
    return d_inner, n_heads, cfg.ssm_state


def mamba_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_inner, nh, ds = mamba_dims(cfg)
    return {
        "in_proj": P((d, 2 * d_inner), ("embed", "ff")),       # x, z
        "bc_proj": P((d, 2 * ds), ("embed", None)),            # B, C (1 group)
        "dt_proj": P((d, nh), ("embed", None)),
        "dt_bias": P((nh,), (None,), init="zeros", dtype="float32"),
        "a_log": P((nh,), (None,), init="zeros", dtype="float32"),
        "d_skip": P((nh,), (None,), init="ones", dtype="float32"),
        "conv_w": P((4, d_inner), (None, None)),
        "out_proj": P((d_inner, d), ("ff", "embed")),
    }


def _mamba_qkv(params, x, cfg):
    """Shared projections.  x: (B,T,d) -> (q,k,v,log_a,z) in SSD layout."""
    b, t, _ = x.shape
    d_inner, nh, ds = mamba_dims(cfg)
    xz = jnp.einsum("btd,de->bte", x, params["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)
    bc = jnp.einsum("btd,de->bte", x, params["bc_proj"]).astype(jnp.float32)
    b_in, c_out = jnp.split(bc, 2, axis=-1)                    # (B,T,ds)
    dt = jnp.einsum("btd,dh->bth", x.astype(jnp.float32), params["dt_proj"])
    dt = jax.nn.softplus(dt + params["dt_bias"])               # (B,T,nh)
    log_a = -dt * jnp.exp(params["a_log"])                     # <= 0
    return xs, z, b_in, c_out, dt, log_a


class SSMState(NamedTuple):
    h: jax.Array      # (B, nh, ds, head_dim) f32
    conv: jax.Array   # (B, K-1, d_inner)


def mamba_branch(params: dict, x: jax.Array,
                 cfg: ModelConfig) -> tuple[jax.Array, "SSMState"]:
    """Full-sequence mamba branch: (B,T,d) -> ((B,T,d), final state)."""
    b, t, _ = x.shape
    d_inner, nh, ds = mamba_dims(cfg)
    xs_pre, z, b_in, c_out, dt, log_a = _mamba_qkv(params, x, cfg)
    xs = causal_conv1d(xs_pre, params["conv_w"])
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(x.dtype)
    xh = xs.reshape(b, t, nh, SSM_HEAD_DIM)
    v = xh * dt[..., None].astype(xh.dtype)                    # fold dt in
    q = jnp.broadcast_to(c_out[:, :, None, :], (b, t, nh, ds)).astype(x.dtype)
    k = jnp.broadcast_to(b_in[:, :, None, :], (b, t, nh, ds)).astype(x.dtype)
    h0 = jnp.zeros((b, nh, ds, SSM_HEAD_DIM), jnp.float32)
    y, h_t = chunked_linear_attention(q, k, v, log_a, h0,
                                      unroll=cfg.unroll_ssm)
    y = y + xh * params["d_skip"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(b, t, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    out = jnp.einsum("bte,ed->btd", y, params["out_proj"])
    conv_k = params["conv_w"].shape[0]
    if t >= conv_k - 1:
        conv_state = xs_pre[:, t - (conv_k - 1):]
    else:
        conv_state = jnp.pad(xs_pre, ((0, 0), (conv_k - 1 - t, 0), (0, 0)))
    return out, SSMState(h=h_t, conv=conv_state)


def init_ssm_state(batch: int, cfg: ModelConfig, dtype) -> SSMState:
    d_inner, nh, ds = mamba_dims(cfg)
    return SSMState(h=jnp.zeros((batch, nh, ds, SSM_HEAD_DIM), jnp.float32),
                    conv=jnp.zeros((batch, 3, d_inner), dtype))


def mamba_branch_step(params: dict, x: jax.Array, state: SSMState,
                      cfg: ModelConfig) -> tuple[jax.Array, SSMState]:
    """Decode: x (B,1,d)."""
    b = x.shape[0]
    d_inner, nh, ds = mamba_dims(cfg)
    xs, z, b_in, c_out, dt, log_a = _mamba_qkv(params, x, cfg)
    xs, conv_state = conv_step(xs, params["conv_w"], state.conv)
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(x.dtype)
    xh = xs.reshape(b, 1, nh, SSM_HEAD_DIM)
    v = xh * dt[..., None].astype(xh.dtype)
    q = jnp.broadcast_to(c_out[:, :, None, :], (b, 1, nh, ds)).astype(x.dtype)
    k = jnp.broadcast_to(b_in[:, :, None, :], (b, 1, nh, ds)).astype(x.dtype)
    y, h_new = recurrent_step(q, k, v, log_a, state.h)
    y = y + xh * params["d_skip"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(b, 1, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    out = jnp.einsum("bte,ed->btd", y, params["out_proj"])
    return out, SSMState(h=h_new, conv=conv_state)


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM)
# ---------------------------------------------------------------------------


def mlstm_dims(cfg: ModelConfig) -> tuple[int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    nh = cfg.n_heads
    dh = d_inner // nh
    return d_inner, nh, dh


def mlstm_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_inner, nh, dh = mlstm_dims(cfg)
    return {
        "norm": rmsnorm_defs(d),
        "up_proj": P((d, 2 * d_inner), ("embed", "ff")),       # x, z
        "wq": P((d_inner, nh, dh), ("ff", "heads", None)),
        "wk": P((d_inner, nh, dh), ("ff", "heads", None)),
        "wv": P((d_inner, nh, dh), ("ff", "heads", None)),
        "w_if": P((d_inner, 2 * nh), ("ff", None)),            # i, f gates
        "gn": P((nh, dh), (None, None), init="ones", dtype="float32"),
        "down_proj": P((d_inner, d), ("ff", "embed")),
    }


def _mlstm_proj(params, xr, cfg):
    b, t, _ = xr.shape
    d_inner, nh, dh = mlstm_dims(cfg)
    xz = jnp.einsum("btd,de->bte", xr, params["up_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)
    q = jnp.einsum("bte,ehd->bthd", xs, params["wq"]) / jnp.sqrt(float(dh))
    k = jnp.einsum("bte,ehd->bthd", xs, params["wk"]) / jnp.sqrt(float(dh))
    v = jnp.einsum("bte,ehd->bthd", xs, params["wv"])
    gates = jnp.einsum("bte,eh->bth", xs.astype(jnp.float32), params["w_if"])
    i_g, f_g = jnp.split(gates, 2, axis=-1)                    # (B,T,nh)
    log_a = jax.nn.log_sigmoid(f_g)                            # <= 0
    i_t = jax.nn.sigmoid(i_g)
    # fold input gate into k; append normalizer ones-column to v
    k = k * i_t[..., None].astype(k.dtype)
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    return q, k, v_aug, log_a, z


def _mlstm_out(params, y_aug, z, cfg):
    b, t = y_aug.shape[:2]
    d_inner, nh, dh = mlstm_dims(cfg)
    y, n = y_aug[..., :-1], y_aug[..., -1:]
    y = y / jnp.maximum(jnp.abs(n), 1.0).astype(y.dtype)
    # per-head group norm
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * params["gn"]
    y = yf.astype(z.dtype).reshape(b, t, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    return jnp.einsum("bte,ed->btd", y, params["down_proj"])


def mlstm_block(params: dict, x: jax.Array,
                cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    xr = rmsnorm(params["norm"], x, cfg.norm_eps)
    q, k, v_aug, log_a, z = _mlstm_proj(params, xr, cfg)
    b = x.shape[0]
    _, nh, dh = mlstm_dims(cfg)
    h0 = jnp.zeros((b, nh, dh, dh + 1), jnp.float32)
    y_aug, h_t = chunked_linear_attention(q, k, v_aug, log_a, h0,
                                          unroll=cfg.unroll_ssm)
    return x + _mlstm_out(params, y_aug, z, cfg), h_t


def init_mlstm_state(batch: int, cfg: ModelConfig) -> jax.Array:
    _, nh, dh = mlstm_dims(cfg)
    return jnp.zeros((batch, nh, dh, dh + 1), jnp.float32)


def mlstm_block_step(params: dict, x: jax.Array, h: jax.Array,
                     cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    xr = rmsnorm(params["norm"], x, cfg.norm_eps)
    q, k, v_aug, log_a, z = _mlstm_proj(params, xr, cfg)
    y_aug, h_new = recurrent_step(q, k, v_aug, log_a, h)
    return x + _mlstm_out(params, y_aug, z, cfg), h_new


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM) — sequential scalar-memory recurrence
# ---------------------------------------------------------------------------


def slstm_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    nh, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    ff = -(-4 * d // 3 // 64) * 64                  # gated FFN, ~4d/3
    return {
        "norm": rmsnorm_defs(d),
        "w_gates": P((d, 4 * d), ("embed", "ff")),
        "r_gates": P((nh, dh, 4 * dh), (None, None, None)),  # per-head recur.
        "ffn_norm": rmsnorm_defs(d),
        "ffn_in": P((d, ff), ("embed", "ff")),
        "ffn_gate": P((d, ff), ("embed", "ff")),
        "ffn_out": P((ff, d), ("ff", "embed")),
    }


class SLSTMState(NamedTuple):
    c: jax.Array   # (B, d) f32
    n: jax.Array   # (B, d) f32
    h: jax.Array   # (B, d) f32


def init_slstm_state(batch: int, cfg: ModelConfig) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(c=z, n=z, h=z)


def _slstm_cell(params, wx_t, state: SLSTMState, nh: int, dh: int):
    """wx_t: (B, 4d) input contribution at time t."""
    b = wx_t.shape[0]
    hr = state.h.reshape(b, nh, dh)
    rec = jnp.einsum("bhd,hde->bhe", hr, params["r_gates"]).reshape(b, 4 * nh * dh)
    pre = (wx_t + rec).reshape(b, 4, nh * dh)
    z_t = jnp.tanh(pre[:, 0])
    i_t = jax.nn.sigmoid(pre[:, 1])
    f_t = jax.nn.sigmoid(pre[:, 2])
    o_t = jax.nn.sigmoid(pre[:, 3])
    c = f_t * state.c + i_t * z_t
    n = f_t * state.n + i_t
    h = o_t * c / jnp.maximum(n, 1.0)
    return SLSTMState(c=c, n=n, h=h)


def _slstm_ffn(params, x, cfg):
    xr = rmsnorm(params["ffn_norm"], x, cfg.norm_eps)
    h = jnp.einsum("btd,df->btf", xr, params["ffn_in"])
    g = jnp.einsum("btd,df->btf", xr, params["ffn_gate"])
    h = h * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
    return x + jnp.einsum("btf,fd->btd", h, params["ffn_out"])


def slstm_block(params: dict, x: jax.Array,
                cfg: ModelConfig) -> tuple[jax.Array, SLSTMState]:
    b, t, d = x.shape
    nh, dh = cfg.n_heads, d // cfg.n_heads
    xr = rmsnorm(params["norm"], x, cfg.norm_eps)
    wx = jnp.einsum("btd,de->bte", xr.astype(jnp.float32),
                    params["w_gates"].astype(jnp.float32))
    # gate blocks laid out as (4, nh*dh) — see _slstm_cell
    state0 = init_slstm_state(b, cfg)

    def body(state, wx_t):
        new = _slstm_cell(params, wx_t, state, nh, dh)
        return new, new.h

    final, hs = jax.lax.scan(body, state0, jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    x = x + y
    return _slstm_ffn(params, x, cfg), final


def slstm_block_step(params: dict, x: jax.Array, state: SLSTMState,
                     cfg: ModelConfig) -> tuple[jax.Array, SLSTMState]:
    b, _, d = x.shape
    nh, dh = cfg.n_heads, d // cfg.n_heads
    xr = rmsnorm(params["norm"], x, cfg.norm_eps)
    wx = jnp.einsum("btd,de->bte", xr.astype(jnp.float32),
                    params["w_gates"].astype(jnp.float32))
    new = _slstm_cell(params, wx[:, 0], state, nh, dh)
    x = x + new.h[:, None].astype(x.dtype)
    return _slstm_ffn(params, x, cfg), new
