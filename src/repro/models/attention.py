"""Attention: GQA, sliding windows, query-chunking, ring-buffer KV caches.

Two execution paths share one masked-softmax core:

* ``attention_full``  — whole-sequence (training / one-shot prefill).  The
  query axis is processed in ``cfg.attn_chunk`` chunks via ``lax.scan`` so
  the score tensor never materializes at (S × S); windowed layers
  additionally ``dynamic_slice`` the K/V stream to ``window + chunk``
  keys per query chunk, which is what makes 32k-prefill local layers and
  500k SWA decoding sub-quadratic in both FLOPs and bytes.

* ``attention_cached`` — attend a (short) query block against a ring-buffer
  KV cache (chunked prefill steps and decode).  The cache stores absolute
  key positions (``kpos``), so sliding-window masks, ring wraparound and
  not-yet-written slots all reduce to one position comparison.

The pure-jnp path here is also the oracle for the Pallas kernels in
``repro.kernels`` (see kernels/ref.py), and is what the dry-run lowers so
``cost_analysis()`` sees real FLOPs (a pallas custom-call would hide them).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import FULL_ATTENTION, BlockSpec, ModelConfig
from repro.models.layers import apply_rope
from repro.models.params import P, tp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter defs
# ---------------------------------------------------------------------------


def attn_defs(cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    defs = {
        "wq": P((d, h, dh), ("embed", "heads", None)),
        "wk": P((d, hkv, dh), ("embed", "kv_heads", None)),
        "wv": P((d, hkv, dh), ("embed", "kv_heads", None)),
        "wo": P((h, dh, d), ("heads", None, "embed")),
    }
    if cfg.qk_norm and not cross:
        defs["q_scale"] = P((dh,), (None,), init="ones", dtype="float32")
        defs["k_scale"] = P((dh,), (None,), init="ones", dtype="float32")
    return defs


# ---------------------------------------------------------------------------
# Core masked attention (GQA grouped layout)
# ---------------------------------------------------------------------------


def _group(q: jax.Array, hkv: int) -> jax.Array:
    b, s, h, dh = q.shape
    return q.reshape(b, s, hkv, h // hkv, dh)


def _qk_rms(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(dt)


def attn_core(q: jax.Array, k: jax.Array, v: jax.Array,
              mask: jax.Array) -> jax.Array:
    """q: (B,Sq,Hkv,G,dh); k,v: (B,T,Hkv,dh); mask: (B,1,1,Sq,T) or
    broadcastable.  Returns (B,Sq,Hkv,G,dh)."""
    dh = q.shape[-1]
    scores = jnp.einsum("bqhgd,bthd->bhgqt", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (1.0 / math.sqrt(dh))
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqt,bthd->bqhgd", probs.astype(v.dtype), v)
    return out


# ---------------------------------------------------------------------------
# Full-sequence path (training / one-shot prefill)
# ---------------------------------------------------------------------------


def _causal_window_mask(qpos: jax.Array, kpos: jax.Array,
                        window: int) -> jax.Array:
    """qpos (Sq,), kpos (T,) -> (1,1,1,Sq,T) bool."""
    m = kpos[None, :] <= qpos[:, None]
    if window > 0:
        m &= kpos[None, :] > (qpos[:, None] - window)
    return m[None, None, None]


def attention_full(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   window: int, chunk: int, causal: bool = True) -> jax.Array:
    """q (B,S,H,dh) vs k,v (B,T,Hkv,dh), queries chunked by ``chunk``."""
    b, s, h, dh = q.shape
    t = k.shape[1]
    hkv = k.shape[2]
    qg = _group(q, hkv)

    if not causal:  # encoder self-attention / cross-attention
        mask = jnp.ones((1, 1, 1, s, t), bool)
        out = attn_core(qg, k, v, mask)
        return out.reshape(b, s, h, dh)

    if s <= chunk or s % chunk != 0:
        # irregular lengths (engine ensures multiples of chunk on hot paths)
        mask = _causal_window_mask(jnp.arange(s), jnp.arange(t), window)
        return attn_core(qg, k, v, mask).reshape(b, s, h, dh)
    n_chunks = s // chunk
    use_slice = window > 0 and t > window + chunk
    kv_span = window + chunk if use_slice else t

    def body(carry, i):
        qs = i * chunk
        qc = jax.lax.dynamic_slice_in_dim(qg, qs, chunk, axis=1)
        if use_slice:
            ks = jnp.clip(qs - window, 0, t - kv_span)
            kc = jax.lax.dynamic_slice_in_dim(k, ks, kv_span, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, ks, kv_span, axis=1)
            kpos = ks + jnp.arange(kv_span)
        else:
            kc, vc, kpos = k, v, jnp.arange(t)
        qpos = qs + jnp.arange(chunk)
        mask = _causal_window_mask(qpos, kpos, window)
        oc = attn_core(qc, kc, vc, mask)
        return carry, oc

    _, chunks = jax.lax.scan(body, None, jnp.arange(n_chunks))
    # chunks: (n_chunks, B, chunk, Hkv, G, dh) -> (B, S, H, dh)
    out = jnp.moveaxis(chunks, 0, 1).reshape(b, s, hkv, h // hkv, dh)
    return out.reshape(b, s, h, dh)


# ---------------------------------------------------------------------------
# Ring-buffer KV cache
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    """Ring buffer over ``size`` slots; ``kpos`` holds the absolute position
    written in each slot (-1 = empty).  For full-attention layers ``size``
    equals the max context so the ring never wraps; for SWA layers
    ``size = window + chunk`` rounded up, bounding cache memory AND the
    bytes each decode step reads — the TPU-adapted equivalent of the
    paper's bounded-cache serving assumption."""

    k: jax.Array       # (B, size, Hkv, dh)
    v: jax.Array       # (B, size, Hkv, dh)
    kpos: jax.Array    # (B, size) int32


def kv_cache_size(spec: BlockSpec, max_context: int, chunk: int) -> int:
    if spec.window > 0:
        size = spec.window + chunk
        return min(-(-size // chunk) * chunk, max_context)
    return max_context


def init_kv_cache(batch: int, size: int, hkv: int, dh: int,
                  dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, size, hkv, dh), dtype),
        v=jnp.zeros((batch, size, hkv, dh), dtype),
        kpos=jnp.full((batch, size), -1, jnp.int32),
    )


def cache_write(cache: KVCache, k_new: jax.Array, v_new: jax.Array,
                start_pos: jax.Array) -> KVCache:
    """Write S_new tokens at absolute positions start_pos..start_pos+S_new.

    start_pos: (B,) int32.  If S_new exceeds the ring size only the last
    ``size`` tokens are written (the older ones would be overwritten
    anyway); this keeps scatter slots unique.
    """
    b, s_new = k_new.shape[:2]
    size = cache.k.shape[1]
    if s_new > size:
        k_new = k_new[:, s_new - size:]
        v_new = v_new[:, s_new - size:]
        start_pos = start_pos + (s_new - size)
        s_new = size
    pos = start_pos[:, None] + jnp.arange(s_new)[None, :]        # (B, S_new)
    slots = pos % size
    bidx = jnp.arange(b)[:, None]
    k = cache.k.at[bidx, slots].set(k_new)
    v = cache.v.at[bidx, slots].set(v_new)
    kpos = cache.kpos.at[bidx, slots].set(pos)
    return KVCache(k, v, kpos)


def _cached_mask(kpos: jax.Array, q_pos: jax.Array,
                 window: int) -> jax.Array:
    """kpos (B,size), q_pos (B,Sq) -> (B,1,1,Sq,size)."""
    mask = (kpos[:, None, :] <= q_pos[:, :, None]) & (kpos[:, None, :] >= 0)
    if window > 0:
        mask &= kpos[:, None, :] > (q_pos[:, :, None] - window)
    return mask[:, None, None]


def attention_cached(q: jax.Array, cache: KVCache, q_pos: jax.Array, *,
                     window: int, chunk: int = 0) -> jax.Array:
    """q: (B,Sq,H,dh) at absolute positions q_pos (B,Sq).  Assumes the
    q tokens' own K/V were already written (write-then-attend).  Large Sq
    is processed in ``chunk``-sized query blocks."""
    b, sq, h, dh = q.shape
    hkv = cache.k.shape[2]
    qg = _group(q, hkv)

    if chunk and sq > chunk and sq % chunk == 0:
        nc = sq // chunk

        def body(_, i):
            qc = jax.lax.dynamic_slice_in_dim(qg, i * chunk, chunk, axis=1)
            pc = jax.lax.dynamic_slice_in_dim(q_pos, i * chunk, chunk, axis=1)
            oc = attn_core(qc, cache.k, cache.v,
                           _cached_mask(cache.kpos, pc, window))
            return _, oc

        _, chunks = jax.lax.scan(body, None, jnp.arange(nc))
        out = jnp.moveaxis(chunks, 0, 1).reshape(b, sq, hkv, h // hkv, dh)
        return out.reshape(b, sq, h, dh)

    out = attn_core(qg, cache.k, cache.v,
                    _cached_mask(cache.kpos, q_pos, window))
    return out.reshape(b, sq, h, dh)


# ---------------------------------------------------------------------------
# Paged-pool KV cache (the measured fast path)
# ---------------------------------------------------------------------------


class PagedKVCache(NamedTuple):
    """Shared KV page pool for one layer: ``(num_pages + 1, page, Hkv,
    dh)``.  Physical page ids come from ``serving.kv_cache.PageAllocator``
    — page ``i`` of the pool IS allocator page ``i``, so the scheduling
    plane's accounting and the attention memory layout are one structure,
    and sequences acquiring a shared prefix block attend through the
    *same* physical pages with zero KV copies.

    The extra last page is a write sink: batch rows whose block-table
    entry is -1 (inactive slots, positions past the mapped tail) scatter
    there instead of corrupting page 0.  Reads clamp -1 to page 0 and
    mask by position, matching the Pallas kernel's contract."""

    k: jax.Array       # (num_pages + 1, page, Hkv, dh)
    v: jax.Array       # (num_pages + 1, page, Hkv, dh)


def init_paged_kv_cache(num_pages: int, page: int, hkv: int, dh: int,
                        dtype) -> PagedKVCache:
    return PagedKVCache(
        k=jnp.zeros((num_pages + 1, page, hkv, dh), dtype),
        v=jnp.zeros((num_pages + 1, page, hkv, dh), dtype),
    )


def _phys_slots(cache: PagedKVCache, tables: jax.Array,
                pos: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Map absolute token positions (B,S) through block tables (B,P) to
    (physical page, in-page slot); unmapped positions hit the sink."""
    page = cache.k.shape[1]
    p_max = tables.shape[1]
    sink = cache.k.shape[0] - 1
    logical = pos // page
    phys = jnp.take_along_axis(tables, jnp.clip(logical, 0, p_max - 1),
                               axis=1)
    bad = (phys < 0) | (logical < 0) | (logical >= p_max)
    return jnp.where(bad, sink, phys), pos % page


def paged_cache_write(cache: PagedKVCache, k_new: jax.Array,
                      v_new: jax.Array, start_pos: jax.Array,
                      tables: jax.Array) -> PagedKVCache:
    """Append S_new tokens at absolute positions start_pos..+S_new
    through per-sequence block tables (B, P) of physical page ids.
    Live rows own their mapped pages exclusively, so scatters never
    collide; -1 rows (inactive slots) land in the sink page."""
    b, s_new = k_new.shape[:2]
    pos = start_pos[:, None] + jnp.arange(s_new)[None, :]         # (B, S)
    return paged_cache_write_at(cache, k_new, v_new, pos, tables)


def paged_cache_write_at(cache: PagedKVCache, k_new: jax.Array,
                         v_new: jax.Array, pos: jax.Array,
                         tables: jax.Array) -> PagedKVCache:
    """Scatter tokens at explicit absolute positions (B, S); negative
    positions (unwritten ring slots during KV injection) hit the sink."""
    phys, slot = _phys_slots(cache, tables, pos)
    k = cache.k.at[phys, slot].set(k_new)
    v = cache.v.at[phys, slot].set(v_new)
    return PagedKVCache(k, v)


def paged_view(cache: PagedKVCache, tables: jax.Array) -> KVCache:
    """Gather a (B, P·page) contiguous view of each sequence's pages —
    the pure-jnp oracle for the Pallas paged kernel, and the prefill
    path (the kernel is decode-only).  Returned as a ring-layout
    ``KVCache`` so the masked-softmax core is shared: ``kpos`` is the
    absolute position (logical index) for mapped pages, -1 for the
    unmapped tail."""
    b, p_max = tables.shape
    page, hkv, dh = cache.k.shape[1:]
    phys = jnp.maximum(tables, 0)
    kg = cache.k[phys].reshape(b, p_max * page, hkv, dh)
    vg = cache.v[phys].reshape(b, p_max * page, hkv, dh)
    t = p_max * page
    kpos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    kpos = jnp.where(jnp.repeat(tables >= 0, page, axis=1), kpos, -1)
    return KVCache(kg, vg, kpos)


def self_attention_paged(params: dict, x: jax.Array, cache: PagedKVCache,
                         cfg: ModelConfig, spec: BlockSpec,
                         positions: jax.Array, tables: jax.Array,
                         ) -> tuple[jax.Array, PagedKVCache]:
    """Write-then-attend over the shared page pool.  Serves both decode
    (Sq = 1) and suffix prefill (Sq = uncached prompt tokens, attending
    back into prefix pages a sibling request already populated).

    Decode with ``cfg.use_pallas`` runs the Pallas paged kernel
    (``ops.paged_decode_attention``) straight over the pool + live block
    tables; everything else uses the jnp gather oracle, which is also
    the interpret-parity reference the tests pin the kernel against."""
    q, k, v = qkv_project(params, x, cfg, positions)
    pos1 = _pos1d(positions)
    # write through the explicit per-token positions (identical to the
    # consecutive-from-start form for ordinary prefill/decode, since
    # positions ARE consecutive there) so a padded mixed-step chunk can
    # mark its tail -1: those writes route to the sink page instead of
    # scribbling past the valid frontier of the sequence's pages
    cache = paged_cache_write_at(cache, k, v, pos1, tables)
    sq = q.shape[1]
    if cfg.use_pallas and sq == 1:
        from repro.kernels import ops
        # write-then-attend: the just-written token is position pos, so
        # ctx = pos + 1; rows with an unmapped head page are inactive
        # padding slots — zero context (the kernel emits zeros there)
        ctx = jnp.where(tables[:, 0] >= 0, pos1[:, 0] + 1, 0)
        out = ops.paged_decode_attention(q, cache.k, cache.v, tables, ctx,
                                         window=spec.window)
    else:
        view = paged_view(cache, tables)
        out = attention_cached(q, view, pos1, window=spec.window,
                               chunk=cfg.attn_chunk)
    return out_project(params, out), cache


# ---------------------------------------------------------------------------
# Full attention block entry points (proj + rope + core + out-proj)
# ---------------------------------------------------------------------------


def qkv_project(params: dict, x: jax.Array, cfg: ModelConfig,
                positions: jax.Array, rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", x, tp(params["wq"], None, "model", None))
    k = jnp.einsum("bsd,dhk->bshk", x, tp(params["wk"], None, "model", None))
    v = jnp.einsum("bsd,dhk->bshk", x, tp(params["wv"], None, "model", None))
    if cfg.qk_norm and "q_scale" in params:
        q = _qk_rms(q, params["q_scale"], cfg.norm_eps)
        k = _qk_rms(k, params["k_scale"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k, v


def out_project(params: dict, out: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", out, tp(params["wo"], "model", None, None))


def _pos1d(positions: jax.Array) -> jax.Array:
    """(B,S) from (B,S) or (B,S,3) (M-RoPE uses the temporal stream for
    cache bookkeeping)."""
    return positions[..., 0] if positions.ndim == 3 else positions


def self_attention(params: dict, x: jax.Array, cfg: ModelConfig,
                   spec: BlockSpec, positions: jax.Array,
                   causal: bool = True) -> jax.Array:
    """Whole-sequence self attention (train / one-shot prefill)."""
    q, k, v = qkv_project(params, x, cfg, positions)
    out = attention_full(q, k, v, window=spec.window, chunk=cfg.attn_chunk,
                         causal=causal)
    return out_project(params, out)


def self_attention_cached(params: dict, x: jax.Array, cache: KVCache,
                          cfg: ModelConfig, spec: BlockSpec,
                          positions: jax.Array) -> tuple[jax.Array, KVCache]:
    """Write this block of tokens into the ring cache, then attend.
    Valid for decode (Sq=1) and chunked-prefill steps (Sq <= cache slack)."""
    q, k, v = qkv_project(params, x, cfg, positions)
    pos1 = _pos1d(positions)
    cache = cache_write(cache, k, v, pos1[:, 0])
    out = attention_cached(q, cache, pos1, window=spec.window,
                           chunk=cfg.attn_chunk)
    return out_project(params, out), cache


def self_attention_prefill(params: dict, x: jax.Array, cache: KVCache,
                           cfg: ModelConfig, spec: BlockSpec,
                           positions: jax.Array) -> tuple[jax.Array, KVCache]:
    """One-shot prefill from position 0: windowed/chunked full attention
    over the prompt itself, then write the surviving tail into the ring."""
    q, k, v = qkv_project(params, x, cfg, positions)
    out = attention_full(q, k, v, window=spec.window, chunk=cfg.attn_chunk)
    cache = cache_write(cache, k, v, _pos1d(positions)[:, 0])
    return out_project(params, out), cache


def cross_attention(params: dict, x: jax.Array, memory: jax.Array,
                    cfg: ModelConfig) -> jax.Array:
    """Encoder-decoder cross attention (memory precomputed)."""
    q = jnp.einsum("bsd,dhk->bshk", x, tp(params["wq"], None, "model", None))
    k = jnp.einsum("btd,dhk->bthk", memory, tp(params["wk"], None, "model", None))
    v = jnp.einsum("btd,dhk->bthk", memory, tp(params["wv"], None, "model", None))
    out = attention_full(q, k, v, window=FULL_ATTENTION,
                         chunk=cfg.attn_chunk, causal=False)
    return out_project(params, out)


class CrossKV(NamedTuple):
    k: jax.Array   # (B, T_enc, Hkv, dh)
    v: jax.Array


def cross_kv_precompute(params: dict, memory: jax.Array) -> CrossKV:
    k = jnp.einsum("btd,dhk->bthk", memory, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", memory, params["wv"])
    return CrossKV(k, v)


def cross_attention_cached(params: dict, x: jax.Array,
                           ckv: CrossKV) -> jax.Array:
    b, sq = x.shape[:2]
    t = ckv.k.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    hkv = ckv.k.shape[2]
    mask = jnp.ones((1, 1, 1, sq, t), bool)
    out = attn_core(_group(q, hkv), ckv.k, ckv.v, mask)
    return out_project(params, out.reshape(b, sq, q.shape[2], q.shape[3]))
