"""Shared neural building blocks: norms, rotary embeddings (incl. M-RoPE),
dense MLPs, embeddings.  Pure functions over parameter pytrees."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.params import P, tp

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_defs(d: int) -> dict:
    return {"scale": P((d,), (None,), init="ones", dtype="float32")}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"]).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float) -> jax.Array:
    half = d_head // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mrope_sections: tuple[int, ...] = ()) -> jax.Array:
    """x: (B, S, H, dh).  positions: (B, S) int32, or (B, S, 3) for M-RoPE.

    M-RoPE (qwen2-vl): the dh/2 frequency channels are partitioned into
    ``mrope_sections`` groups, each driven by one of the (t, h, w) position
    streams.
    """
    b, s, h, dh = x.shape
    half = dh // 2
    freqs = rope_freqs(dh, theta)                     # (half,)
    if mrope_sections:
        assert sum(mrope_sections) == half, (mrope_sections, half)
        assert positions.ndim == 3 and positions.shape[-1] == len(mrope_sections)
        sec_ids = jnp.repeat(
            jnp.arange(len(mrope_sections)),
            jnp.array(mrope_sections),
            total_repeat_length=half)                  # (half,)
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32),             # (B, S, 3)
            jnp.broadcast_to(sec_ids[None, None, :], (b, s, half)),
            axis=-1)                                   # (B, S, half)
    else:
        if positions.ndim == 3:
            positions = positions[..., 0]
        pos = positions.astype(jnp.float32)[..., None]  # (B, S, 1)
    angles = pos * freqs                               # (B, S, half)
    sin = jnp.sin(angles)[:, :, None, :]
    cos = jnp.cos(angles)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense MLP (SwiGLU)
# ---------------------------------------------------------------------------


def mlp_defs(d: int, ff: int) -> dict:
    return {
        "w_in": P((d, ff), ("embed", "ff")),
        "w_gate": P((d, ff), ("embed", "ff")),
        "w_out": P((ff, d), ("ff", "embed")),
    }


def mlp(params: dict, x: jax.Array) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, tp(params["w_in"], None, "model"))
    g = jnp.einsum("...d,df->...f", x, tp(params["w_gate"], None, "model"))
    h = h * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
    return jnp.einsum("...f,fd->...d", h, tp(params["w_out"], "model", None))


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_defs(vocab: int, d: int) -> dict:
    return {"table": P((vocab, d), ("vocab", "embed"), scale=1.0)}


def embed(params: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0)


def unembed_defs(d: int, vocab: int) -> dict:
    return {"w": P((d, vocab), ("embed", "vocab"))}


def unembed(params: dict, x: jax.Array, softcap: float = 0.0) -> jax.Array:
    logits = jnp.einsum("...d,dv->...v", x, tp(params["w"], None, "model"))
    if softcap > 0.0:
        logits = (jnp.tanh(logits.astype(jnp.float32) / softcap)
                  * softcap).astype(logits.dtype)
    return logits


def unembed_tied(embed_params: dict, x: jax.Array,
                 softcap: float = 0.0) -> jax.Array:
    logits = jnp.einsum("...d,vd->...v", x, embed_params["table"])
    if softcap > 0.0:
        logits = (jnp.tanh(logits.astype(jnp.float32) / softcap)
                  * softcap).astype(logits.dtype)
    return logits


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token cross entropy.  logits (..., V) f32-upcast."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def chunked_unembed_xent(logits_fn, x: jax.Array, labels: jax.Array,
                         mask: jax.Array, chunk: int) -> jax.Array:
    """Cross entropy without materializing the full (B, S, V) logits.

    Scans the sequence in ``chunk``-token slices; each slice computes its
    own logits (``logits_fn`` = unembed closure) and reduces to scalars
    (sum-nll, sum-mask) immediately.  For a 128k-vocab 4k-seq train step
    this is the difference between ~TB and ~GB of live activations — the
    standard big-vocab loss treatment.  Exact, not an approximation.
    """
    b, s = labels.shape
    if chunk <= 0 or s <= chunk:
        return softmax_xent(logits_fn(x), labels, mask)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    n = x.shape[1] // chunk
    xs = (x.reshape(b, n, chunk, -1).swapaxes(0, 1),
          labels.reshape(b, n, chunk).swapaxes(0, 1),
          mask.reshape(b, n, chunk).swapaxes(0, 1))

    def body(acc, slc):
        xc, lc, mc = slc
        logits = logits_fn(xc).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll_sum, m_sum = acc
        return (nll_sum + jnp.sum((logz - gold) * mc),
                m_sum + jnp.sum(mc)), None

    (nll_sum, m_sum), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), xs)
    return nll_sum / jnp.maximum(m_sum, 1.0)
