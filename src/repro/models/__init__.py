"""Model zoo public API.

>>> from repro import models
>>> cfg = get_smoke("llama3-405b")
>>> params = models.init(cfg, jax.random.key(0))
>>> logits, aux = models.forward(params, cfg, tokens)
"""
from repro.models.transformer import (decode_step, default_positions, encode,
                                      forward, init, init_cache, loss_fn,
                                      model_defs, paged_extract, paged_insert,
                                      param_count, prefill, prefill_paged,
                                      prefill_paged_padded)

__all__ = [
    "decode_step", "default_positions", "encode", "forward", "init",
    "init_cache", "loss_fn", "model_defs", "paged_extract", "paged_insert",
    "param_count", "prefill", "prefill_paged", "prefill_paged_padded",
]
