"""Mixture-of-Experts FFN with sort-based (dropping) dispatch.

Dispatch is gather/scatter-based rather than one-hot-einsum-based, so the
compiled FLOPs stay proportional to *active* parameters (top_k / E of the
dense-equivalent) — this keeps the roofline's MODEL_FLOPS / HLO_FLOPs
ratio honest and is the layout the ``grouped_matmul`` Pallas kernel
consumes on TPU (experts × capacity × d tiles).

Expert weights carry the ``experts`` logical axis → sharded over the
``model`` mesh axis (expert parallelism); the (E, C, d) dispatch buffer is
sharded the same way, so GSPMD materializes the dispatch/return as
all-to-alls over ``model``.

Supports: top-k routing with capacity dropping, shared experts (kimi),
dense residual branch (arctic), and a load-balancing auxiliary loss.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import BlockSpec, ModelConfig
from repro.models.layers import mlp, mlp_defs
from repro.models.params import P, tp


def moe_defs(cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    defs = {
        "router": P((d, e), ("embed", None), dtype="float32"),
        "w_in": P((e, d, f), ("experts", "embed", "ff")),
        "w_gate": P((e, d, f), ("experts", "embed", "ff")),
        "w_out": P((e, f, d), ("experts", "ff", "embed")),
    }
    if cfg.n_shared_experts > 0:
        defs["shared"] = mlp_defs(d, f * cfg.n_shared_experts)
    return defs


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = -(-n_tokens * cfg.top_k // cfg.n_experts)        # ceil
    c = int(c * cfg.capacity_factor) + 1
    return -(-c // 8) * 8                                # round up to 8


class MoEStats(NamedTuple):
    aux_loss: jax.Array        # load-balance loss (Switch-style)
    dropped_frac: jax.Array    # fraction of (token, expert) slots dropped


def _moe_tokens(params: dict, xt: jax.Array,
                cfg: ModelConfig) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Token-level MoE core: xt (T, d) -> (y (T, d), aux, dropped)."""
    t, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k

    # --- routing (f32) -----------------------------------------------------
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)             # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # --- load-balance aux loss ---------------------------------------------
    density = jnp.mean(jax.nn.one_hot(expert_ids, e, dtype=jnp.float32),
                       axis=(0, 1))                             # (E,)
    prop = jnp.mean(probs, axis=0)                              # (E,)
    aux = jnp.sum(density * prop) * e

    # --- sort-based dispatch ------------------------------------------------
    c = capacity(t, cfg)
    flat_expert = expert_ids.reshape(-1)                        # (T*k,)
    order = jnp.argsort(flat_expert, stable=True)               # group by expert
    sorted_expert = flat_expert[order]
    first = jnp.searchsorted(sorted_expert, sorted_expert, side="left")
    pos_in_e = jnp.arange(t * k) - first                        # rank in group
    keep = pos_in_e < c
    dest = jnp.where(keep, sorted_expert * c + pos_in_e, e * c) # OOB -> drop

    token_of = order // k                                       # source token
    x_sorted = xt[token_of]                                     # (T*k, d)
    buf = jnp.zeros((e * c, d), xt.dtype).at[dest].set(
        x_sorted, mode="drop")
    # EP: the capacity buffer shards over the expert dim like the expert
    # weights (under vmap the group dim stays data-sharded → the expert
    # matmuls are 2-D sharded data×model); this reshard IS the all-to-all
    buf = tp(buf.reshape(e, c, d), "model", None, None)

    # --- expert computation (grouped matmul layout) -------------------------
    h = jnp.einsum("ecd,edf->ecf", buf, tp(params["w_in"], "model", None, None))
    g = jnp.einsum("ecd,edf->ecf", buf, tp(params["w_gate"], "model", None, None))
    h = h * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
    out_buf = jnp.einsum("ecf,efd->ecd", h, tp(params["w_out"], "model", None, None))    # (E, C, d)

    # --- return + combine ----------------------------------------------------
    safe_dest = jnp.where(keep, dest, 0)
    y_sorted = out_buf.reshape(e * c, d)[safe_dest]
    y_sorted = jnp.where(keep[:, None], y_sorted, 0)
    y_flat = jnp.zeros((t * k, d), xt.dtype).at[order].set(y_sorted)
    gates = gate_vals.reshape(t * k).astype(jnp.float32)
    y = (y_flat.reshape(t, k, d).astype(jnp.float32)
         * gates.reshape(t, k, 1)).sum(axis=1)
    return y.astype(xt.dtype), aux, 1.0 - jnp.mean(keep.astype(jnp.float32))


GROUPWISE_MIN_TOKENS = 256


def moe_ffn(params: dict, x: jax.Array, cfg: ModelConfig,
            spec: BlockSpec) -> tuple[jax.Array, MoEStats]:
    """x: (B, S, d) -> (B, S, d).

    Long sequences dispatch **group-wise** (GShard-style, one group per
    batch row, vmapped): a single global argsort over all B·S tokens is
    unshardable, so GSPMD all-gathers the token set over the ``data``
    axis and every device routes the whole batch — measured 16×
    per-device FLOP inflation on kimi-k2 (see EXPERIMENTS.md §Perf).
    Per-row dispatch keeps the batch dim sharded; capacity is per group.
    Short inputs (decode steps) keep the global path — per-group padding
    would dominate there.
    """
    b, s, d = x.shape
    if s >= GROUPWISE_MIN_TOKENS and b > 1:
        # spmd_axis_name pins the vmapped group dim to the data axis —
        # without it GSPMD folds the groups into the expert matmul's
        # capacity dim *replicated* (measured: full-batch expert compute
        # on every device)
        kw = {}
        try:
            mesh = jax.sharding.get_abstract_mesh()
            if mesh is not None and not mesh.empty \
                    and "data" in mesh.axis_names and b % mesh.shape["data"] == 0:
                kw["spmd_axis_name"] = "data"
        except Exception:
            pass
        y, aux, dropped = jax.vmap(
            lambda xg: _moe_tokens(params, xg, cfg), **kw)(x)
        y = y.reshape(b, s, d)
        aux, dropped = jnp.mean(aux), jnp.mean(dropped)
    else:
        yt, aux, dropped = _moe_tokens(params, x.reshape(b * s, d), cfg)
        y = yt.reshape(b, s, d)

    # --- shared experts (always-on) ------------------------------------------
    if "shared" in params:
        y = y + mlp(params["shared"], x.reshape(b, s, d))

    stats = MoEStats(aux_loss=aux, dropped_frac=dropped)
    return y, stats
