import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture × input-shape)
cell on the production meshes, and dump the roofline inputs.

The two lines above MUST stay the first statements in this module: jax
locks the device count on first initialization, and the dry-run needs
512 placeholder CPU devices to build the 16×16 and 2×16×16 meshes.
(Only the dry-run: smoke tests and benches see the 1 real device.)

Per cell this produces artifacts/dryrun/<arch>.<shape>.<mesh>.json with:
  * compiled.cost_analysis() FLOPs / bytes accessed,
  * compiled.memory_analysis() per-device byte breakdown,
  * collective bytes by op kind, parsed from the optimized HLO,
  * MODEL_FLOPS (6·N·D train / 2·N·D forward, N_active for MoE),
and EXPERIMENTS.md §Dry-run / §Roofline are rendered from these files by
benchmarks/roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                    # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b \
      --shape train_4k --mesh single
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.launch import specs as specs_mod
from repro.launch.mesh import make_production_mesh
from repro.launch.serve import make_prefill_step, make_serve_step
from repro.launch.train import (AdamWConfig, TrainPlan, abstract_state,
                                default_plan, make_train_step)

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_BUF_RE = re.compile(r"= (f32|bf16)\[([\d,]+)\]")


def cpu_bf16_inflation(hlo_text: str) -> int:
    """Estimate bytes of f32 buffers that exist only because the CPU
    backend legalizes bf16 by converting to f32 (convert fusions create
    an f32 twin of each large bf16 tensor).  On a real TPU these twins
    don't exist; the dry-run subtracts them to report a TPU-adjusted
    temp figure.  Heuristic: an f32 buffer whose dims exactly match a
    bf16 buffer in the same module is counted as legalization."""
    bf16_shapes: set[str] = set()
    f32: dict[str, int] = {}
    for m in _BUF_RE.finditer(hlo_text):
        dt, dims = m.group(1), m.group(2)
        if dt == "bf16":
            bf16_shapes.add(dims)
        else:
            n = 1
            for d in dims.split(","):
                n *= int(d)
            f32[dims] = n * 4
    return sum(v for dims, v in f32.items()
               if dims in bf16_shapes and v > 1 << 26)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes of every collective op in optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        # '%name = TYPE op-name(' — find which collective, if any
        for kind in _COLLECTIVES:
            token = f" {kind}("
            alt = f" {kind}-start("
            if token in s or alt in s:
                eq = s.find("= ")
                if eq < 0:
                    continue
                paren = s.find(token if token in s else alt)
                type_str = s[eq + 2:paren]
                out[kind] += _shape_bytes(type_str)
                out["count"] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


# ---------------------------------------------------------------------------
# Model-FLOPs reference
# ---------------------------------------------------------------------------


def recurrent_correction(cfg, shape, data_shards: int = 16) -> float:
    """Analytic per-device FLOPs of the recurrent chunk scans that XLA's
    cost analysis counts only once (the chunk loop stays rolled even in
    the cost pass — flattening it is compile-prohibitive; measured).

    Covers the mamba branch (hybrid) and mLSTM/sLSTM blocks (ssm).
    Forward-only analytic count × 4 for training (bwd 2×, remat re-fwd
    1×) — the same overhead the measured cells show.  Exact chunk math
    mirrors chunked_linear_attention's einsums.
    """
    if cfg.family not in ("hybrid", "ssm") or shape.kind == "decode":
        return 0.0
    from repro.models.ssm import SSM_HEAD_DIM, mamba_dims, mlstm_dims
    b, t = shape.global_batch, shape.seq_len
    c = 128
    nc = max(t // c, 1)

    def chunk_flops(h, dk, dv):
        per_chunk = b * h * (2 * c * c * (dk + dv) + 4 * c * dk * dv
                             + 2 * c * c)
        return (nc - 1) * per_chunk          # one chunk already counted

    total = 0.0
    if cfg.family == "hybrid":
        _, nh, ds = mamba_dims(cfg)
        total += cfg.n_layers * chunk_flops(nh, ds, SSM_HEAD_DIM)
    else:                                    # xlstm
        _, nh, dh = mlstm_dims(cfg)
        n_mlstm = sum(n * seg.repeat for seg in cfg.plan()
                      for sp, n in seg.pattern if sp.kind == "mlstm")
        n_slstm = sum(n * seg.repeat for seg in cfg.plan()
                      for sp, n in seg.pattern if sp.kind == "slstm")
        total += n_mlstm * chunk_flops(nh, dh, dh + 1)
        d_inner = cfg.ssm_expand * cfg.d_model
        total += n_slstm * (t - 1) * 20 * b * d_inner   # elementwise scan
    if shape.kind == "train":
        total *= 4.0
    shards = data_shards if b % data_shards == 0 else 1
    return total / shards


def model_flops(cfg, shape) -> float:
    """6·N·D (train) / 2·N·D (forward) with N_active for MoE."""
    from repro.sim.costmodel import CostModel
    n = CostModel(cfg).n_active_params()
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n * toks
    return 2.0 * n * shape.global_batch          # one decode token / seq


# ---------------------------------------------------------------------------
# One cell
# ---------------------------------------------------------------------------


# ---------------------------------------------------------------------------
# Layer-scaled cost extrapolation
# ---------------------------------------------------------------------------
#
# The unrolled cost pass is exact but unrolling 126 layers at a 256-way
# mesh does not compile in reasonable time on this 1-core container.
# Per-layer cost is structurally linear in the number of repeating units
# (identical blocks, identical sharding), so for deep/wide archs we
# compile the unrolled module at TWO reduced depths and extrapolate:
#
#     F(u) = outer + u * per_unit      (u = number of repeating units)
#
# outer (embed/unembed/loss/optimizer/batch reshards) and per_unit
# (block compute + its FSDP gathers / TP reduces) both live at the full
# production mesh, so sharding effects are captured exactly.  Exact for
# homogeneous stacks; gemma3's trailing partial period (2 local layers
# of a 6-layer pattern) is approximated by a fractional unit (<2% of
# depth).  Records carry "cost_mode": "direct" | "extrapolated".

_DIRECT_MAX_LAYERS = 48          # unroll directly when depth*width is small
_DIRECT_MAX_DMODEL = 4096


def _period(cfg) -> int:
    if cfg.local_global_ratio > 0:
        return cfg.local_global_ratio + 1
    if cfg.mlstm_ratio > 0:
        return cfg.mlstm_ratio + 1
    return 1


def _scaled_cfg(cfg, units: int):
    period = _period(cfg)
    n = cfg.first_k_dense + units * period
    kw = {"n_layers": n, "scan_layers": False, "loss_chunk": 0}
    if cfg.global_layers:
        density = len(cfg.global_layers) / cfg.n_layers
        k = max(1, round(density * n))
        kw["global_layers"] = tuple(min(n - 1, int(i * n / k) + 1)
                                    for i in range(k))
    if cfg.enc_layers:
        kw["enc_layers"] = n
    return cfg.replace(**kw)


def _units_full(cfg) -> float:
    return (cfg.n_layers - cfg.first_k_dense) / _period(cfg)


def _direct_ok(cfg) -> bool:
    if cfg.family in ("hybrid", "ssm"):
        # recurrent branches unroll their chunk scans in cost mode —
        # direct full-depth unrolls are compile-prohibitive; extrapolate
        return False
    return (cfg.n_layers <= _DIRECT_MAX_LAYERS
            and cfg.d_model <= _DIRECT_MAX_DMODEL)


def _costs_of(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "collectives": coll}


def _lin(fa: dict, fb: dict, ua: float, ub: float, u: float) -> dict:
    def go(a, b):
        if isinstance(a, dict):
            return {k: go(a[k], b[k]) for k in a}
        slope = (b - a) / (ub - ua)
        return max(0.0, a + slope * (u - ua))
    out = go(fa, fb)
    out["collectives"] = {k: (int(v) if k == "count" else v)
                          for k, v in out["collectives"].items()}
    return out


def _compile_cell(cfg, shape, mesh, plan: TrainPlan | None):
    """Lower + compile one step for this cell; returns (compiled, plan)."""
    chips = mesh.devices.size
    ins = specs_mod.input_specs(cfg, shape)
    with jax.set_mesh(mesh):
        if shape.kind == "train":
            plan = plan or default_plan(cfg, shape, chips)
            acfg = AdamWConfig(int8_moments=plan.int8_moments)
            step, _ = make_train_step(cfg, mesh, plan, acfg, shape=shape)
            p, o = abstract_state(cfg, acfg)
            lowered = step.lower(p, o, ins["batch"])
        elif shape.kind == "prefill":
            step, _ = make_prefill_step(cfg, mesh, shape)
            p, _ = abstract_state(cfg, AdamWConfig())
            args = [p, ins["tokens"], ins["cache"]]
            if cfg.frontend == "patch":
                args.append(ins["vision_embeds"])
            elif cfg.is_encdec:
                args.append(ins["frames"])
            lowered = step.lower(*args)
        else:
            step, _ = make_serve_step(cfg, mesh, shape)
            p, _ = abstract_state(cfg, AdamWConfig())
            lowered = step.lower(p, ins["tokens"], ins["cache"])
        return lowered.compile(), plan


def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str,
               plan: TrainPlan | None = None,
               cost_pass: bool = True) -> dict:
    """Two lowerings per cell:

    * **memory pass** — production form (lax.scan over layers + remat +
      the real microbatch plan): proves the sharding compiles and gives
      the deployable per-device memory picture.
    * **cost pass** (single-pod roofline cells only) — unrolled layers,
      microbatch=1: XLA's cost_analysis counts while-loop bodies once,
      so only the unrolled module yields honest FLOP/byte/collective
      totals.  Numerically identical modulo bf16 reassociation (tested).
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "skipped": why}

    t0 = time.time()
    compiled, plan = _compile_cell(cfg, shape, mesh, plan)
    t_mem = time.time() - t0
    mem = compiled.memory_analysis()
    inflation = cpu_bf16_inflation(compiled.as_text())
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "chips": mesh.devices.size,
        "model_flops": model_flops(cfg, shape),
        "compile_s": round(t_mem, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
            # conservative resident bound: inputs (donated outputs alias
            # them) + live temporaries
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0)
                           + getattr(mem, "temp_size_in_bytes", 0)),
            "xla_peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
            # CPU-backend bf16 legalization creates f32 twins of large
            # bf16 buffers; a TPU build doesn't have them
            "cpu_bf16_inflation_bytes": inflation,
            "tpu_adjusted_peak_bytes": max(
                0, getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0) - inflation),
        },
    }
    if plan is not None and shape.kind == "train":
        rec["plan"] = {"microbatch": plan.microbatch,
                       "accum_dtype": plan.accum_dtype,
                       "int8_moments": plan.int8_moments}

    if cost_pass:
        t1 = time.time()
        plan_u = TrainPlan(microbatch=1,
                           int8_moments=(plan.int8_moments
                                         if plan else False)) \
            if shape.kind == "train" else None
        # cost configs: unrolled layers AND unchunked loss — every scan
        # body must be gone or its flops are undercounted
        if _direct_ok(cfg):
            cfg_u = cfg.replace(scan_layers=False, loss_chunk=0)
            compiled_u, _ = _compile_cell(cfg_u, shape, mesh, plan_u)
            rec.update(_costs_of(compiled_u))
            rec["cost_mode"] = "direct"
        else:
            per = _period(cfg)
            if per >= 6:
                ua, ub = 1, 2              # one/two full patterns
            elif cfg.global_layers:
                ua, ub = 8, 16             # keep the global-layer density
            else:
                ua, ub = 2, 4
            ca, _ = _compile_cell(_scaled_cfg(cfg, ua), shape, mesh, plan_u)
            fa = _costs_of(ca)
            del ca
            cb, _ = _compile_cell(_scaled_cfg(cfg, ub), shape, mesh, plan_u)
            fb = _costs_of(cb)
            del cb
            rec.update(_lin(fa, fb, ua, ub, _units_full(cfg)))
            rec["cost_mode"] = f"extrapolated(u={ua},{ub})"
        corr = recurrent_correction(cfg, shape)
        if corr > 0:
            rec["recurrent_correction_flops"] = corr
            rec["flops"] = rec.get("flops", 0.0) + corr
        rec["cost_compile_s"] = round(time.time() - t1, 1)
    return rec


def run(archs, shapes, meshes, out_dir: Path,
        stop_on_error: bool = False) -> list[dict]:
    out_dir.mkdir(parents=True, exist_ok=True)
    results = []
    mesh_objs = {}
    if "single" in meshes:
        mesh_objs["single"] = make_production_mesh(multi_pod=False)
    if "multi" in meshes:
        mesh_objs["multi"] = make_production_mesh(multi_pod=True)

    for arch in archs:
        for shape_name in shapes:
            for mesh_name, mesh in mesh_objs.items():
                tag = f"{arch}.{shape_name}.{mesh_name}"
                try:
                    # cost pass (unrolled) only for the single-pod
                    # roofline cells; multi-pod is the sharding proof
                    rec = lower_cell(arch, shape_name, mesh, mesh_name,
                                     cost_pass=(mesh_name == "single"))
                except Exception as e:
                    if stop_on_error:
                        raise
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                results.append(rec)
                (out_dir / f"{tag}.json").write_text(
                    json.dumps(rec, indent=1))
                if "skipped" in rec:
                    print(f"SKIP {tag}: {rec['skipped']}", flush=True)
                elif "error" in rec:
                    print(f"FAIL {tag}: {rec['error']}", flush=True)
                else:
                    peak = rec["memory"]["peak_bytes"] / 1e9
                    extra = ""
                    if "flops" in rec:
                        extra = (f"{rec['flops']:.3e} FLOPs "
                                 f"{rec['bytes_accessed']:.3e} B "
                                 f"coll={rec['collectives']['total']:.3e} B ")
                    print(f"OK   {tag}: {extra}peak={peak:.2f} GB/dev "
                          f"compile={rec['compile_s']}"
                          f"+{rec.get('cost_compile_s', 0)}s", flush=True)
    return results


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--stop-on-error", action="store_true")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    results = run(archs, shapes, meshes, Path(args.out),
                  stop_on_error=args.stop_on_error)
    failed = [r for r in results if "error" in r]
    print(f"\n{len(results)} cells: {len(failed)} failed, "
          f"{sum(1 for r in results if 'skipped' in r)} skipped")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
