"""Production meshes.

Functions, not module-level constants, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS *before* any
jax initialization)."""
from __future__ import annotations

import jax


def _auto(n: int):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; ×2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh for tests / small-scale runs (e.g. (1,1))."""
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def mesh_chips(mesh) -> int:
    return mesh.devices.size
