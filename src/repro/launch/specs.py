"""Abstract input specs for AOT lowering (the dry-run's currency).

``input_specs(cfg, shape)`` returns ``ShapeDtypeStruct`` stand-ins for
every model input of that (architecture × input-shape) cell — weak-type
correct, shardable, zero allocation.  ``input_pspecs`` returns the
matching PartitionSpec tree for a mesh.

Modality frontends are stubs per the assignment: the VLM cell receives
precomputed patch embeddings (``vision_embeds``), the audio cell
precomputed frame embeddings (``frames``).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.models import transformer

VLM_PATCHES = 256          # stub patch-embedding count (qwen2-vl)
AUDIO_FRAMES = 1024        # stub speech-frame count (seamless)
DECODE_CACHE_PAD = 128     # ring slack so one decode step never wraps


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _n_patches(seq_len: int) -> int:
    return min(VLM_PATCHES, seq_len // 2)


def _n_frames(seq_len: int) -> int:
    return min(AUDIO_FRAMES, max(seq_len // 4, 8))


def decode_context(shape: ShapeConfig) -> int:
    return shape.seq_len + DECODE_CACHE_PAD


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """Abstract inputs for the step this cell lowers."""
    b, s = shape.global_batch, shape.seq_len
    dt = cfg.dtype
    enc = _n_frames(s) if cfg.is_encdec else 0

    if shape.kind == "train":
        batch: dict[str, Any] = {"tokens": _sds((b, s), jnp.int32),
                                 "labels": _sds((b, s), jnp.int32)}
        if cfg.frontend == "patch":
            batch["vision_embeds"] = _sds((b, _n_patches(s), cfg.d_model), dt)
        if cfg.is_encdec:
            batch["frames"] = _sds((b, enc, cfg.d_model), dt)
        return {"batch": batch}

    if shape.kind == "prefill":
        cache = jax.eval_shape(
            lambda: transformer.init_cache(cfg, b, s, enc))
        out = {"tokens": _sds((b, s), jnp.int32), "cache": cache}
        if cfg.frontend == "patch":
            out["vision_embeds"] = _sds((b, _n_patches(s), cfg.d_model), dt)
        if cfg.is_encdec:
            out["frames"] = _sds((b, enc, cfg.d_model), dt)
        return out

    assert shape.kind == "decode"
    ctx = decode_context(shape)
    cache = jax.eval_shape(
        lambda: transformer.init_cache(cfg, b, ctx, enc))
    return {"tokens": _sds((b, 1), jnp.int32), "cache": cache}


def input_pspecs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                 rules: shd.ShardingRules = shd.DEFAULT_RULES):
    """PartitionSpec tree matching ``input_specs``."""
    b = shape.global_batch
    bspec = shd.batch_pspec(mesh, rules, batch_size=b)
    b_entry = bspec[0] if len(bspec) else None
    enc = _n_frames(shape.seq_len) if cfg.is_encdec else 0

    def bleading(ndim):
        return PartitionSpec(b_entry, *([None] * (ndim - 1)))

    if shape.kind == "train":
        batch = {"tokens": bleading(2), "labels": bleading(2)}
        if cfg.frontend == "patch":
            batch["vision_embeds"] = bleading(3)
        if cfg.is_encdec:
            batch["frames"] = bleading(3)
        return {"batch": batch}

    if shape.kind == "prefill":
        cache = shd.cache_pspecs(cfg, b, shape.seq_len, mesh, enc_len=enc,
                                 rules=rules)
        out = {"tokens": bleading(2), "cache": cache}
        if cfg.frontend == "patch":
            out["vision_embeds"] = bleading(3)
        if cfg.is_encdec:
            out["frames"] = bleading(3)
        return out

    ctx = decode_context(shape)
    # long-context decode (batch too small to shard): sequence-shard the
    # KV cache instead — mesh-level flash decoding
    shard_seq = shape.name.startswith("long")
    cache = shd.cache_pspecs(cfg, b, ctx, mesh, enc_len=enc, rules=rules,
                             shard_seq=shard_seq)
    return {"tokens": bleading(2), "cache": cache}
