"""Training-step factory + CLI trainer.

``make_train_step`` builds the jitted (params, opt_state, batch) →
(params, opt_state, metrics) update for a (config, mesh):

* grads via ``jax.value_and_grad`` over the model's loss;
* optional **microbatch gradient accumulation** (``lax.scan`` over
  global-batch slices — the activation-memory knob for the 100B+ cells);
* optional **int8 cross-pod gradient compression with error feedback**
  (shard_map over the ``pod`` axis; intra-pod reduction stays bf16);
* sharded AdamW (optionally int8 moments) from repro.optim;
* in/out shardings from the logical-axis rules, donated buffers.

The same factory serves the real CPU training example (1-device mesh)
and the 256/512-chip dry-run (abstract lowering only).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.distributed.collectives import compressed_psum
from repro.models import transformer
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


@dataclass(frozen=True)
class TrainPlan:
    microbatch: int = 1              # gradient-accumulation steps
    accum_dtype: str = "float32"     # grad accumulator dtype
    compress_grads: bool = False     # int8+EF psum over 'pod'
    int8_moments: bool = False
    donate: bool = True


def default_plan(cfg: ModelConfig, shape: ShapeConfig,
                 n_chips: int = 256) -> TrainPlan:
    """Memory-fitting heuristics (validated by compiled memory_analysis;
    overridden per-cell during hillclimbing)."""
    n = transformer.param_count(cfg)
    if n > 100e9:
        return TrainPlan(microbatch=16, accum_dtype="bfloat16",
                         int8_moments=True)
    if n > 20e9:
        return TrainPlan(microbatch=4, int8_moments=True)
    return TrainPlan(microbatch=1)


# ---------------------------------------------------------------------------
# Sharding trees
# ---------------------------------------------------------------------------


def opt_pspecs(cfg: ModelConfig, mesh, acfg: AdamWConfig,
               rules: shd.ShardingRules = shd.DEFAULT_RULES):
    """Moments shard exactly like their parameters; int8 block scales
    inherit the param spec minus the (re-blocked) last dim."""
    p_specs = shd.param_pspecs(cfg, mesh, rules)
    flat_p, p_treedef = jax.tree.flatten(p_specs)
    sizes = dict(zip(mesh.axis_names, np.shape(mesh.devices)))

    pshapes = transformer.model_defs(cfg)
    from repro.models import params as prm
    pshape_tree = prm.param_shapes(pshapes, jnp.dtype(cfg.dtype))
    opt_shapes = jax.eval_shape(lambda p: adamw_init(p, acfg), pshape_tree)

    def _axes_size(entry) -> int:
        group = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for ax in group:
            total *= sizes.get(ax, 1)
        return total

    if acfg.int8_moments:
        # flatten order per param leaf: q then scale — handle pairwise
        def map_q8(tree):
            leaves, treedef = jax.tree.flatten(tree)
            out = []
            pi = 0
            k = 0
            while k < len(leaves):
                q, scale = leaves[k], leaves[k + 1]
                pspec = flat_p[pi]
                out.append(pspec)
                entries = list(pspec)[:scale.ndim]
                entries += [None] * (scale.ndim - len(entries))
                if entries and entries[-1] is not None \
                        and scale.shape[-1] % _axes_size(entries[-1]):
                    entries[-1] = None
                out.append(PartitionSpec(*entries))
                pi += 1
                k += 2
            return jax.tree.unflatten(treedef, out)
        m_spec = map_q8(opt_shapes.m)
        v_spec = map_q8(opt_shapes.v)
    else:
        m_spec = jax.tree.unflatten(p_treedef, flat_p)
        v_spec = jax.tree.unflatten(p_treedef, flat_p)

    return type(opt_shapes)(step=PartitionSpec(), m=m_spec, v=v_spec)


# ---------------------------------------------------------------------------
# The step
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, mesh, plan: Optional[TrainPlan] = None,
                    acfg: Optional[AdamWConfig] = None,
                    rules: shd.ShardingRules = shd.DEFAULT_RULES,
                    shape: Optional[ShapeConfig] = None):
    """Returns (jitted_step, shardings dict)."""
    plan = plan or TrainPlan()
    acfg = acfg or AdamWConfig(int8_moments=plan.int8_moments)
    accum_dt = jnp.dtype(plan.accum_dtype)

    p_spec = shd.param_pspecs(cfg, mesh, rules)
    o_spec = opt_pspecs(cfg, mesh, acfg, rules)
    bsz = shape.global_batch if shape is not None else None
    b_spec = shd.batch_pspec(mesh, rules, batch_size=bsz)

    def grads_of(params, mb):
        (loss, metrics), grads = jax.value_and_grad(
            transformer.loss_fn, has_aux=True)(params, cfg, mb)
        return loss, metrics, grads

    def step(params, opt_state, batch):
        k = plan.microbatch
        if k <= 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def slice_mb(x, i):
                b = x.shape[0] // k
                return jax.lax.dynamic_slice_in_dim(x, i * b, b, axis=0)

            def body(acc, i):
                mb = jax.tree.map(lambda x: slice_mb(x, i), batch)
                loss, metrics, grads = grads_of(params, mb)
                acc_g, acc_l = acc
                acc_g = jax.tree.map(
                    lambda a, g: a + g.astype(accum_dt) / k, acc_g, grads)
                return (acc_g, acc_l + loss / k), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dt), params)
            (grads, loss), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)),
                jnp.arange(k))
            metrics = {"loss": loss}

        new_params, new_opt, opt_metrics = adamw_update(
            grads, opt_state, params, acfg)
        metrics = {**metrics, **opt_metrics}
        return new_params, new_opt, metrics

    dev_kw = {}
    if plan.donate:
        dev_kw["donate_argnums"] = (0, 1)
    named = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree)
    jitted = jax.jit(
        step,
        in_shardings=(named(p_spec), named(o_spec),
                      # prefix-broadcast over the batch dict: every input
                      # shards its leading (batch) dim only
                      NamedSharding(mesh, b_spec)),
        out_shardings=(named(p_spec), named(o_spec), None),
        **dev_kw)
    shardings = {"params": p_spec, "opt": o_spec, "batch": b_spec}
    return jitted, shardings


def abstract_state(cfg: ModelConfig, acfg: AdamWConfig):
    """(params, opt_state) as ShapeDtypeStructs — dry-run currency."""
    from repro.models import params as prm
    p = prm.param_shapes(transformer.model_defs(cfg), jnp.dtype(cfg.dtype))
    o = jax.eval_shape(lambda pp: adamw_init(pp, acfg), p)
    return p, o


# ---------------------------------------------------------------------------
# Compressed-gradient variant (pod-axis int8 + error feedback)
# ---------------------------------------------------------------------------


def make_compressed_grad_fn(cfg: ModelConfig, mesh):
    """Grad all-reduce across pods in int8 with error feedback, inside
    shard_map; everything else stays GSPMD.  Only built when the mesh has
    a 'pod' axis."""
    assert "pod" in mesh.axis_names
    from jax.experimental.shard_map import shard_map

    def xpod_mean(grads, ef):
        fn = lambda g, e: compressed_psum(g, e, "pod")
        spec = PartitionSpec()        # per-pod replicated view of grads

        def inner(g, e):
            return compressed_psum(g, e, "pod")

        return shard_map(
            inner, mesh=mesh,
            in_specs=(PartitionSpec("pod"), PartitionSpec("pod")),
            out_specs=(PartitionSpec("pod"), PartitionSpec("pod")),
            check_rep=False)(grads, ef)

    return xpod_mean
