"""Serving-step factories: one decode step / one prefill over a sharded
KV cache.  Lowered by the dry-run for the ``prefill_*`` / ``decode_*`` /
``long_*`` cells and used live by the real-engine serving example (on a
1-device mesh)."""
from __future__ import annotations


import jax
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.launch import specs as specs_mod
from repro.models import transformer


def make_serve_step(cfg: ModelConfig, mesh, shape: ShapeConfig,
                    rules: shd.ShardingRules = shd.DEFAULT_RULES):
    """One batched decode step: (params, tokens (B,1), cache) ->
    (logits (B,V), cache)."""
    assert shape.kind == "decode"
    b = shape.global_batch
    ctx = specs_mod.decode_context(shape)
    enc = specs_mod._n_frames(shape.seq_len) if cfg.is_encdec else 0

    p_spec = shd.param_pspecs(cfg, mesh, rules)
    c_spec = shd.cache_pspecs(cfg, b, ctx, mesh, enc_len=enc, rules=rules,
                              shard_seq=shape.name.startswith("long"))
    b_spec = shd.batch_pspec(mesh, rules, batch_size=b)

    def serve_step(params, tokens, cache):
        logits, new_cache = transformer.decode_step(params, cfg, tokens,
                                                    cache)
        return logits, new_cache

    named = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree)
    jitted = jax.jit(
        serve_step,
        in_shardings=(named(p_spec), NamedSharding(mesh, b_spec),
                      named(c_spec)),
        out_shardings=(NamedSharding(mesh, b_spec), named(c_spec)),
        donate_argnums=(2,))
    return jitted, {"params": p_spec, "cache": c_spec, "batch": b_spec}


def make_prefill_step(cfg: ModelConfig, mesh, shape: ShapeConfig,
                      rules: shd.ShardingRules = shd.DEFAULT_RULES):
    """One-shot prefill: (params, tokens (B,S), cache[, frontend]) ->
    (last-token logits (B,V), cache)."""
    assert shape.kind == "prefill"
    b, s = shape.global_batch, shape.seq_len
    enc = specs_mod._n_frames(shape.seq_len) if cfg.is_encdec else 0

    p_spec = shd.param_pspecs(cfg, mesh, rules)
    c_spec = shd.cache_pspecs(cfg, b, s, mesh, enc_len=enc, rules=rules)
    b_spec = shd.batch_pspec(mesh, rules, batch_size=b)

    named = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree)
    extra = []
    if cfg.frontend == "patch":
        def prefill_step(params, tokens, cache, vision_embeds):
            return transformer.prefill(params, cfg, tokens, cache,
                                       vision_embeds=vision_embeds)
        extra.append(NamedSharding(mesh, b_spec))
    elif cfg.is_encdec:
        def prefill_step(params, tokens, cache, frames):
            return transformer.prefill(params, cfg, tokens, cache,
                                       frames=frames)
        extra.append(NamedSharding(mesh, b_spec))
    else:
        def prefill_step(params, tokens, cache):
            return transformer.prefill(params, cfg, tokens, cache)

    jitted = jax.jit(
        prefill_step,
        in_shardings=(named(p_spec), NamedSharding(mesh, b_spec),
                      named(c_spec), *extra),
        out_shardings=(NamedSharding(mesh, b_spec), named(c_spec)),
        donate_argnums=(2,))
    return jitted, {"params": p_spec, "cache": c_spec, "batch": b_spec}
