"""xlstm-350m — sLSTM + mLSTM blocks (xLSTM[7:1]).  [arXiv:2405.04517;
unverified]

24L d_model=1024 4H vocab=50304, d_ff=0 (mLSTM blocks carry their own
up-projection; sLSTM blocks use a small gated FFN).  Constant-size
recurrent state → long_500k RUNS (the "cache" is the state, not a KV
buffer).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    mlstm_ratio=7,          # 7 mLSTM then 1 sLSTM, repeated
    ssm_expand=2,
)

SMOKE = CONFIG.replace(n_layers=6, d_model=64, n_heads=2, n_kv_heads=2,
                       vocab=256, mlstm_ratio=2)
