"""Architecture config registry.

``get_config(name)`` / ``get_smoke(name)`` resolve the 10 assigned
architectures plus the paper's own agentic-workload configs; ``ARCHS``
lists the assigned ids in the assignment's order.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (FULL_ATTENTION, SHAPES, BlockSpec,
                                ModelConfig, Segment, ShapeConfig,
                                shape_applicable)

ARCHS: tuple[str, ...] = (
    "h2o-danube-3-4b",
    "llama3-405b",
    "command-r-plus-104b",
    "gemma3-27b",
    "arctic-480b",
    "kimi-k2-1t-a32b",
    "qwen2-vl-2b",
    "hymba-1.5b",
    "xlstm-350m",
    "seamless-m4t-large-v2",
)

_EXTRA = ("tiny-agent", "lm-100m", "agent-7b", "agent-1b")


def _module(name: str) -> str:
    if name in _EXTRA:
        return "repro.configs.paper_agentic"
    return "repro.configs." + name.replace("-", "_").replace(".", "_")


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(_module(name))
    if name in _EXTRA:
        attr = {"tiny-agent": "TINY_AGENT", "lm-100m": "LM_100M",
                "agent-7b": "AGENT_7B", "agent-1b": "AGENT_1B"}[name]
        return getattr(mod, attr)
    cfg = mod.CONFIG
    assert cfg.name == name, (cfg.name, name)
    return cfg


def get_smoke(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    mod = importlib.import_module(_module(name))
    return getattr(mod, "SMOKE", get_config(name))


__all__ = [
    "ARCHS", "SHAPES", "BlockSpec", "FULL_ATTENTION", "ModelConfig",
    "Segment", "ShapeConfig", "get_config", "get_smoke", "shape_applicable",
]
