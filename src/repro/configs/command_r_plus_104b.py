"""command-r-plus-104b — dense GQA, no-bias, parallel attn+FFN block.

[hf:CohereForAI/c4ai-command-r-v01; unverified] 64L d_model=12288 96H
(GQA kv=8) d_ff=33792 vocab=256000.  Full attention → long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab=256000,
    parallel_block=True,          # cohere runs attention and FFN in parallel
    rope_theta=75_000.0,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab=256, attn_chunk=8)
