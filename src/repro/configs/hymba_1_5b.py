"""hymba-1.5b — hybrid: parallel attention + mamba heads in every layer.

[arXiv:2411.13676; hf] 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001 ssm_state=16.  SWA(1024) everywhere except global full
attention at layers {0, 16, 31} (first/middle/last, per the paper).
Hybrid + bounded windows → long_500k RUNS.  Hymba's 128 learnable meta
tokens are a prompt-side detail and are omitted from the shape cells
(noted in DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    window=1024,
    global_layers=(0, 16, 31),
    ssm_state=16,
    rope_theta=10_000.0,
)

SMOKE = CONFIG.replace(n_layers=5, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab=256, window=16, global_layers=(0, 4),
                       ssm_state=4, attn_chunk=8)
