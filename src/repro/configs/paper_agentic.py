"""Configs for the paper's own workload: the MetaGPT-like developer→tester
agentic pipeline (Figures 1, 3, 6, 7).

The paper serves two agents (a "developer" that emits functions and a
"tester" that generates tests) behind a serving framework.  On this CPU
container the *real-engine* examples use the tiny configs below; the
load-sweep benchmarks use the sim substrate with roofline-calibrated costs
for the paper-scale agent (a ~7B-class dense model).
"""
from repro.configs.base import ModelConfig

# Tiny but real: runs actual JAX forward passes on CPU.
TINY_AGENT = ModelConfig(
    name="tiny-agent",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab=512,
    attn_chunk=32,
    rope_theta=10_000.0,
)

# ~100M-class model for the end-to-end training example.
LM_100M = ModelConfig(
    name="lm-100m",
    family="dense",
    n_layers=8,
    d_model=512,
    n_heads=8,
    n_kv_heads=4,
    d_ff=2048,
    vocab=32000,
    attn_chunk=256,
    rope_theta=10_000.0,
)

# Small serving tier (~1B-class dense): the workflow plane's Aragog-style
# per-stage tiering routes cheap stages (map workers, summarizers) here
# instead of the 7B tier — same architecture family, ~1/6 the weights.
AGENT_1B = ModelConfig(
    name="agent-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=5632,
    vocab=32000,
    rope_theta=10_000.0,
)

# Paper-scale serving agent (7B-class dense) — used by the sim cost model.
AGENT_7B = ModelConfig(
    name="agent-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=11008,
    vocab=32000,
    rope_theta=10_000.0,
)
