"""h2o-danube-3-4b — dense, llama+mistral mix with sliding-window attention.

[arXiv:2401.16818; unverified] 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000.  SWA on every layer makes the decoder cache bounded, so the
long_500k cell runs for this arch.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab=32000,
    window=4096,
    rope_theta=10_000.0,
)

# Reduced config for CPU smoke tests — same family/structure, tiny dims.
SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab=256, window=16, attn_chunk=8)
