"""qwen2-vl-2b — VLM backbone with M-RoPE and dynamic resolution.

[arXiv:2409.12191; hf] 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936.  The vision tower is a STUB per the assignment:
``input_specs()`` provides precomputed patch embeddings that are scattered
into the token stream; M-RoPE uses 3-axis (t, h, w) positions with
sections (16, 24, 24) over d_head/2 = 64.  Full attention → long_500k skip.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_head=128,
    d_ff=8960,
    vocab=151936,
    mrope_sections=(16, 24, 24),
    frontend="patch",
    rope_theta=1_000_000.0,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       d_head=16, d_ff=128, vocab=256,
                       mrope_sections=(2, 3, 3), attn_chunk=8)
