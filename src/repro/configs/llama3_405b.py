"""llama3-405b — dense GQA, 128k vocab.  [arXiv:2407.21783; unverified]

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.  Pure full
attention → long_500k is skipped per the assignment's sub-quadratic rule.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab=128256,
    rope_theta=500_000.0,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=192, vocab=256, attn_chunk=8)
