"""seamless-m4t-large-v2 — encoder-decoder, multimodal.  [arXiv:2308.11596; hf]

24L (encoder) + 24L (decoder) d_model=1024 16H (kv=16, i.e. MHA) d_ff=8192
vocab=256206.  The speech frontend is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings (B, T, d_model).
Decode shapes lower the *decoder* step (self-attn cache + precomputed
cross-attention KV).  Full attention → long_500k skipped.

LM shape convention for enc-dec (documented in DESIGN.md): a cell with
seq_len S splits into S/2 encoder frames + S/2 decoder tokens for train
and prefill; decode cells use an S/2 decoder self-cache + S/2 encoder
memory.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    enc_layers=24,
    frontend="frames",
    rope_theta=10_000.0,
)

SMOKE = CONFIG.replace(n_layers=2, enc_layers=2, d_model=64, n_heads=4,
                       n_kv_heads=4, d_ff=128, vocab=256, attn_chunk=8)
