"""Model / shape / mesh configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig`` plus a
``stack plan`` — an ordered list of (possibly nested) block segments that
``models/transformer.py`` compiles into ``jax.lax.scan`` stacks.  The plan
keeps compile time O(#distinct block types) instead of O(#layers), which is
what makes 126-layer dry-runs tractable, and lets heterogeneous stacks
(gemma3's 5 local : 1 global, hymba's 3 global islands, xLSTM's 7 mLSTM :
1 sLSTM) stay scan-friendly.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

# ---------------------------------------------------------------------------
# Block specs
# ---------------------------------------------------------------------------

FULL_ATTENTION = -1  # sentinel window meaning "no sliding window"


@dataclass(frozen=True)
class BlockSpec:
    """One block *type* in the stack plan.

    kind:
      'attn'    — attention + (dense MLP | MoE) residual block
      'hymba'   — parallel attention + mamba heads, fused output
      'mlstm'   — xLSTM matrix-memory block (has its own up-proj; no MLP)
      'slstm'   — xLSTM scalar-memory block (+ small gated FFN)
      'enc'     — bidirectional encoder block (attn + MLP)
      'dec'     — decoder block w/ cross attention (self + cross + MLP)
    """

    kind: str = "attn"
    window: int = FULL_ATTENTION          # sliding window size; -1 = full
    moe: bool = False                     # MoE FFN instead of dense
    dense_residual: bool = False          # arctic: dense FFN in parallel w/ MoE
    n_shared_experts: int = 0             # kimi: always-on shared expert(s)
    parallel_block: bool = False          # cohere: attn & MLP in parallel
    cross_attention: bool = False         # decoder blocks

    def cache_kinds(self) -> tuple[str, ...]:
        """Which decode-state tensors this block carries."""
        if self.kind in ("attn", "enc", "dec"):
            kinds = ("kv",)
            if self.cross_attention:
                kinds = ("kv", "cross_kv")
            return kinds
        if self.kind == "hymba":
            return ("kv", "ssm")
        if self.kind == "mlstm":
            return ("mlstm",)
        if self.kind == "slstm":
            return ("slstm",)
        raise ValueError(self.kind)


@dataclass(frozen=True)
class Segment:
    """A run of layers in the model.

    pattern: tuple of (BlockSpec, n_inner) executed in order; the whole
    pattern repeats ``repeat`` times.  A plain homogeneous stack is
    ``Segment(((spec, n),), repeat=1)``.

    Parameters for each pattern element are stacked with leading dims
    ``(repeat, n_inner, ...)`` and executed with nested ``lax.scan``.
    """

    pattern: tuple[tuple[BlockSpec, int], ...]
    repeat: int = 1

    @property
    def n_layers(self) -> int:
        return self.repeat * sum(n for _, n in self.pattern)


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | vlm | hybrid | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                  # 0 -> d_model // n_heads

    # attention structure
    window: int = FULL_ATTENTION     # default sliding window for all layers
    local_global_ratio: int = 0      # gemma3: N local then 1 global
    global_layers: tuple[int, ...] = ()   # hymba: explicit global layer ids
    rope_theta: float = 500_000.0
    mrope_sections: tuple[int, ...] = ()  # qwen2-vl M-RoPE (sums to d_head//2)
    parallel_block: bool = False     # cohere
    qk_norm: bool = False
    logit_softcap: float = 0.0       # gemma-style final-logit softcap

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    first_k_dense: int = 0           # kimi: first k layers use dense FFN
    dense_residual: bool = False     # arctic
    capacity_factor: float = 1.25

    # SSM / hybrid / xLSTM
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    mlstm_ratio: int = 0             # xlstm: k mLSTM blocks then 1 sLSTM

    # enc-dec
    enc_layers: int = 0              # >0 => encoder-decoder model
    frontend: str = "none"           # 'patch' (vlm) | 'frames' (audio) | none

    # numerics / structure
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # implementation knobs (hillclimbing surface)
    attn_chunk: int = 1024           # query-chunked attention block
    use_pallas: bool = False         # swap pure-jnp attention for kernels
    remat: bool = True
    scan_layers: bool = True
    act_sharding: bool = True        # layer-boundary sharding constraints
                                     # (batch over data, seq over model)
    loss_chunk: int = 2048           # seq-chunked unembed+xent (0 = off);
                                     # avoids materializing (B, S, V)
    unroll_ssm: bool = False         # flatten recurrent chunk scans
                                     # (cost-analysis only; compile-heavy)

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    # -- derived -----------------------------------------------------------
    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """True if the decoder never needs an unbounded full-attention cache
        in *every* layer (assignment rule for long_500k eligibility)."""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.window > 0 and not self.global_layers and not self.local_global_ratio:
            return True   # pure SWA (danube)
        if self.local_global_ratio > 0:
            return True   # gemma3: bounded except sparse global layers
        return False

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- stack plan ----------------------------------------------------------
    def plan(self) -> list[Segment]:
        """Decoder (or decoder-only) stack plan."""
        if self.family == "ssm":
            return self._xlstm_plan()
        if self.family == "hybrid":
            return self._hymba_plan()
        if self.is_encdec:
            spec = BlockSpec(kind="dec", cross_attention=True)
            return [Segment(((spec, self.n_layers),))]
        if self.local_global_ratio > 0:
            return self._local_global_plan()
        base = BlockSpec(
            kind="attn",
            window=self.window,
            moe=self.n_experts > 0,
            dense_residual=self.dense_residual,
            n_shared_experts=self.n_shared_experts,
            parallel_block=self.parallel_block,
        )
        segs: list[Segment] = []
        n = self.n_layers
        if self.n_experts > 0 and self.first_k_dense > 0:
            dense = dataclasses.replace(base, moe=False, dense_residual=False,
                                        n_shared_experts=0)
            segs.append(Segment(((dense, self.first_k_dense),)))
            n -= self.first_k_dense
        segs.append(Segment(((base, n),)))
        return segs

    def enc_plan(self) -> list[Segment]:
        assert self.is_encdec
        spec = BlockSpec(kind="enc")
        return [Segment(((spec, self.enc_layers),))]

    def _local_global_plan(self) -> list[Segment]:
        r = self.local_global_ratio
        local = BlockSpec(kind="attn", window=self.window)
        glob = BlockSpec(kind="attn", window=FULL_ATTENTION)
        group = r + 1
        n_groups, leftover = divmod(self.n_layers, group)
        segs = [Segment(((local, r), (glob, 1)), repeat=n_groups)]
        if leftover:
            segs.append(Segment(((local, leftover),)))
        return segs

    def _hymba_plan(self) -> list[Segment]:
        """hymba: global full attention at explicit layer ids, SWA elsewhere;
        every layer is a parallel attn+mamba block."""
        swa = BlockSpec(kind="hymba", window=self.window)
        glob = BlockSpec(kind="hymba", window=FULL_ATTENTION)
        ids = set(self.global_layers)
        segs: list[Segment] = []
        run = 0
        for i in range(self.n_layers):
            if i in ids:
                if run:
                    segs.append(Segment(((swa, run),)))
                    run = 0
                segs.append(Segment(((glob, 1),)))
            else:
                run += 1
        if run:
            segs.append(Segment(((swa, run),)))
        return segs

    def _xlstm_plan(self) -> list[Segment]:
        m = BlockSpec(kind="mlstm")
        s = BlockSpec(kind="slstm")
        if self.mlstm_ratio <= 0:
            return [Segment(((m, self.n_layers),))]
        group = self.mlstm_ratio + 1
        n_groups, leftover = divmod(self.n_layers, group)
        segs = [Segment(((m, self.mlstm_ratio), (s, 1)), repeat=n_groups)]
        if leftover:
            segs.append(Segment(((m, leftover),)))
        return segs


# ---------------------------------------------------------------------------
# Shapes (assigned input-shape set) & mesh config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # 'train' | 'prefill' | 'decode'
    microbatch: int = 0  # 0 = auto (train only)


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: long_500k requires sub-quadratic attention (see DESIGN.md)"
    return True, ""
