"""arctic-480b — MoE 128 experts top-2 with a dense residual FFN in
parallel (dense-MoE hybrid).  [hf:Snowflake/snowflake-arctic-base; hf]

35L d_model=7168 56H (GQA kv=8) d_ff=4864 (expert dim) vocab=32000.
Full attention → long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,              # dense-residual FFN width
    vocab=32000,
    n_experts=128,
    top_k=2,
    d_ff_expert=4864,
    dense_residual=True,
    rope_theta=10_000.0,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=96, d_ff_expert=96, n_experts=8, top_k=2,
                       vocab=256, attn_chunk=8)
