"""kimi-k2-1t-a32b — trillion-parameter MoE, 384 experts top-8 + 1 shared
expert, first layer dense.  [arXiv:2501.kimi2; unverified]

61L d_model=7168 64H (GQA kv=8) d_ff=2048 (expert dim) vocab=163840.
Full attention → long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=11264,             # dense FFN width for the first_k_dense layers
    vocab=163840,
    n_experts=384,
    top_k=8,
    d_ff_expert=2048,
    n_shared_experts=1,
    first_k_dense=1,
    rope_theta=50_000.0,
)

SMOKE = CONFIG.replace(n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, d_ff_expert=32, n_experts=8, top_k=2,
                       n_shared_experts=1, first_k_dense=1, vocab=256,
                       attn_chunk=8)
