"""gemma3-27b — dense GQA with 5:1 local:global attention, 262k vocab.

[hf:google/gemma-3-1b-pt; unverified] 62L d_model=5376 32H (GQA kv=16)
d_ff=21504 vocab=262144.  5 local (window 1024) then 1 global layer; the
stack plan nests the 6-layer cycle in an outer scan.  long_500k RUNS
(bounded cache in 5/6 of layers; global layers are decode-linear).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_head=128,
    d_ff=21504,
    vocab=262144,
    window=1024,
    local_global_ratio=5,
    qk_norm=True,
    logit_softcap=30.0,
    rope_theta=1_000_000.0,
)

SMOKE = CONFIG.replace(n_layers=13, d_model=64, n_heads=4, n_kv_heads=2,
                       d_head=16, d_ff=128, vocab=256, window=16,
                       attn_chunk=8)
