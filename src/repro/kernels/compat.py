"""Pallas-TPU API compatibility across JAX generations.

The kernels target the current accelerator toolchain, where the Mosaic
compiler-params dataclass is ``pltpu.CompilerParams``; on the previous
generation (JAX <= 0.4.x) the same object is ``pltpu.TPUCompilerParams``.
Everything else the kernels use (``pl.pallas_call``, ``BlockSpec``,
``PrefetchScalarGridSpec``, VMEM scratch) is stable across both, so this
one alias is the entire skew — resolving it here keeps every kernel
importable (and interpret-mode testable) on either toolchain instead of
skipping the whole suite on the older one.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams", None)

HAVE_COMPILER_PARAMS = CompilerParams is not None


def compiler_params(**kw):
    """Build Mosaic compiler params (``dimension_semantics`` etc.) on
    whichever API generation is installed."""
    if CompilerParams is None:  # pragma: no cover - env dependent
        raise RuntimeError("no Pallas TPU CompilerParams API available")
    return CompilerParams(**kw)
