"""Pallas TPU kernels for the serving substrate's compute hot spots.

Each kernel ships as <name>.py (pl.pallas_call + BlockSpec), with the
jit'd model-layout wrapper in ops.py and the pure-jnp oracle in ref.py.
Validated on CPU via interpret=True (tests/test_kernels.py sweeps
shapes/dtypes against the oracles).
"""
from repro.kernels import ops, ref
from repro.kernels.ops import (decode_attention, flash_attention,
                               grouped_matmul, paged_decode_attention,
                               ssm_scan)

__all__ = ["ops", "ref", "decode_attention", "flash_attention",
           "grouped_matmul", "paged_decode_attention", "ssm_scan"]
