"""Pure-jnp oracles for every Pallas kernel (the ground truth the sweep
tests in tests/test_kernels.py assert against)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=-1):
    """q: (B,H,S,dh); k,v: (B,Hkv,T,dh) -> (B,H,S,dh)."""
    b, h, s, dh = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, s, dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bhgsd,bhtd->bhgst", qg, kf) / math.sqrt(dh)
    if causal:
        qpos = jnp.arange(s)[:, None]
        kpos = jnp.arange(t)[None, :]
        mask = kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgst,bhtd->bhgsd", p, v.astype(jnp.float32))
    return out.reshape(b, h, s, dh).astype(q.dtype)


def decode_attention_ref(q, k, v, kpos, q_pos, *, window=-1):
    """q: (B,Hkv,G,dh); k,v: (B,Hkv,T,dh); kpos: (B,T); q_pos: (B,1)."""
    b, hkv, g, dh = q.shape
    scores = jnp.einsum("bhgd,bhtd->bhgt", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(dh)
    valid = (kpos >= 0) & (kpos <= q_pos)            # (B, T)
    if window > 0:
        valid &= kpos > q_pos - window
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgt,bhtd->bhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_decode_attention_ref(q, k_pages, v_pages, block_tables, ctx_lens,
                               *, window=-1):
    """Gather-then-attend oracle for the paged kernel.  q: (B,Hkv,G,dh);
    k_pages/v_pages: (N,page,Hkv,dh); block_tables: (B,P) int32 (-1 =
    unmapped); ctx_lens: (B,)."""
    b = q.shape[0]
    page = k_pages.shape[1]
    t = block_tables.shape[1] * page
    ids = jnp.maximum(block_tables, 0)                    # (B, P)
    k = k_pages[ids].reshape(b, t, *k_pages.shape[2:])    # (B, T, Hkv, dh)
    v = v_pages[ids].reshape(b, t, *v_pages.shape[2:])
    kpos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    kpos = jnp.where(kpos < ctx_lens[:, None], kpos, -1)
    qpos = (ctx_lens - 1)[:, None]
    return decode_attention_ref(q, jnp.moveaxis(k, 2, 1),
                                jnp.moveaxis(v, 2, 1), kpos, qpos,
                                window=window)


def grouped_matmul_ref(x, w, counts):
    """x: (E,C,d); w: (E,d,f); counts: (E,) -> (E,C,f) with rows past
    counts zeroed (they are padding)."""
    out = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                     w.astype(jnp.float32))
    c = x.shape[1]
    row = jnp.arange(c)[None, :, None]
    return jnp.where(row < counts[:, None, None], out, 0.0).astype(x.dtype)


def ssm_scan_ref(q, k, v, log_a, h0):
    """Sequential recurrence.  q,k: (B,H,T,dk); v: (B,H,T,dv);
    log_a: (B,H,T,1); h0: (B,H,dk,dv) -> (y (B,H,T,dv), hT)."""
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    a = jnp.exp(log_a.astype(jnp.float32))[..., 0]   # (B,H,T)

    def step(h, xs):
        qt, kt, vt, at = xs                          # (B,H,dk) ... (B,H)
        h = at[..., None, None] * h + kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhd,bhde->bhe", qt, h)
        return h, y

    xs = (jnp.moveaxis(qf, 2, 0), jnp.moveaxis(kf, 2, 0),
          jnp.moveaxis(vf, 2, 0), jnp.moveaxis(a, 2, 0))
    hT, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 2).astype(v.dtype), hT
