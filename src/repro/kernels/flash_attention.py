"""Blocked causal flash attention (prefill/training) — Pallas TPU kernel.

Grid (B, H, nq, nk); the nk axis iterates sequentially per (b, h, iq) with
the running (m, l, acc) streaming-softmax state held in VMEM scratch —
the TPU-native restatement of flash attention (no warp shuffles; the MXU
consumes (blk_q × dh) · (dh × blk_k) tiles, dh padded to a lane multiple
of 128 by ops.py).

Supports GQA (q head h reads kv head h // group via the k/v index_map)
and sliding windows (fully-masked k-blocks are skipped with ``pl.when``,
so SWA costs O(S·window) not O(S²)).

Layout (from ops.py): q (B, H, S, dh); k, v (B, Hkv, T, dh).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            blk_q: int, blk_k: int, kv_len: int, window: int, causal: bool,
            scale: float):
    iq = pl.program_id(2)
    jk = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * blk_q
    k_start = jk * blk_k
    # block-level skip: fully-masked k blocks never touch the MXU
    live = k_start < kv_len
    if causal:
        live &= k_start <= q_start + blk_q - 1
        if window > 0:
            live &= (k_start + blk_k - 1) > (q_start - window)

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (blk_q, dh)
        k = k_ref[0, 0].astype(jnp.float32)                  # (blk_k, dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (blk_q, blk_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (blk_q, blk_k), 1)
        mask = k_pos < kv_len
        if causal:
            mask &= k_pos <= q_pos
            if window > 0:
                mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                  # (blk_q, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                               # (blk_q, blk_k)
        v = v_ref[0, 0].astype(jnp.float32)                  # (blk_k, dh)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(jk == nk - 1)
    def _fin():
        l = l_ref[...]
        out = acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "blk_q", "blk_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = -1,
                    blk_q: int = 128, blk_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, S, dh); k, v: (B, Hkv, T, dh).  S, T multiples of the
    block sizes and dh lane-aligned (ops.py pads).  Returns (B, H, S, dh)."""
    b, h, s, dh = q.shape
    _, hkv, t, _ = k.shape
    group = h // hkv
    nq, nk = s // blk_q, t // blk_k
    scale = 1.0 / math.sqrt(dh)

    kern = functools.partial(_kernel, blk_q=blk_q, blk_k=blk_k, kv_len=t,
                             window=window, causal=causal, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, dh), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, blk_k, dh),
                         lambda b_, h_, i, j, g=group: (b_, h_ // g, j, 0)),
            pl.BlockSpec((1, 1, blk_k, dh),
                         lambda b_, h_, i, j, g=group: (b_, h_ // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, dh),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, dh), jnp.float32),
        ],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
