"""Chunked decayed linear-recurrence scan — Pallas TPU kernel.

Evaluates   h_t = a_t · h_{t-1} + k_t ⊗ v_t ;  y_t = q_t · h_t
in chunk-parallel form: the grid walks (B, H, T/chunk) with the running
(dk × dv) state in VMEM f32 scratch; within a chunk everything is MXU
matmuls (intra-chunk masked decay attention + inter-chunk carry), i.e.
the mamba-2/SSD restatement of the selective scan that DESIGN.md §3
adopts as the TPU-native form.  Backs hymba's mamba branch and xLSTM's
mLSTM cell (via models/ssm.chunked_linear_attention's identical math).

Layout (from ops.py): q, k (B, H, T, dk); v (B, H, T, dv);
log_a (B, H, T, 1) (per-token log decay, <= 0); h0 (B, H, dk, dv).
Outputs: y (B, H, T, dv); h_final (B, H, dk, dv).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat


def _kernel(q_ref, k_ref, v_ref, la_ref, h0_ref, y_ref, hT_ref, h_ref, *,
            chunk: int):
    jc = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(jc == 0)
    def _init():
        h_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    q = q_ref[0, 0].astype(jnp.float32)          # (chunk, dk)
    k = k_ref[0, 0].astype(jnp.float32)          # (chunk, dk)
    v = v_ref[0, 0].astype(jnp.float32)          # (chunk, dv)
    la = la_ref[0, 0].astype(jnp.float32)        # (chunk, 1)
    h = h_ref[...]                               # (dk, dv)

    L = jnp.cumsum(la, axis=0)                   # inclusive, (chunk, 1)
    # intra-chunk: S_ij = (q_i · k_j) exp(L_i - L_j), j <= i
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    ldiff = L - L[:, 0][None, :]                 # (chunk_i, chunk_j)
    decay = jnp.where(lj <= li, jnp.exp(ldiff), 0.0)
    y = jax.lax.dot_general(s * decay, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk: y_i += exp(L_i) q_i · h_prev
    y += jnp.exp(L) * jax.lax.dot_general(
        q, h, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)
    # carry: h = exp(L_last) h + sum_j exp(L_last - L_j) k_j v_j^T
    l_last = L[chunk - 1, 0]
    rem = jnp.exp(l_last - L)                    # (chunk, 1)
    kv = jax.lax.dot_general(k * rem, v, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    h_ref[...] = jnp.exp(l_last) * h + kv

    @pl.when(jc == nc - 1)
    def _fin():
        hT_ref[0, 0] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan(q: jax.Array, k: jax.Array, v: jax.Array, log_a: jax.Array,
             h0: jax.Array, *, chunk: int = 128,
             interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """q,k: (B,H,T,dk); v: (B,H,T,dv); log_a: (B,H,T,1); h0: (B,H,dk,dv).
    T must be a multiple of ``chunk`` (ops.py pads)."""
    b, h, t, dk = q.shape
    dv = v.shape[3]
    chunk = min(chunk, t)
    nc = t // chunk

    kern = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kern,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, dk), lambda b_, h_, j: (b_, h_, j, 0)),
            pl.BlockSpec((1, 1, chunk, dk), lambda b_, h_, j: (b_, h_, j, 0)),
            pl.BlockSpec((1, 1, chunk, dv), lambda b_, h_, j: (b_, h_, j, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b_, h_, j: (b_, h_, j, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda b_, h_, j: (b_, h_, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, chunk, dv), lambda b_, h_, j: (b_, h_, j, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda b_, h_, j: (b_, h_, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, h, t, dv), v.dtype),
            jax.ShapeDtypeStruct((b, h, dk, dv), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, log_a, h0)
