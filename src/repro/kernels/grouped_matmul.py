"""Grouped (per-expert) matmul — Pallas TPU kernel for the MoE layer.

Computes out[e] = x[e] @ w[e] for the (E, C, d) dispatch buffer produced
by models/moe.py's sort-based routing.  Grid (E, nc, nf, nd) accumulates
over the contraction axis in VMEM f32 scratch; experts whose row count is
zero (``counts``) skip the MXU entirely — the TPU equivalent of
megablocks' ragged skip, which is where the kernel beats a dense
einsum when expert load is skewed.

Layout: x (E, C, d); w (E, d, f); counts (E,) int32 (rows actually
occupied per expert; C-padded rows are zeros either way).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat


def _kernel(counts_ref, x_ref, w_ref, o_ref, acc_ref, *,
            blk_c: int):
    e = pl.program_id(0)
    ic = pl.program_id(1)
    kd = pl.program_id(3)
    nd = pl.num_programs(3)

    @pl.when(kd == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    count = counts_ref[e]
    live = ic * blk_c < count

    @pl.when(live)
    def _body():
        x = x_ref[0].astype(jnp.float32)          # (blk_c, blk_d)
        w = w_ref[0].astype(jnp.float32)          # (blk_d, blk_f)
        acc_ref[...] += jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(kd == nd - 1)
    def _fin():
        # zero rows past this expert's live count (padding rows must not
        # leak garbage even if the dispatch buffer wasn't pre-zeroed)
        rows = ic * blk_c + jax.lax.broadcasted_iota(
            jnp.int32, acc_ref.shape, 0)
        acc = jnp.where(rows < count, acc_ref[...], 0.0)
        o_ref[0] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blk_c", "blk_f", "blk_d",
                                             "interpret"))
def grouped_matmul(x: jax.Array, w: jax.Array, counts: jax.Array, *,
                   blk_c: int = 128, blk_f: int = 128, blk_d: int = 128,
                   interpret: bool = False) -> jax.Array:
    """x: (E, C, d) @ w: (E, d, f) -> (E, C, f), skipping empty experts."""
    e, c, d = x.shape
    f = w.shape[2]
    blk_c, blk_f, blk_d = min(blk_c, c), min(blk_f, f), min(blk_d, d)
    grid = (e, c // blk_c, f // blk_f, d // blk_d)

    kern = functools.partial(_kernel, blk_c=blk_c)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # counts, whole array
            pl.BlockSpec((1, blk_c, blk_d),
                         lambda e_, i, j, k_: (e_, i, k_)),
            pl.BlockSpec((1, blk_d, blk_f),
                         lambda e_, i, j, k_: (e_, k_, j)),
        ],
        out_specs=pl.BlockSpec((1, blk_c, blk_f),
                               lambda e_, i, j, k_: (e_, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((blk_c, blk_f), jnp.float32)],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(counts, x, w)
