"""Paged flash-decoding over a block-table-indirected KV pool — Pallas
TPU kernel (the measured fast path behind serving/kv_cache.py).

The KV cache lives in a shared pool of fixed-size pages — the same
128-token pages ``PageAllocator`` accounts for — instead of per-sequence
contiguous rings.  Each sequence names its pages through a **block
table**: row ``b`` lists the physical page ids holding that sequence's
context, in logical order (shared prefix blocks first, then private
pages; -1 pads the tail).  Two sequences sharing a cached prefix simply
list the same physical page ids, so the prefix-cache plane's
"cached context is KV-reads-not-recompute" pricing is realized as an
actual memory-access pattern: one copy of the prefix in HBM, gathered by
every sharer.

The gather is the grid itself: ``PrefetchScalarGridSpec`` prefetches the
block table and context lengths into SMEM before the kernel runs, and
the K/V ``BlockSpec`` index maps read ``bt[b, j]`` to aim each grid
step's DMA at the right physical page — no materialized per-sequence
copy ever exists.  Softmax streams over pages with the usual
(m, l, acc) running max/sum rescaling in VMEM scratch.

Layout: q (B, Hkv, G, dh) — the whole GQA query group rides the MXU
tile; k_pages, v_pages (Hkv, N_pages, page, dh) — a page is the
second-to-last (sublane) axis so each block is a well-tiled
(page × dh) slab; block_tables (B, P) int32; ctx_lens (B,) int32
(number of valid cached tokens; position ``ctx_len - 1`` is the newest).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

NEG_INF = -1e30


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, page: int, window: int):
    b = pl.program_id(0)
    jp = pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(jp == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    dh = q_ref.shape[3]
    q = q_ref[0, 0].astype(jnp.float32) * (1.0 / math.sqrt(dh))  # (G, dh)
    k = k_ref[0, 0].astype(jnp.float32)                          # (page, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, page)
    # token positions this logical page covers; unmapped tail pages
    # (block-table -1, clamped to page 0 by the index map) fall past
    # ctx_len and mask out here
    kpos = jp * page + jax.lax.broadcasted_iota(jnp.int32, (page,), 0)
    ctx = len_ref[b]
    valid = kpos < ctx
    if window > 0:
        valid &= kpos >= ctx - window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    v = v_ref[0, 0].astype(jnp.float32)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(jp == np_ - 1)
    def _fin():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_tables: jax.Array,
                           ctx_lens: jax.Array, *, window: int = -1,
                           interpret: bool = False) -> jax.Array:
    """q: (B, Hkv, G, dh); k_pages, v_pages: (Hkv, N, page, dh);
    block_tables: (B, P) int32, -1 = unmapped; ctx_lens: (B,) int32.
    Returns (B, Hkv, G, dh)."""
    b, hkv, g, dh = q.shape
    page = k_pages.shape[2]
    npages = block_tables.shape[1]

    def q_map(b_, h_, j, bt_ref, len_ref):
        return (b_, h_, 0, 0)

    def kv_map(b_, h_, j, bt_ref, len_ref):
        # the paged gather: logical page j of sequence b_ lives at
        # physical page bt[b_, j]; -1 (tail padding) clamps to page 0,
        # whose keys the kernel masks out via ctx_lens
        return (h_, jnp.maximum(bt_ref[b_, j], 0), 0, 0)

    kern = functools.partial(_kernel, page=page, window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, npages),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), q_map),
            pl.BlockSpec((1, 1, page, dh), kv_map),
            pl.BlockSpec((1, 1, page, dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), q_map),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dh), q.dtype),
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), ctx_lens.astype(jnp.int32),
      q, k_pages, v_pages)
