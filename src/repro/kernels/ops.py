"""jit'd public wrappers around the Pallas kernels.

Handles model-layout <-> kernel-layout transposes, pads head dims to the
TPU lane width (128) and sublane minimum (8), and auto-selects
interpret mode off-TPU (this container is CPU: kernels execute their
bodies in Python via interpret=True; on a real TPU the same code lowers
to Mosaic).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import grouped_matmul as _gm
from repro.kernels import paged_decode_attention as _pdec
from repro.kernels import ssm_scan as _ssm

LANE = 128
SUBLANE = 8


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flash_attention(q, k, v, *, causal=True, window=-1,
                    blk_q=128, blk_k=128, interpret=None):
    """Model layout: q (B,S,H,dh); k,v (B,T,Hkv,dh) -> (B,S,H,dh)."""
    if interpret is None:
        interpret = not on_tpu()
    b, s, h, dh = q.shape
    t = k.shape[1]
    qt = _pad_to(jnp.moveaxis(q, 2, 1), 3, LANE)       # (B,H,S,dh')
    kt = _pad_to(jnp.moveaxis(k, 2, 1), 3, LANE)
    vt = _pad_to(jnp.moveaxis(v, 2, 1), 3, LANE)
    blk_q = min(blk_q, max(s, SUBLANE))
    blk_k = min(blk_k, t)
    qt = _pad_to(qt, 2, blk_q)
    kt = _pad_to(kt, 2, blk_k)
    vt = _pad_to(vt, 2, blk_k)
    # scale uses the padded dh; rescale q to compensate
    qt = qt * (jnp.sqrt(qt.shape[-1] / dh).astype(qt.dtype))
    out = _fa.flash_attention(qt, kt, vt, causal=causal, window=window,
                              blk_q=blk_q, blk_k=blk_k, interpret=interpret)
    return jnp.moveaxis(out[:, :, :s, :dh], 1, 2)


def decode_attention(q, cache_k, cache_v, kpos, q_pos, *, window=-1,
                     blk_k=128, interpret=None):
    """Model layout: q (B,1,H,dh); cache k/v (B,T,Hkv,dh); kpos (B,T);
    q_pos (B,) -> (B,1,H,dh)."""
    if interpret is None:
        interpret = not on_tpu()
    b, _, h, dh = q.shape
    t = cache_k.shape[1]
    hkv = cache_k.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, dh)
    qg = _pad_to(_pad_to(qg, 2, SUBLANE), 3, LANE)
    kt = _pad_to(jnp.moveaxis(cache_k, 2, 1), 3, LANE)  # (B,Hkv,T,dh')
    vt = _pad_to(jnp.moveaxis(cache_v, 2, 1), 3, LANE)
    blk_k = min(blk_k, t)
    kt = _pad_to(kt, 2, blk_k)
    vt = _pad_to(vt, 2, blk_k)
    kp = _pad_to(kpos, 1, blk_k) if t % blk_k else kpos
    if kp.shape[1] > t:   # padded slots must be invalid
        kp = kp.at[:, t:].set(-1)
    qg = qg * (jnp.sqrt(qg.shape[-1] / dh).astype(qg.dtype))
    out = _dec.decode_attention(qg, kt, vt, kp, q_pos[:, None],
                                window=window, blk_k=blk_k,
                                interpret=interpret)
    return out[:, :, :g, :dh].reshape(b, 1, h, dh)


def paged_decode_attention(q, k_pages, v_pages, block_tables, ctx_lens, *,
                           window=-1, interpret=None):
    """Paged decode attention over a shared KV page pool.

    Model layout: q (B, 1, H, dh); k_pages/v_pages (N_pages, page, Hkv,
    dh) — the allocator-natural pool layout (a real engine would store
    pages in the kernel's (Hkv, N, page, dh) layout and skip the
    transpose); block_tables (B, P) int32 physical page ids in logical
    order, -1 = unmapped tail; ctx_lens (B,) int32 valid cached tokens.
    Returns (B, 1, H, dh).
    """
    if interpret is None:
        interpret = not on_tpu()
    b, _, h, dh = q.shape
    hkv = k_pages.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, dh)
    qg = _pad_to(_pad_to(qg, 2, SUBLANE), 3, LANE)
    kt = _pad_to(jnp.moveaxis(k_pages, 2, 0), 3, LANE)  # (Hkv, N, page, dh')
    vt = _pad_to(jnp.moveaxis(v_pages, 2, 0), 3, LANE)
    # scale uses the padded dh; rescale q to compensate
    qg = qg * (jnp.sqrt(qg.shape[-1] / dh).astype(qg.dtype))
    out = _pdec.paged_decode_attention(qg, kt, vt, block_tables, ctx_lens,
                                       window=window, interpret=interpret)
    return out[:, :, :g, :dh].reshape(b, 1, h, dh)


def grouped_matmul(x, w, counts, *, interpret=None):
    """x (E,C,d) @ w (E,d,f) with per-expert row counts."""
    if interpret is None:
        interpret = not on_tpu()
    e, c, d = x.shape
    f = w.shape[2]
    xp = _pad_to(_pad_to(x, 1, SUBLANE), 2, LANE)
    wp = _pad_to(_pad_to(w, 1, LANE), 2, LANE)
    out = _gm.grouped_matmul(xp, wp, counts, interpret=interpret)
    return out[:, :c, :f]


def ssm_scan(q, k, v, log_a, h0, *, chunk=128, interpret=None):
    """Model layout: q,k (B,T,H,dk); v (B,T,H,dv); log_a (B,T,H);
    h0 (B,H,dk,dv) -> (y (B,T,H,dv), hT)."""
    if interpret is None:
        interpret = not on_tpu()
    b, t, h, dk = q.shape
    dv = v.shape[3]
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    la = jnp.moveaxis(log_a, 2, 1)[..., None]          # (B,H,T,1)
    chunk = min(chunk, t)
    pad_t = (-t) % chunk
    if pad_t:
        qt = _pad_to(qt, 2, chunk)
        kt = _pad_to(kt, 2, chunk)
        vt = _pad_to(vt, 2, chunk)
        la = _pad_to(la, 2, chunk)   # zeros: a=1, k=0 -> state unchanged
    y, hT = _ssm.ssm_scan(qt, kt, vt, la, h0, chunk=chunk,
                          interpret=interpret)
    return jnp.moveaxis(y[:, :, :t], 1, 2), hT
