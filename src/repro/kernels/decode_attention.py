"""Flash-decoding over a (ring) KV cache — Pallas TPU kernel.

One new query token per sequence attends to the cached keys.  The grid
iterates KV blocks sequentially per (batch, kv-head) with streaming
(m, l, acc) in VMEM scratch; the whole GQA query group (G = H/Hkv rows,
padded to the 8-sublane minimum by ops.py) rides in the MXU tile, so a
128-key block does a (G × dh)·(dh × 128) matmul per step.

Validity comes from the ring cache's ``kpos`` (absolute position per
slot, -1 = empty): mask = 0 <= kpos <= q_pos (and > q_pos - window), so
ring wraparound and partially-filled caches need no special cases —
identical semantics to models/attention.py's cached path.

Layout (from ops.py): q (B, Hkv, G, dh); k, v (B, Hkv, T, dh);
kpos (B, T) int32; q_pos (B, 1) int32.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, kpos_ref, qpos_ref, o_ref,
            m_ref, l_ref, acc_ref, *, blk_k: int, window: int):
    jk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g, dh = q_ref.shape[2], q_ref.shape[3]
    q = q_ref[0, 0].astype(jnp.float32) * (1.0 / math.sqrt(dh))  # (G, dh)
    k = k_ref[0, 0].astype(jnp.float32)                          # (blk_k, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, blk_k)
    kpos = kpos_ref[0]                                           # (blk_k,)
    qpos = qpos_ref[0, 0]
    valid = (kpos >= 0) & (kpos <= qpos)
    if window > 0:
        valid &= kpos > qpos - window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    v = v_ref[0, 0].astype(jnp.float32)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(jk == nk - 1)
    def _fin():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "blk_k", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kpos: jax.Array, q_pos: jax.Array, *,
                     window: int = -1, blk_k: int = 128,
                     interpret: bool = False) -> jax.Array:
    """q: (B, Hkv, G, dh); k, v: (B, Hkv, T, dh); kpos: (B, T);
    q_pos: (B, 1).  Returns (B, Hkv, G, dh)."""
    b, hkv, g, dh = q.shape
    t = k.shape[2]
    # pad the key axis up to a whole number of blocks: the tail block's
    # padded slots carry kpos = -1, which the validity mask already
    # treats as empty — without this, t % blk_k trailing keys would be
    # silently dropped from the softmax
    nk = -(-t // blk_k)
    pad = nk * blk_k - t
    if pad:
        widths4 = ((0, 0), (0, 0), (0, pad), (0, 0))
        k = jnp.pad(k, widths4)
        v = jnp.pad(v, widths4)
        kpos = jnp.pad(kpos, ((0, 0), (0, pad)), constant_values=-1)

    kern = functools.partial(_kernel, blk_k=blk_k, window=window)
    return pl.pallas_call(
        kern,
        grid=(b, hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda b_, h_, j: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, blk_k, dh), lambda b_, h_, j: (b_, h_, j, 0)),
            pl.BlockSpec((1, 1, blk_k, dh), lambda b_, h_, j: (b_, h_, j, 0)),
            pl.BlockSpec((1, blk_k), lambda b_, h_, j: (b_, j)),
            pl.BlockSpec((1, 1), lambda b_, h_, j: (b_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), lambda b_, h_, j: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, kpos, q_pos)
