"""Training-side fault tolerance: checkpoint/restart supervision.

``TrainSupervisor.run`` drives a step function and transparently
survives failures: on any exception from the step (a real crash, a
``SimulatedFailure`` injected by tests, a preemption signal) it restores
the latest checkpoint — params, optimizer state, *and* the data-pipeline
cursor — and resumes.  Combined with the deterministic TokenPipeline the
post-restart trajectory is bit-identical to an uninterrupted run (the
restart test asserts exactly this)."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Optional

from repro.checkpoint.manager import CheckpointManager


class SimulatedFailure(RuntimeError):
    """Injected by tests/chaos hooks to exercise the restart path."""


@dataclass
class SupervisorConfig:
    ckpt_every: int = 50
    max_restarts: int = 10
    async_ckpt: bool = True


class TrainSupervisor:
    def __init__(self, manager: CheckpointManager,
                 cfg: Optional[SupervisorConfig] = None):
        self.mgr = manager
        self.cfg = cfg or SupervisorConfig()
        self.restarts = 0
        self.log: list[str] = []

    def run(self, *, state: Any, pipeline, step_fn: Callable,
            total_steps: int,
            on_step: Optional[Callable] = None) -> Any:
        """state: pytree (params, opt_state, ...) — anything the step
        consumes and returns.  step_fn(state, batch, step) -> state.
        """
        step = 0
        # resume if a checkpoint exists
        restored = self.mgr.restore_latest(state)
        if restored is not None:
            step, state, meta = restored
            pipeline.load_state({"step": meta.get("data_step", step),
                                 "seed": pipeline.cfg.seed})
            self.log.append(f"resumed from step {step}")

        it = iter(pipeline)
        while step < total_steps:
            try:
                batch = next(it)
                state = step_fn(state, batch, step)
                step += 1
                if on_step is not None:
                    on_step(step, state)
                if step % self.cfg.ckpt_every == 0 or step == total_steps:
                    self.mgr.save(step, state,
                                  meta={"data_step": pipeline.step},
                                  blocking=not self.cfg.async_ckpt)
            except (SimulatedFailure, RuntimeError) as e:
                if isinstance(e, RuntimeError) \
                        and not isinstance(e, SimulatedFailure) \
                        and "checkpoint" in str(e):
                    raise            # checkpoint corruption is fatal
                self.restarts += 1
                self.log.append(f"failure at step {step}: {e!r}")
                if self.restarts > self.cfg.max_restarts:
                    raise RuntimeError("restart budget exhausted") from e
                self.mgr.wait()
                restored = self.mgr.restore_latest(state)
                if restored is None:
                    step = 0         # no checkpoint yet: start over
                    pipeline.load_state({"step": 0,
                                         "seed": pipeline.cfg.seed})
                else:
                    step, state, meta = restored
                    pipeline.load_state({"step": meta.get("data_step",
                                                          step),
                                         "seed": pipeline.cfg.seed})
                it = iter(pipeline)
                self.log.append(f"restarted at step {step}")
        self.mgr.wait()
        return state
