"""Elastic scaling of a serving instance group.

``ElasticGroup`` owns the tester fleet: it can spawn a new instance
(engine + agent, registered with the registry, watched by the heartbeat
monitor, added to the router) or drain one (stop admissions, migrate its
sessions out over the KV fabric, then remove it).  AutoscalePolicy
(core/policies.py) decides *when*; this module knows *how* — the
separation of concerns the paper's control plane prescribes.

The group is itself a registered *controllable* (kind ``"group"``) with
a single ``replicas`` knob, so the intent language's ``scale GROUP ±N``
action and plain ``registry.set(group, "replicas", n)`` both reach it
through the same Table-1 surface as every other knob."""
from __future__ import annotations


from repro.agents.agent import TesterAgent
from repro.core.knobs import ControlSurface, KnobSpec
from repro.core.rules import RequestRule
from repro.core.types import RequestState
from repro.serving.engine_sim import SimEngine
from repro.serving.scheduler import SchedulerConfig


class ElasticGroup(ControlSurface):
    kind = "group"
    CAPABILITIES = ("scale",)
    METRICS = ("replicas",)
    KNOB_SPECS = (
        KnobSpec("replicas", kind="int", lo=1, attr="replicas",
                 doc="target live instance count; setting it scales "
                     "up (spawn) or down (graceful drain)"),
    )

    def __init__(self, pipeline, monitor=None, name: str = "tester-group"):
        self.name = name
        self.p = pipeline
        self.loop = pipeline.loop
        self.collector = getattr(pipeline, "collector", None)
        self.monitor = monitor
        self.spawned = 0
        self.drained: list[str] = []
        self._draining: set[str] = set()
        self._publish_replicas()

    def _publish_replicas(self) -> None:
        # keep the advertised METRICS live so intent terms/triggers over
        # tester-group.replicas actually observe samples
        if self.collector is not None:
            self.collector.gauge(f"{self.name}.replicas", self.replicas,
                                 self.loop.now())

    # -- the replicas knob ----------------------------------------------------
    def _live(self) -> list[TesterAgent]:
        return [t for t in self.p.testers if t.name not in self._draining]

    @property
    def replicas(self) -> int:
        return len(self._live())

    @replicas.setter
    def replicas(self, n: int) -> None:
        n = max(1, int(n))
        while self.replicas < n:
            self.scale_up()
        while self.replicas > n:
            self.drain(self._live()[-1].name)   # newest live instance first

    # -- scale up -----------------------------------------------------------
    def scale_up(self) -> str:
        cfg = self.p.cfg
        taken = set(self.p.registry.names()) | {t.name
                                                for t in self.p.testers}
        i = 0
        while f"tester-{i}" in taken:
            i += 1
        name = f"tester-{i}"
        sched = SchedulerConfig(max_slots=cfg.tester_slots,
                                num_pages=cfg.num_pages,
                                max_context=cfg.max_context)
        eng = SimEngine(self.p.loop, self.p.costmodel, sched, name=name,
                        collector=self.p.collector)
        agent = TesterAgent(name, eng, self.p.loop,
                            directory=self.p.directory, kvx=self.p.kvx,
                            header_tokens=cfg.header_tokens,
                            on_task_done=self.p._task_done)
        self.p.testers.append(agent)
        self.p.router.add_instance(agent)
        self.p.registry.register(eng)
        if hasattr(self.p, "attach_prefix_cache"):
            self.p.attach_prefix_cache(eng)
        if self.monitor is not None:
            from repro.runtime.heartbeat import attach_engine
            attach_engine(self.monitor, eng)
        # installed agent-rules (e.g. an admit_priority_min floor) must
        # hold for the new replica too — the rule table stays the
        # source of truth across scale-ups
        self.p.controller.reapply_agent_rules()
        self.spawned += 1
        self._publish_replicas()
        return name

    def _drop_cache(self, name: str) -> None:
        """Instance gone: its cache controllable and directory residency
        records go with it."""
        self.p.registry.deregister(f"{name}.cache")
        cache_dir = getattr(self.p, "cache_dir", None)
        if cache_dir is not None:
            cache_dir.detach(name)

    # -- scale down ----------------------------------------------------------
    def drain(self, name: str) -> None:
        """Graceful: stop new sessions, migrate homed sessions away,
        remove once idle."""
        agent = next(t for t in self.p.testers if t.name == name)
        others = [t.name for t in self.p.testers
                  if t.name != name and t.name not in self._draining]
        assert others, "cannot drain the last instance"
        self._draining.add(name)
        # stop new admissions at the engine
        self.p.registry.set(name, "admit_priority_min", 99)
        # re-home sessions
        for sess, rec in list(self.p.directory.records.items()):
            if rec.instance == name:
                dst = others[len(self.drained) % len(others)]
                self.p.kvx.transfer(sess, name, dst)
                self.p.controller.rules.install(
                    RequestRule(session=sess, route_to=dst))

        def _finalize():
            if agent.engine.busy:
                self.p.loop.call_after(0.2, _finalize)
                return
            self.p.router.remove_instance(name)
            self.p.registry.deregister(name)
            self._drop_cache(name)
            if self.monitor is not None:
                self.monitor.unwatch(name)
            self.p.testers = [t for t in self.p.testers if t.name != name]
            self._draining.discard(name)
            self.drained.append(name)

        _finalize()
        self._publish_replicas()

    # -- failure path ---------------------------------------------------------
    def fail_over(self, name: str) -> int:
        """Hard failure: instance is gone.  Re-route its sessions (KV is
        lost → destination re-prefills) and re-submit its queued work."""
        agent = next((t for t in self.p.testers if t.name == name), None)
        if agent is None:
            return 0
        others = [t for t in self.p.testers if t.name != name]
        assert others, "no surviving instances"
        moved = 0
        for sess, rec in self.p.directory.records.items():
            if rec.instance == name:
                dst = others[moved % len(others)]
                rec.instance = dst.name       # KV lost; recompute on arrival
                rec.context_len = 0           # nothing left to transfer
                self.p.controller.rules.install(
                    RequestRule(session=sess, route_to=dst.name))
                moved += 1
        # re-queue in-flight requests on survivors (they re-prefill)
        sched = agent.engine.scheduler
        for req in list(sched.running) + list(sched.waiting):
            req.prefilled = 0
            req.generated = 0
            req.available = req.prompt_len
            req.state = RequestState.QUEUED
            others[moved % len(others)].engine.submit(req)
            moved += 1
        self.p.router.remove_instance(name)
        self.p.registry.deregister(name)
        self._drop_cache(name)
        if self.monitor is not None:
            self.monitor.unwatch(name)
        self.p.testers = [t for t in self.p.testers if t.name != name]
        self._publish_replicas()
        return moved
