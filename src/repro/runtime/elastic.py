"""Elastic scaling of a serving instance group.

``ElasticGroup`` owns the tester fleet: it can spawn a new instance
(engine + agent, registered with the registry, watched by the heartbeat
monitor, added to the router) or drain one (stop admissions, migrate its
sessions out over the KV fabric, then remove it).  AutoscalePolicy
(core/policies.py) decides *when*; this module knows *how* — the
separation of concerns the paper's control plane prescribes."""
from __future__ import annotations

from typing import Callable, Optional

from repro.agents.agent import TesterAgent
from repro.core.rules import RequestRule
from repro.core.types import RequestState
from repro.serving.engine_sim import SimEngine
from repro.serving.scheduler import SchedulerConfig


class ElasticGroup:
    def __init__(self, pipeline, monitor=None):
        self.p = pipeline
        self.monitor = monitor
        self.spawned = 0
        self.drained: list[str] = []

    # -- scale up -----------------------------------------------------------
    def scale_up(self) -> str:
        cfg = self.p.cfg
        taken = set(self.p.registry.names()) | {t.name
                                                for t in self.p.testers}
        i = 0
        while f"tester-{i}" in taken:
            i += 1
        name = f"tester-{i}"
        sched = SchedulerConfig(max_slots=cfg.tester_slots,
                                num_pages=cfg.num_pages,
                                max_context=cfg.max_context)
        eng = SimEngine(self.p.loop, self.p.costmodel, sched, name=name,
                        collector=self.p.collector)
        agent = TesterAgent(name, eng, self.p.loop,
                            directory=self.p.directory, kvx=self.p.kvx,
                            header_tokens=cfg.header_tokens,
                            on_task_done=self.p._task_done)
        self.p.testers.append(agent)
        self.p.router.add_instance(agent)
        self.p.registry.register(eng)
        if self.monitor is not None:
            from repro.runtime.heartbeat import attach_engine
            attach_engine(self.monitor, eng)
        self.spawned += 1
        return name

    # -- scale down ----------------------------------------------------------
    def drain(self, name: str) -> None:
        """Graceful: stop new sessions, migrate homed sessions away,
        remove once idle."""
        agent = next(t for t in self.p.testers if t.name == name)
        others = [t.name for t in self.p.testers if t.name != name]
        assert others, "cannot drain the last instance"
        # stop new admissions at the engine
        self.p.registry.set(name, "admit_priority_min", 99)
        # re-home sessions
        for sess, rec in list(self.p.directory.records.items()):
            if rec.instance == name:
                dst = others[len(self.drained) % len(others)]
                self.p.kvx.transfer(sess, name, dst)
                self.p.controller.rules.install(
                    RequestRule(session=sess, route_to=dst))

        def _finalize():
            if agent.engine.busy:
                self.p.loop.call_after(0.2, _finalize)
                return
            self.p.router.remove_instance(name)
            self.p.registry.deregister(name)
            if self.monitor is not None:
                self.monitor.unwatch(name)
            self.drained.append(name)

        _finalize()

    # -- failure path ---------------------------------------------------------
    def fail_over(self, name: str) -> int:
        """Hard failure: instance is gone.  Re-route its sessions (KV is
        lost → destination re-prefills) and re-submit its queued work."""
        agent = next((t for t in self.p.testers if t.name == name), None)
        if agent is None:
            return 0
        others = [t for t in self.p.testers if t.name != name]
        assert others, "no surviving instances"
        moved = 0
        for sess, rec in self.p.directory.records.items():
            if rec.instance == name:
                dst = others[moved % len(others)]
                rec.instance = dst.name       # KV lost; recompute on arrival
                rec.context_len = 0           # nothing left to transfer
                self.p.controller.rules.install(
                    RequestRule(session=sess, route_to=dst.name))
                moved += 1
        # re-queue in-flight requests on survivors (they re-prefill)
        sched = agent.engine.scheduler
        for req in list(sched.running) + list(sched.waiting):
            req.prefilled = 0
            req.generated = 0
            req.available = req.prompt_len
            req.state = RequestState.QUEUED
            others[moved % len(others)].engine.submit(req)
            moved += 1
        self.p.router.remove_instance(name)
        self.p.registry.deregister(name)
        if self.monitor is not None:
            self.monitor.unwatch(name)
        self.p.testers = [t for t in self.p.testers if t.name != name]
        return moved
