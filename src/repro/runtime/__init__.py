from repro.runtime.heartbeat import HeartbeatMonitor
from repro.runtime.straggler import StragglerPolicy
from repro.runtime.elastic import ElasticGroup
from repro.runtime.supervisor import TrainSupervisor, SimulatedFailure

__all__ = ["ElasticGroup", "HeartbeatMonitor", "SimulatedFailure",
           "StragglerPolicy", "TrainSupervisor"]
