"""Failure detection: heartbeats from every serving instance.

Engines beat on every step (and on an idle timer); the monitor marks an
instance failed after ``miss_timeout`` of silence and notifies the
controller (a push event — failures can't wait for the next poll).  The
controller's FailoverPolicy then re-routes the failed instance's
sessions and re-queues its in-flight requests elsewhere; KV state that
lived only on the failed instance is lost, so the re-queued requests
re-prefill (correct, just slower — exactly what a real pod failure
costs)."""
from __future__ import annotations

from typing import Callable, Optional

from repro.sim.clock import EventLoop


class HeartbeatMonitor:
    def __init__(self, loop: EventLoop, miss_timeout: float = 1.0,
                 check_interval: float = 0.25):
        self.loop = loop
        self.miss_timeout = miss_timeout
        self.check_interval = check_interval
        self.last_beat: dict[str, float] = {}
        self.failed: set[str] = set()
        self.on_failure: Optional[Callable[[str], None]] = None
        self.on_recovery: Optional[Callable[[str], None]] = None
        self._running = False

    def beat(self, name: str) -> None:
        self.last_beat[name] = self.loop.now()
        if name in self.failed:
            self.failed.discard(name)
            if self.on_recovery:
                self.on_recovery(name)

    def watch(self, name: str) -> None:
        self.last_beat.setdefault(name, self.loop.now())

    def unwatch(self, name: str) -> None:
        self.last_beat.pop(name, None)
        self.failed.discard(name)

    def start(self) -> None:
        if not self._running:
            self._running = True
            self.loop.call_after(self.check_interval, self._check)

    def stop(self) -> None:
        self._running = False

    def _check(self) -> None:
        if not self._running:
            return
        now = self.loop.now()
        for name, t in list(self.last_beat.items()):
            if name not in self.failed and now - t > self.miss_timeout:
                self.failed.add(name)
                if self.on_failure:
                    self.on_failure(name)
        self.loop.call_after(self.check_interval, self._check)


def attach_engine(monitor: HeartbeatMonitor, engine,
                  idle_ping: float = 0.5) -> None:
    """Wrap an engine's step bookkeeping to emit heartbeats, plus an
    idle-time liveness ping (an idle instance is healthy, a crashed one
    is not — ``engine.dead`` models the crash in tests/drills)."""
    monitor.watch(engine.name)
    orig = engine._step_metrics

    def beat_and_record(duration: float) -> None:
        monitor.beat(engine.name)
        orig(duration)

    engine._step_metrics = beat_and_record

    def ping():
        if engine.name not in monitor.last_beat:
            return                      # unwatched: stop pinging
        if not getattr(engine, "dead", False):
            monitor.beat(engine.name)
        monitor.loop.call_after(idle_ping, ping)

    monitor.loop.call_after(idle_ping, ping)
