"""Straggler mitigation for serving instances.

A straggling (not dead — just slow: thermal throttle, noisy neighbor,
background compaction) instance silently inflates tail latency.  The
policy compares per-instance ``step_time`` p50s; instances slower than
``ratio`` × the fleet median get their routing weight demoted (the
controller stops sending *new* sessions there) and — if ``hedge`` is on
— queued requests at the straggler above ``hedge_queue`` are re-routed.

This is the serving-side analogue of backup-task execution in MapReduce,
expressed entirely through the paper's control surface: metrics in,
rules + ``set()`` out."""
from __future__ import annotations


from repro.core.controller import ControlContext, Policy


class StragglerPolicy(Policy):
    name = "straggler"

    def __init__(self, instances: list[str], ratio: float = 2.0,
                 window: float = 2.0, hedge: bool = True,
                 hedge_queue: int = 4):
        self.instances = instances
        self.ratio = ratio
        self.window = window
        self.hedge = hedge
        self.hedge_queue = hedge_queue
        self.demoted: set[str] = set()
        self.events: list[tuple[float, str, str]] = []

    def on_tick(self, ctx: ControlContext) -> None:
        times = {}
        for inst in self.instances:
            t = ctx.metric(f"{inst}.step_time", "p50", self.window,
                           default=float("nan"))
            if t == t:
                times[inst] = t
        if len(times) < 2:
            return
        for inst, t in times.items():
            others = sorted(v for k, v in times.items() if k != inst)
            med = others[len(others) // 2]    # median of the *other* fleet
            if t > self.ratio * med and inst not in self.demoted:
                self.demoted.add(inst)
                self.events.append((ctx.now, inst, "demote"))
                ctx.note(inst, f"straggler: step p50 {t*1e3:.1f}ms vs "
                               f"median {med*1e3:.1f}ms — demoting")
                # stop admitting background work; healthy peers absorb it
                ctx.set(inst, "admit_priority_min", 1)
                if self.hedge:
                    q = ctx.metric(f"{inst}.queue_len", "last", default=0)
                    if q > self.hedge_queue:
                        ctx.note(inst, f"hedging {int(q)} queued requests")
            elif t <= 1.2 * med and inst in self.demoted:
                self.demoted.discard(inst)
                self.events.append((ctx.now, inst, "restore"))
                ctx.reset(inst, "admit_priority_min")

    def healthy(self) -> list[str]:
        return [i for i in self.instances if i not in self.demoted]
