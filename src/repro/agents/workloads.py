"""Workload generators for the MetaGPT-style developer→tester pipeline.

``ClosedLoopClient`` — N concurrent sessions; each submits a task, waits
for completion, thinks, submits the next.  Sweeping N is the paper's
"varying load" axis (Fig 3): at low N latency dominates (streaming wins),
at high N engine efficiency dominates (batching wins).

``PhasedLoad`` — drives the client count through phases (low → high →
low) for the Fig-6 adaptive-switching experiment.

``GraphBurst`` — the workflow-plane arrival pattern: N ``GraphTask``s
submitted to a ``WorkflowPipeline`` in a (possibly staggered) burst, so
queues form and cross-stage scheduling order actually matters.

``TenantMix`` — the tenancy-plane arrival pattern: per-tenant open
(Poisson) or closed (session) loops submitting tenant-stamped
``Request``s straight at a serving pool, with a heavy-head Zipf helper
for the many-small-tenants shape real multi-tenant fleets see.
"""
from __future__ import annotations

import random
from dataclasses import dataclass

from repro.agents.graph import GraphTask
from repro.agents.pipeline import AgenticPipeline, TaskSpec
from repro.core.types import Priority, Request, SLOClass


@dataclass
class WorkloadConfig:
    n_clients: int = 4
    think_time: float = 0.5
    tasks_per_client: int = 0          # 0 = unlimited (run until t_end)
    prompt_tokens: int = 192
    n_functions: int = 6
    func_tokens: int = 48
    test_tokens: int = 40
    jitter: float = 0.25               # fractional think-time jitter
    seed: int = 0


class ClosedLoopClient:
    def __init__(self, pipeline: AgenticPipeline, session: str,
                 cfg: WorkloadConfig, rng: random.Random,
                 stop_at: float = float("inf")):
        self.p = pipeline
        self.session = session
        self.cfg = cfg
        self.rng = rng
        self.stop_at = stop_at
        self.submitted = 0
        self.completed = 0
        self.active = False
        self._timer = None               # pending think/start event

    def start(self, delay: float = 0.0) -> None:
        self.active = True
        self._timer = self.p.loop.call_after(delay, self._next)

    def stop(self) -> None:
        """Deactivate AND cancel the pending think-timer, so a stopped
        client leaves nothing on the event loop (a bare flag would let
        an in-flight timer fire one more ``_next``)."""
        self.active = False
        if self._timer is not None:
            self.p.loop.cancel(self._timer)
            self._timer = None

    def _next(self) -> None:
        self._timer = None
        if not self.active or self.p.loop.now() >= self.stop_at:
            return
        if self.cfg.tasks_per_client and self.submitted >= self.cfg.tasks_per_client:
            return
        spec = TaskSpec(session=self.session,
                        prompt_tokens=self.cfg.prompt_tokens,
                        n_functions=self.cfg.n_functions,
                        func_tokens=self.cfg.func_tokens,
                        test_tokens=self.cfg.test_tokens)
        spec.meta_client = self        # dispatch handle for _dispatch_done
        self.submitted += 1
        self.p.submit(spec)

    def _on_done(self) -> None:
        self.completed += 1
        if not self.active:
            return        # stopped with a task in flight: stay quiescent
                          # (re-arming here would leave an untracked timer
                          # that a later start() could double up with)
        think = self.cfg.think_time * (
            1 + self.rng.uniform(-self.cfg.jitter, self.cfg.jitter))
        self._timer = self.p.loop.call_after(max(think, 0.0), self._next)


def _dispatch_done(spec: TaskSpec) -> None:
    client = getattr(spec, "meta_client", None)
    if client is not None:
        client._on_done()


def launch_clients(pipeline: AgenticPipeline, cfg: WorkloadConfig,
                   stop_at: float = float("inf")) -> list[ClosedLoopClient]:
    rng = random.Random(cfg.seed)
    clients = []
    pipeline.on_task_done = _dispatch_done
    for i in range(cfg.n_clients):
        c = ClosedLoopClient(pipeline, f"sess-{i}", cfg, rng, stop_at)
        clients.append(c)
        c.start(delay=rng.uniform(0, cfg.think_time + 1e-3))
    return clients


class OpenLoopSource:
    """Poisson arrivals per session, independent of completions — the
    load does NOT self-throttle, so hot-instance queue buildup is fully
    visible (Fig 7 needs this; closed loops hide imbalance)."""

    def __init__(self, pipeline: AgenticPipeline, sessions: list[str],
                 rate_per_session: float, cfg: WorkloadConfig,
                 t_end: float, seed: int = 0):
        self.p = pipeline
        self.sessions = sessions
        self.rate = rate_per_session
        self.cfg = cfg
        self.t_end = t_end
        self.rng = random.Random(seed)
        self.submitted = 0

    def start(self) -> None:
        for s in self.sessions:
            self._schedule(s, self.rng.expovariate(self.rate))

    def _schedule(self, session: str, dt: float) -> None:
        t = self.p.loop.now() + dt
        if t >= self.t_end:
            return
        self.p.loop.call_at(t, lambda: self._fire(session))

    def _fire(self, session: str) -> None:
        spec = TaskSpec(session=session,
                        prompt_tokens=self.cfg.prompt_tokens,
                        n_functions=self.cfg.n_functions,
                        func_tokens=self.cfg.func_tokens,
                        test_tokens=self.cfg.test_tokens)
        self.submitted += 1
        self.p.submit(spec)
        self._schedule(session, self.rng.expovariate(self.rate))


class GraphBurst:
    """Open-loop burst of workflow tasks against a WorkflowPipeline."""

    def __init__(self, pipeline, n_tasks: int, prompt_tokens: int = 128,
                 stagger: float = 0.0, seed: int = 0):
        self.p = pipeline
        self.n_tasks = n_tasks
        self.prompt_tokens = prompt_tokens
        self.stagger = stagger           # mean inter-arrival gap (0 = all at t0)
        self.rng = random.Random(seed)
        self.tasks: list[GraphTask] = []

    def start(self) -> None:
        t = self.p.loop.now()
        for i in range(self.n_tasks):
            task = GraphTask(session=f"wf-sess-{i}",
                             prompt_tokens=self.prompt_tokens)
            self.tasks.append(task)
            self.p.loop.call_at(t, lambda task=task: self.p.submit(task))
            if self.stagger > 0:
                t += self.rng.expovariate(1.0 / self.stagger)


@dataclass
class TenantLoad:
    """One tenant's traffic shape inside a ``TenantMix``."""

    tenant: str
    slo_class: str = SLOClass.STANDARD.value
    mode: str = "open"               # open (Poisson) | closed (sessions)
    rate: float = 4.0                # open: requests/s (live-tunable —
                                     # rescheduling reads it each arrival)
    sessions: int = 4                # closed: concurrent sessions
    think: float = 0.25              # closed: think time between requests
    prompt: int = 256
    gen: int = 64
    priority: Priority = Priority.NORMAL


class TenantMix:
    """Multi-tenant arrival generator: each ``TenantLoad`` runs its own
    open (Poisson) or closed (think-time session) loop, submitting
    tenant-stamped ``Request``s through ``submit_fn``.  Closed loops
    re-arm from ``req.meta['on_done']`` — wire the serving pool's finish
    callback with ``TenantMix.wire_pool(pool)``.  Open-loop rates are
    read on every reschedule, so a driver can reshape a tenant's load
    mid-run (flash crowds) by assigning ``load.rate``."""

    def __init__(self, loop, submit_fn, loads: list[TenantLoad],
                 t_end: float = float("inf"), seed: int = 0):
        self.loop = loop
        self.submit_fn = submit_fn
        self.loads = loads
        self.t_end = t_end
        self.rng = random.Random(seed)
        self.requests: dict[str, list[Request]] = {
            ld.tenant: [] for ld in loads}

    # -- zipf helper ---------------------------------------------------------
    @classmethod
    def zipf(cls, loop, submit_fn, n_tenants: int, total_rate: float,
             alpha: float = 1.1, t_end: float = float("inf"), seed: int = 0,
             prompt: int = 256, gen: int = 64) -> "TenantMix":
        """Heavy-head Zipf over N open-loop tenants: tenant *i* arrives
        at a rate ∝ 1/(i+1)^alpha, normalized to ``total_rate``."""
        raw = [1.0 / (i + 1) ** alpha for i in range(n_tenants)]
        z = sum(raw)
        loads = [TenantLoad(f"t{i}", rate=total_rate * w / z,
                            prompt=prompt, gen=gen)
                 for i, w in enumerate(raw)]
        return cls(loop, submit_fn, loads, t_end=t_end, seed=seed)

    # -- drive ---------------------------------------------------------------
    RATE_PROBE = 0.25            # quiesced-loop poll for a rate restore

    def start(self) -> None:
        for ld in self.loads:
            if ld.mode == "open":
                self._schedule_open(
                    ld, (self.rng.expovariate(ld.rate) if ld.rate > 0
                         else self.RATE_PROBE))
            else:
                for _ in range(ld.sessions):
                    self._arm_closed(
                        ld, delay=self.rng.uniform(0, max(ld.think, 0.01)))

    def _make(self, ld: TenantLoad) -> Request:
        r = Request(prompt_len=ld.prompt, max_new_tokens=ld.gen,
                    priority=ld.priority, tenant=ld.tenant,
                    slo_class=ld.slo_class)
        self.requests[ld.tenant].append(r)
        return r

    def _schedule_open(self, ld: TenantLoad, dt: float) -> None:
        t = self.loop.now() + dt
        if t >= self.t_end:
            return
        self.loop.call_at(t, lambda: self._tick_open(ld))

    def _tick_open(self, ld: TenantLoad) -> None:
        if ld.rate > 0:
            self.submit_fn(self._make(ld))
            self._schedule_open(ld, self.rng.expovariate(ld.rate))
        else:
            # quiesced (rate set to 0 mid-run): keep a probe timer alive
            # so restoring the rate revives the loop
            self._schedule_open(ld, self.RATE_PROBE)

    def _arm_closed(self, ld: TenantLoad, delay: float) -> None:
        def go():
            if self.loop.now() >= self.t_end:
                return
            r = self._make(ld)
            r.meta["on_done"] = lambda: self._arm_closed(
                ld, ld.think * (1 + self.rng.uniform(-0.3, 0.3)))
            self.submit_fn(r)
        self.loop.call_after(max(delay, 0.0), go)

    @staticmethod
    def wire_pool(pool) -> None:
        """Chain the pool's finish callback to the closed loops'
        ``on_done`` re-arm hook (keeps any existing callback)."""
        prev = pool.on_finish

        def _done(req, t):
            cb = req.meta.get("on_done")
            if cb is not None:
                cb()
            if prev is not None:
                prev(req, t)
        pool.on_finish = _done


@dataclass
class Phase:
    duration: float
    n_clients: int


class PhasedLoad:
    """Fig 6: load that shifts between phases at runtime."""

    def __init__(self, pipeline: AgenticPipeline, cfg: WorkloadConfig,
                 phases: list[Phase]):
        self.p = pipeline
        self.cfg = cfg
        self.phases = phases
        self.clients: list[ClosedLoopClient] = []
        self.rng = random.Random(cfg.seed)
        self.boundaries: list[float] = []

    def start(self) -> None:
        self.p.on_task_done = _dispatch_done
        t = 0.0
        for ph in self.phases:
            self.p.loop.call_at(t, lambda n=ph.n_clients: self._set_clients(n))
            self.boundaries.append(t)
            t += ph.duration
        self.t_end = t

    def _set_clients(self, n: int) -> None:
        while len(self.clients) < n:
            i = len(self.clients)
            c = ClosedLoopClient(self.p, f"sess-{i}", self.cfg, self.rng)
            self.clients.append(c)
            c.start(delay=self.rng.uniform(0, 0.2))
        for i, c in enumerate(self.clients):
            if i < n and not c.active:
                c.active = True
                c.start(delay=self.rng.uniform(0, 0.2))
            elif i >= n:
                c.stop()
