"""Workload generators for the MetaGPT-style developer→tester pipeline.

``ClosedLoopClient`` — N concurrent sessions; each submits a task, waits
for completion, thinks, submits the next.  Sweeping N is the paper's
"varying load" axis (Fig 3): at low N latency dominates (streaming wins),
at high N engine efficiency dominates (batching wins).

``PhasedLoad`` — drives the client count through phases (low → high →
low) for the Fig-6 adaptive-switching experiment.

``GraphBurst`` — the workflow-plane arrival pattern: N ``GraphTask``s
submitted to a ``WorkflowPipeline`` in a (possibly staggered) burst, so
queues form and cross-stage scheduling order actually matters.
"""
from __future__ import annotations

import random
from dataclasses import dataclass

from repro.agents.graph import GraphTask
from repro.agents.pipeline import AgenticPipeline, TaskSpec


@dataclass
class WorkloadConfig:
    n_clients: int = 4
    think_time: float = 0.5
    tasks_per_client: int = 0          # 0 = unlimited (run until t_end)
    prompt_tokens: int = 192
    n_functions: int = 6
    func_tokens: int = 48
    test_tokens: int = 40
    jitter: float = 0.25               # fractional think-time jitter
    seed: int = 0


class ClosedLoopClient:
    def __init__(self, pipeline: AgenticPipeline, session: str,
                 cfg: WorkloadConfig, rng: random.Random,
                 stop_at: float = float("inf")):
        self.p = pipeline
        self.session = session
        self.cfg = cfg
        self.rng = rng
        self.stop_at = stop_at
        self.submitted = 0
        self.completed = 0
        self.active = False
        self._timer = None               # pending think/start event

    def start(self, delay: float = 0.0) -> None:
        self.active = True
        self._timer = self.p.loop.call_after(delay, self._next)

    def stop(self) -> None:
        """Deactivate AND cancel the pending think-timer, so a stopped
        client leaves nothing on the event loop (a bare flag would let
        an in-flight timer fire one more ``_next``)."""
        self.active = False
        if self._timer is not None:
            self.p.loop.cancel(self._timer)
            self._timer = None

    def _next(self) -> None:
        self._timer = None
        if not self.active or self.p.loop.now() >= self.stop_at:
            return
        if self.cfg.tasks_per_client and self.submitted >= self.cfg.tasks_per_client:
            return
        spec = TaskSpec(session=self.session,
                        prompt_tokens=self.cfg.prompt_tokens,
                        n_functions=self.cfg.n_functions,
                        func_tokens=self.cfg.func_tokens,
                        test_tokens=self.cfg.test_tokens)
        spec.meta_client = self        # dispatch handle for _dispatch_done
        self.submitted += 1
        self.p.submit(spec)

    def _on_done(self) -> None:
        self.completed += 1
        if not self.active:
            return        # stopped with a task in flight: stay quiescent
                          # (re-arming here would leave an untracked timer
                          # that a later start() could double up with)
        think = self.cfg.think_time * (
            1 + self.rng.uniform(-self.cfg.jitter, self.cfg.jitter))
        self._timer = self.p.loop.call_after(max(think, 0.0), self._next)


def _dispatch_done(spec: TaskSpec) -> None:
    client = getattr(spec, "meta_client", None)
    if client is not None:
        client._on_done()


def launch_clients(pipeline: AgenticPipeline, cfg: WorkloadConfig,
                   stop_at: float = float("inf")) -> list[ClosedLoopClient]:
    rng = random.Random(cfg.seed)
    clients = []
    pipeline.on_task_done = _dispatch_done
    for i in range(cfg.n_clients):
        c = ClosedLoopClient(pipeline, f"sess-{i}", cfg, rng, stop_at)
        clients.append(c)
        c.start(delay=rng.uniform(0, cfg.think_time + 1e-3))
    return clients


class OpenLoopSource:
    """Poisson arrivals per session, independent of completions — the
    load does NOT self-throttle, so hot-instance queue buildup is fully
    visible (Fig 7 needs this; closed loops hide imbalance)."""

    def __init__(self, pipeline: AgenticPipeline, sessions: list[str],
                 rate_per_session: float, cfg: WorkloadConfig,
                 t_end: float, seed: int = 0):
        self.p = pipeline
        self.sessions = sessions
        self.rate = rate_per_session
        self.cfg = cfg
        self.t_end = t_end
        self.rng = random.Random(seed)
        self.submitted = 0

    def start(self) -> None:
        for s in self.sessions:
            self._schedule(s, self.rng.expovariate(self.rate))

    def _schedule(self, session: str, dt: float) -> None:
        t = self.p.loop.now() + dt
        if t >= self.t_end:
            return
        self.p.loop.call_at(t, lambda: self._fire(session))

    def _fire(self, session: str) -> None:
        spec = TaskSpec(session=session,
                        prompt_tokens=self.cfg.prompt_tokens,
                        n_functions=self.cfg.n_functions,
                        func_tokens=self.cfg.func_tokens,
                        test_tokens=self.cfg.test_tokens)
        self.submitted += 1
        self.p.submit(spec)
        self._schedule(session, self.rng.expovariate(self.rate))


class GraphBurst:
    """Open-loop burst of workflow tasks against a WorkflowPipeline."""

    def __init__(self, pipeline, n_tasks: int, prompt_tokens: int = 128,
                 stagger: float = 0.0, seed: int = 0):
        self.p = pipeline
        self.n_tasks = n_tasks
        self.prompt_tokens = prompt_tokens
        self.stagger = stagger           # mean inter-arrival gap (0 = all at t0)
        self.rng = random.Random(seed)
        self.tasks: list[GraphTask] = []

    def start(self) -> None:
        t = self.p.loop.now()
        for i in range(self.n_tasks):
            task = GraphTask(session=f"wf-sess-{i}",
                             prompt_tokens=self.prompt_tokens)
            self.tasks.append(task)
            self.p.loop.call_at(t, lambda task=task: self.p.submit(task))
            if self.stagger > 0:
                t += self.rng.expovariate(1.0 / self.stagger)


@dataclass
class Phase:
    duration: float
    n_clients: int


class PhasedLoad:
    """Fig 6: load that shifts between phases at runtime."""

    def __init__(self, pipeline: AgenticPipeline, cfg: WorkloadConfig,
                 phases: list[Phase]):
        self.p = pipeline
        self.cfg = cfg
        self.phases = phases
        self.clients: list[ClosedLoopClient] = []
        self.rng = random.Random(cfg.seed)
        self.boundaries: list[float] = []

    def start(self) -> None:
        self.p.on_task_done = _dispatch_done
        t = 0.0
        for ph in self.phases:
            self.p.loop.call_at(t, lambda n=ph.n_clients: self._set_clients(n))
            self.boundaries.append(t)
            t += ph.duration
        self.t_end = t

    def _set_clients(self, n: int) -> None:
        while len(self.clients) < n:
            i = len(self.clients)
            c = ClosedLoopClient(self.p, f"sess-{i}", self.cfg, self.rng)
            self.clients.append(c)
            c.start(delay=self.rng.uniform(0, 0.2))
        for i, c in enumerate(self.clients):
            if i < n and not c.active:
                c.active = True
                c.start(delay=self.rng.uniform(0, 0.2))
            elif i >= n:
                c.stop()
