"""A2A-compatible protocol facade (paper §3.3 "build compatibility in").

Developers keep writing against the familiar agent-protocol surface
(agent cards, ``send_message`` / ``send_message_streaming`` — Fig 4 of
the paper); underneath, every send goes through the reconfigurable
data-plane shim, so the *controller* decides how the bytes actually move.
The streaming/batching choice in application code becomes a *preference*,
not a binding: ``send_message_streaming`` on a channel the controller has
set to BATCH will batch.

This is deliberately a thin veneer — the point of the paper is that the
protocol layer stays familiar while control moves out of the app.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core.dataplane import Channel
from repro.core.types import AgentCard, Granularity, fresh_id


@dataclass
class A2AClientConfig:
    prefer: Optional[Granularity] = None   # app's (non-binding) preference


class A2AClient:
    """Handle to a remote agent, resolved from its card."""

    def __init__(self, card: AgentCard, channel: Channel,
                 cfg: Optional[A2AClientConfig] = None):
        self.card = card
        self.channel = channel
        self.cfg = cfg or A2AClientConfig()

    @classmethod
    def from_agent_card(cls, registry, name: str, channel: Channel,
                        **kw) -> "A2AClient":
        """The Fig-4 ``get_client_from_agent_card_url`` equivalent:
        discovery via the registration plane instead of an HTTP URL."""
        return cls(registry.card(name), channel,
                   A2AClientConfig(**kw) if kw else None)

    # -- message API ---------------------------------------------------------
    def send_message(self, text_tokens: int, session: Optional[str] = None,
                     **meta) -> str:
        """One-shot message: the whole payload as a single task."""
        task_id = fresh_id("a2a")
        self.channel.begin_task(task_id, session=session, **meta)
        self.channel.push_tokens(task_id, text_tokens)
        self.channel.end_task(task_id)
        return task_id

    def send_message_streaming(self, session: Optional[str] = None,
                               **meta) -> "A2AStream":
        """Open a streaming send.  NOTE: whether tokens leave one-by-one
        is the data plane's call — the app only expresses a preference."""
        task_id = fresh_id("a2a")
        self.channel.begin_task(task_id, session=session, **meta)
        return A2AStream(self.channel, task_id)


class A2AStream:
    def __init__(self, channel: Channel, task_id: str):
        self.channel = channel
        self.task_id = task_id
        self.closed = False

    def push(self, n_tokens: int = 1) -> None:
        assert not self.closed
        self.channel.push_tokens(self.task_id, n_tokens)

    def end_unit(self) -> None:
        self.channel.end_unit(self.task_id)

    def close(self) -> None:
        if not self.closed:
            self.channel.end_task(self.task_id)
            self.closed = True
