from repro.agents.agent import DeveloperAgent, TesterAgent, ToolAgent
from repro.agents.pipeline import AgenticPipeline, PipelineConfig, TaskSpec
from repro.agents.workloads import ClosedLoopClient, WorkloadConfig

__all__ = [
    "AgenticPipeline", "ClosedLoopClient", "DeveloperAgent", "PipelineConfig",
    "TaskSpec", "TesterAgent", "ToolAgent", "WorkloadConfig",
]
