from repro.agents.agent import DeveloperAgent, TesterAgent, ToolAgent
from repro.agents.graph import (GraphTask, WorkflowGraph, debate,
                                deep_review, fig1, map_reduce)
from repro.agents.pipeline import (AgenticPipeline, PipelineConfig, TaskSpec,
                                   TierSpec, WorkflowConfig, WorkflowPipeline)
from repro.agents.stage import StageAgent, StageKind, StageSpec
from repro.agents.workloads import (ClosedLoopClient, GraphBurst, TenantLoad,
                                    TenantMix, WorkloadConfig)

__all__ = [
    "AgenticPipeline", "ClosedLoopClient", "DeveloperAgent", "GraphBurst",
    "GraphTask", "PipelineConfig", "StageAgent", "StageKind", "StageSpec",
    "TaskSpec", "TenantLoad", "TenantMix", "TesterAgent", "TierSpec",
    "ToolAgent", "WorkflowConfig", "WorkflowGraph", "WorkflowPipeline",
    "WorkloadConfig", "debate", "deep_review", "fig1", "map_reduce",
]
