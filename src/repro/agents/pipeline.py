"""Pipeline assembly: one call builds the paper's Fig-1 topology —

    clients → developer(engine) → channel(shim) → router → tester[i](engine)

with the metrics plane attached to every component, everything registered
with the controller, and the KV-transfer fabric wired between tester
instances.  All benchmarks and the serving examples build through here.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.agents.agent import DeveloperAgent, TesterAgent
from repro.configs import get_config
from repro.core.controller import Controller
from repro.core.dataplane import Channel
from repro.core.metrics import CentralPoller, Collector, MetricBus, StateStore
from repro.core.registry import Registry
from repro.core.types import Granularity, Priority, fresh_id
from repro.serving.engine_sim import SimEngine
from repro.serving.kv_transfer import KVTransferManager, SessionDirectory
from repro.serving.prefix_cache import CacheDirectory, PrefixCache
from repro.serving.router import Router
from repro.serving.scheduler import SchedulerConfig
from repro.sim.clock import EventLoop
from repro.sim.costmodel import CostModel
from repro.sim.network import Link


@dataclass
class TaskSpec:
    """One MetaGPT-style task: write n functions, each gets tests."""

    session: str
    prompt_tokens: int = 192
    system_tokens: int = 128            # shared system preamble (cacheable)
    n_functions: int = 6
    func_tokens: int = 48
    test_tokens: int = 40
    priority: Priority = Priority.NORMAL
    speculative: bool = False
    task_id: str = field(default_factory=lambda: fresh_id("task"))
    submitted_at: float = 0.0
    finished_at: float = 0.0


@dataclass
class PipelineConfig:
    model: str = "agent-7b"             # cost-model architecture
    n_testers: int = 1
    dev_chips: int = 4                  # developer engine TP degree
    tester_chips: int = 4               # per-tester-instance TP degree
    granularity: Granularity = Granularity.PIPELINE
    stream_chunk: int = 4
    header_tokens: int = 64
    dev_slots: int = 32                 # developer engine batch capacity
    tester_slots: int = 12              # tester engine batch capacity
    num_pages: int = 4096
    max_context: int = 8192
    msg_bandwidth: float = 1.25e9       # 10 GbE-class agent links
    msg_proc_time: float = 1.0e-3      # per-message protocol/serde cost
    kv_bandwidth: float = 12.5e9        # 100 Gb interconnect for KV
    controller_interval: float = 0.05
    router_policy: str = "static"       # static | least_loaded | cache_aware
    # prefix-cache plane (serving/prefix_cache.py)
    prefix_cache: bool = True
    cache_block_tokens: int = 64
    cache_reserve_frac: float = 0.5
    cache_evict_policy: str = "lru"


class AgenticPipeline:
    def __init__(self, cfg: PipelineConfig, loop: Optional[EventLoop] = None):
        self.cfg = cfg
        self.loop = loop or EventLoop()
        self.bus = MetricBus()
        self.collector = Collector("pipeline", bus=self.bus)
        self.store = StateStore()
        self.poller = CentralPoller(self.store)
        self.poller.attach(self.collector)
        self.registry = Registry()
        self.controller = Controller(self.loop, self.registry, self.poller,
                                     interval=cfg.controller_interval,
                                     bus=self.bus)

        model_cfg = get_config(cfg.model)
        self.costmodel = CostModel(model_cfg, chips=cfg.tester_chips)
        self.dev_costmodel = CostModel(model_cfg, chips=cfg.dev_chips)
        # page granularity bounds the effective prefix-cache block size
        # from below: keep it <= header_tokens so the shared system
        # header fills whole blocks and is actually reusable at defaults
        page = min(cfg.cache_block_tokens, max(cfg.header_tokens, 1))
        sched = lambda slots: SchedulerConfig(
            max_slots=slots, num_pages=cfg.num_pages,
            max_context=cfg.max_context, page_size=page)

        # --- KV fabric + session directory --------------------------------
        self.directory = SessionDirectory()
        # session KV is bounded by the engine's context window
        kv_bytes = lambda ctx_len: self.costmodel.kv_transfer_bytes(
            min(ctx_len, cfg.max_context))
        self.kvx = KVTransferManager(
            self.loop, self.directory, bytes_fn=kv_bytes,
            bandwidth=cfg.kv_bandwidth, collector=self.collector)

        # --- prefix-cache plane: per-instance caches + the controller-
        # visible residency directory the cache-aware router reads
        self.cache_dir = CacheDirectory()

        # --- tester instances behind the router -----------------------------
        self.router = Router(self.loop, "tester-router",
                             policy=cfg.router_policy,
                             collector=self.collector,
                             cache_dir=self.cache_dir,
                             prefix_fn=self._msg_prefix)
        self.testers: list[TesterAgent] = []
        for i in range(cfg.n_testers):
            eng = SimEngine(self.loop, self.costmodel,
                            sched(cfg.tester_slots),
                            name=f"tester-{i}", collector=self.collector)
            t = TesterAgent(f"tester-{i}", eng, self.loop,
                            directory=self.directory, kvx=self.kvx,
                            header_tokens=cfg.header_tokens,
                            on_task_done=self._task_done)
            self.testers.append(t)
            self.router.add_instance(t)
            self.registry.register(eng)
            self.attach_prefix_cache(eng)

        # --- developer + the controllable channel ----------------------------
        dev_eng = SimEngine(self.loop, self.dev_costmodel,
                            sched(cfg.dev_slots),
                            name="developer", collector=self.collector)
        link = Link(self.loop, bandwidth=cfg.msg_bandwidth,
                    proc_time=cfg.msg_proc_time, name="dev-link")
        self.channel = Channel(self.loop, link, "developer", self.router,
                               name="dev->tester", collector=self.collector,
                               granularity=cfg.granularity,
                               stream_chunk=cfg.stream_chunk)
        self.developer = DeveloperAgent("developer", dev_eng, self.loop,
                                        self.channel,
                                        controller=self.controller)
        self.registry.register(dev_eng)
        self.attach_prefix_cache(dev_eng)
        self.registry.register(self.channel)
        self.registry.register(self.router)
        self.router.rules = self.controller.rules
        self.controller.attach_transfer(
            lambda sess, src, dst, proactive: self.kvx.transfer(
                sess, src, dst, proactive=proactive))

        # --- elastic tester group: a "group" controllable so intent v2's
        # ``scale tester-group ±N`` reaches the fleet through the same
        # Table-1 surface as every other knob (import is deferred —
        # runtime/elastic imports agents/agent)
        from repro.runtime.elastic import ElasticGroup
        self.elastic = ElasticGroup(self, name="tester-group")
        self.registry.register(self.elastic)

        # --- bookkeeping -------------------------------------------------------
        self._inflight: dict[str, TaskSpec] = {}
        self.done: list[TaskSpec] = []
        self.on_task_done = None
        self.collector.describe(
            "pipeline.task_latency",
            "End-to-end pipeline task latency in seconds; lower is better.")

    # -- prefix-cache wiring ------------------------------------------------------
    def attach_prefix_cache(self, eng):
        """Give an engine its prefix cache (over the engine's own page
        pool), registered as a `<engine>.cache` controllable and visible
        in the shared CacheDirectory.  No-op when the plane is off."""
        cfg = self.cfg
        if not cfg.prefix_cache:
            return None
        # same clamp as the scheduler page size: blocks no larger than
        # the shared header, or the header could never fill one
        block = min(cfg.cache_block_tokens, max(cfg.header_tokens, 1))
        cache = PrefixCache(
            eng.scheduler.alloc, name=f"{eng.name}.cache",
            instance=eng.name, block_tokens=block,
            evict_policy=cfg.cache_evict_policy,
            reserve_frac=cfg.cache_reserve_frac,
            directory=self.cache_dir, collector=self.collector,
            clock=self.loop.now)
        eng.attach_cache(cache)
        self.registry.register(cache)
        return cache

    def _msg_prefix(self, msg):
        """Prefix source the cache-aware router scores: every tester
        request for this message starts with the instance-shared system
        header (agents/agent.py builds the same identity)."""
        return (("system-prompt", self.cfg.header_tokens),)

    # -- workload entry -----------------------------------------------------------
    def submit(self, spec: TaskSpec) -> None:
        spec.submitted_at = self.loop.now()
        self._inflight[spec.task_id] = spec
        self.developer.submit_task(spec)

    def _task_done(self, st, t: float) -> None:
        spec = self._inflight.pop(st.task_id, None)
        if spec is None:
            return
        spec.finished_at = t
        self.done.append(spec)
        self.collector.observe("pipeline.task_latency",
                               t - spec.submitted_at, t)
        self.collector.counter("pipeline.tasks_done", 1, t)
        if self.on_task_done is not None:
            self.on_task_done(spec)

    # -- results ---------------------------------------------------------------------
    def run(self, until: float) -> None:
        self.controller.start()
        self.loop.run_until(until)

    def throughput(self, t0: float = 0.0, t1: Optional[float] = None) -> float:
        t1 = t1 if t1 is not None else self.loop.now()
        n = sum(1 for s in self.done if t0 <= s.finished_at <= t1)
        return n / max(t1 - t0, 1e-9)

    def latencies(self) -> list[float]:
        return [s.finished_at - s.submitted_at for s in self.done]
