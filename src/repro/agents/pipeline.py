"""Pipeline assembly — the compiler from workflow graphs to wired
serving topologies.

``AgenticPipeline.build(graph)`` is the general entry point: any
``WorkflowGraph`` (agents/graph.py) compiles into engines, channels and
routers with the metrics plane attached to every component and
everything registered with the controller.

* Graphs carrying the ``fig1`` template marker compile through the
  classic ``AgenticPipeline`` — the paper's Fig-1 topology

      clients → developer(engine) → channel(shim) → router → tester[i]

  with its DeveloperAgent/TesterAgent semantics, KV-transfer fabric,
  prefix-cache plane and elastic tester group.  All pre-graph
  ``PipelineConfig`` callers (benchmarks, examples) keep building this
  path unmodified.

* Every other graph compiles into a ``WorkflowPipeline``: a shared,
  tier-labelled engine pool behind one router (``stage_aware`` policy
  routes each stage's calls to its ``model_tier``), one ``StageAgent``
  per stage registered as a ``stage.<name>`` controllable, and one
  data-plane ``Channel`` per graph edge.  The graph is a control-plane
  object: the scheduler consumes critical-path-derived deadlines and
  longest-remaining-path boosts propagated along its edges.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

from repro.agents.agent import (DeveloperAgent, TesterAgent, ToolAgent,
                                expected_tool_latency)
from repro.agents.graph import GraphTask, WorkflowGraph, fig1
from repro.agents.stage import EngineWorker, StageAgent, StageKind
from repro.configs import get_config
from repro.core.controller import Controller
from repro.core.dataplane import Channel
from repro.core.metrics import CentralPoller, Collector, MetricBus, StateStore
from repro.core.registry import Registry
from repro.core.trace import FlightRecorder, Tracer
from repro.core.types import Granularity, Priority, RequestState, fresh_id
from repro.serving.disagg import DisaggPool
from repro.serving.engine_sim import SimEngine
from repro.serving.kv_transfer import KVTransferManager, SessionDirectory
from repro.serving.prefix_cache import CacheDirectory, PrefixCache
from repro.serving.router import Router
from repro.serving.scheduler import SchedulerConfig
from repro.sim.clock import EventLoop
from repro.sim.costmodel import costmodel_for
from repro.sim.network import Link


@dataclass
class TaskSpec:
    """One MetaGPT-style task: write n functions, each gets tests.

    ``speculative`` flows onto the outbound channel's messages (the
    shim's speculative gate holds them while gated); ``finished_at`` is
    stamped by the pipeline's single completion path (``_task_done``)
    and backs ``throughput()``/``latencies()``.
    """

    session: str
    prompt_tokens: int = 192
    system_tokens: int = 128            # shared system preamble (cacheable)
    n_functions: int = 6
    func_tokens: int = 48
    test_tokens: int = 40
    priority: Priority = Priority.NORMAL
    speculative: bool = False
    task_id: str = field(default_factory=lambda: fresh_id("task"))
    submitted_at: float = 0.0
    finished_at: float = 0.0


@dataclass
class PipelineConfig:
    model: str = "agent-7b"             # cost-model architecture
    n_testers: int = 1
    dev_chips: int = 4                  # developer engine TP degree
    tester_chips: int = 4               # per-tester-instance TP degree
    granularity: Granularity = Granularity.PIPELINE
    stream_chunk: int = 4
    header_tokens: int = 64
    dev_slots: int = 32                 # developer engine batch capacity
    tester_slots: int = 12              # tester engine batch capacity
    num_pages: int = 4096
    max_context: int = 8192
    msg_bandwidth: float = 1.25e9       # 10 GbE-class agent links
    msg_proc_time: float = 1.0e-3      # per-message protocol/serde cost
    kv_bandwidth: float = 12.5e9        # 100 Gb interconnect for KV
    controller_interval: float = 0.05
    router_policy: str = "static"       # static | least_loaded | cache_aware
    # prefix-cache plane (serving/prefix_cache.py)
    prefix_cache: bool = True
    cache_block_tokens: int = 64
    cache_reserve_frac: float = 0.5
    cache_evict_policy: str = "lru"
    # measured-calibration artifacts (CALIB_*.json) for the sim engines'
    # cost models; None = REPRO_CALIB_DIR env / artifacts/bench default,
    # with analytic-roofline fallback when no artifact exists
    calib_dir: Optional[str] = None


class ServingFabric:
    """Shared control/metrics fabric every compiled topology stands on:
    metric bus + collector + central poller + registry + controller,
    plus the task-completion bookkeeping (``done``/``latencies``/
    ``throughput``) both pipeline flavors report through."""

    def __init__(self, loop: Optional[EventLoop] = None,
                 interval: float = 0.05):
        self.loop = loop or EventLoop()
        self.bus = MetricBus()
        self.collector = Collector("pipeline", bus=self.bus)
        self.store = StateStore()
        self.poller = CentralPoller(self.store)
        self.poller.attach(self.collector)
        self.registry = Registry()
        self.controller = Controller(self.loop, self.registry, self.poller,
                                     interval=interval, bus=self.bus,
                                     collector=self.collector)
        # tracing plane: off by default (the `trace` intent verb or a
        # direct knob write turns sampling on at runtime); the flight
        # recorder always captures the controller's audit actions so a
        # later-enabled trace can still show what the control plane did
        self.tracer = Tracer(self.loop.now, collector=self.collector)
        self.registry.register(self.tracer)
        self.recorder = FlightRecorder(self.loop.now, bus=self.bus)
        self.controller.attach_recorder(self.recorder)
        self.done: list = []
        self.on_task_done = None

    def run(self, until: float) -> None:
        self.controller.start()
        self.loop.run_until(until)

    def throughput(self, t0: float = 0.0, t1: Optional[float] = None) -> float:
        t1 = t1 if t1 is not None else self.loop.now()
        n = sum(1 for s in self.done if t0 <= s.finished_at <= t1)
        return n / max(t1 - t0, 1e-9)

    def latencies(self) -> list[float]:
        return [s.finished_at - s.submitted_at for s in self.done]


class AgenticPipeline(ServingFabric):
    """The classic Fig-1 pipeline (see module docstring)."""

    def __init__(self, cfg: PipelineConfig, loop: Optional[EventLoop] = None,
                 graph: Optional[WorkflowGraph] = None):
        self.cfg = cfg
        super().__init__(loop, interval=cfg.controller_interval)
        # the fig1 topology as a graph: the same control-plane object
        # generic workflows get, so policies can read stage structure
        # (build() threads a caller-customized fig1 graph through here)
        self.graph = graph if graph is not None else fig1()
        self.controller.attach_graph(self.graph)

        model_cfg = get_config(cfg.model)
        self.costmodel = costmodel_for(model_cfg, chips=cfg.tester_chips,
                                       calib_dir=cfg.calib_dir)
        self.dev_costmodel = costmodel_for(model_cfg, chips=cfg.dev_chips,
                                           calib_dir=cfg.calib_dir)
        # page granularity bounds the effective prefix-cache block size
        # from below: keep it <= header_tokens so the shared system
        # header fills whole blocks and is actually reusable at defaults
        page = min(cfg.cache_block_tokens, max(cfg.header_tokens, 1))
        sched = lambda slots: SchedulerConfig(
            max_slots=slots, num_pages=cfg.num_pages,
            max_context=cfg.max_context, page_size=page)

        # --- KV fabric + session directory --------------------------------
        self.directory = SessionDirectory()
        # session KV is bounded by the engine's context window
        kv_bytes = lambda ctx_len: self.costmodel.kv_transfer_bytes(
            min(ctx_len, cfg.max_context))
        self.kvx = KVTransferManager(
            self.loop, self.directory, bytes_fn=kv_bytes,
            bandwidth=cfg.kv_bandwidth, collector=self.collector)

        # --- prefix-cache plane: per-instance caches + the controller-
        # visible residency directory the cache-aware router reads
        self.cache_dir = CacheDirectory()

        # --- tester instances behind the router -----------------------------
        self.router = Router(self.loop, "tester-router",
                             policy=cfg.router_policy,
                             collector=self.collector,
                             cache_dir=self.cache_dir,
                             prefix_fn=self._msg_prefix)
        self.testers: list[TesterAgent] = []
        for i in range(cfg.n_testers):
            eng = SimEngine(self.loop, self.costmodel,
                            sched(cfg.tester_slots),
                            name=f"tester-{i}", collector=self.collector)
            eng.tracer = self.tracer
            t = TesterAgent(f"tester-{i}", eng, self.loop,
                            directory=self.directory, kvx=self.kvx,
                            header_tokens=cfg.header_tokens,
                            on_task_done=self._task_done)
            self.testers.append(t)
            self.router.add_instance(t)
            self.registry.register(eng)
            self.attach_prefix_cache(eng)

        # --- developer + the controllable channel ----------------------------
        dev_eng = SimEngine(self.loop, self.dev_costmodel,
                            sched(cfg.dev_slots),
                            name="developer", collector=self.collector)
        dev_eng.tracer = self.tracer
        self.router.tracer = self.tracer
        self.kvx.tracer = self.tracer
        link = Link(self.loop, bandwidth=cfg.msg_bandwidth,
                    proc_time=cfg.msg_proc_time, name="dev-link")
        self.channel = Channel(self.loop, link, "developer", self.router,
                               name="dev->tester", collector=self.collector,
                               granularity=cfg.granularity,
                               stream_chunk=cfg.stream_chunk)
        self.developer = DeveloperAgent("developer", dev_eng, self.loop,
                                        self.channel,
                                        controller=self.controller)
        self.registry.register(dev_eng)
        self.attach_prefix_cache(dev_eng)
        self.registry.register(self.channel)
        self.registry.register(self.router)
        self.router.rules = self.controller.rules
        self.controller.attach_transfer(
            lambda sess, src, dst, proactive: self.kvx.transfer(
                sess, src, dst, proactive=proactive))

        # --- elastic tester group: a "group" controllable so intent v2's
        # ``scale tester-group ±N`` reaches the fleet through the same
        # Table-1 surface as every other knob (import is deferred —
        # runtime/elastic imports agents/agent)
        from repro.runtime.elastic import ElasticGroup
        self.elastic = ElasticGroup(self, name="tester-group")
        self.registry.register(self.elastic)

        # --- bookkeeping -------------------------------------------------------
        self._inflight: dict[str, TaskSpec] = {}
        self.collector.describe(
            "pipeline.task_latency",
            "End-to-end pipeline task latency in seconds; lower is better.")

    # -- graph entry point --------------------------------------------------
    @classmethod
    def build(cls, graph: WorkflowGraph, cfg=None,
              loop: Optional[EventLoop] = None):
        """Compile a workflow graph into a wired serving topology.

        ``fig1``-template graphs build the classic pipeline (pass a
        ``PipelineConfig``); everything else builds a
        ``WorkflowPipeline`` (pass a ``WorkflowConfig``)."""
        graph.validate()
        if graph.template == "fig1":
            if cfg is not None and not isinstance(cfg, PipelineConfig):
                raise TypeError("fig1 graphs take a PipelineConfig")
            return cls(cfg or PipelineConfig(), loop, graph=graph)
        if cfg is not None and not isinstance(cfg, WorkflowConfig):
            raise TypeError(f"graph {graph.name!r} takes a WorkflowConfig")
        return WorkflowPipeline(graph, cfg, loop)

    # -- prefix-cache wiring ------------------------------------------------------
    def attach_prefix_cache(self, eng):
        """Give an engine its prefix cache (over the engine's own page
        pool), registered as a `<engine>.cache` controllable and visible
        in the shared CacheDirectory.  No-op when the plane is off."""
        cfg = self.cfg
        if not cfg.prefix_cache:
            return None
        # same clamp as the scheduler page size: blocks no larger than
        # the shared header, or the header could never fill one
        block = min(cfg.cache_block_tokens, max(cfg.header_tokens, 1))
        cache = PrefixCache(
            eng.scheduler.alloc, name=f"{eng.name}.cache",
            instance=eng.name, block_tokens=block,
            evict_policy=cfg.cache_evict_policy,
            reserve_frac=cfg.cache_reserve_frac,
            directory=self.cache_dir, collector=self.collector,
            clock=self.loop.now)
        eng.attach_cache(cache)
        self.registry.register(cache)
        return cache

    def _msg_prefix(self, msg):
        """Prefix source the cache-aware router scores: every tester
        request for this message starts with the instance-shared system
        header (agents/agent.py builds the same identity)."""
        return (("system-prompt", self.cfg.header_tokens),)

    # -- workload entry -----------------------------------------------------------
    def submit(self, spec: TaskSpec) -> None:
        spec.submitted_at = self.loop.now()
        self._inflight[spec.task_id] = spec
        self.tracer.begin_task(spec.task_id, t=spec.submitted_at,
                               session=spec.session)
        self.developer.submit_task(spec)

    def _task_done(self, st, t: float) -> None:
        spec = self._inflight.pop(st.task_id, None)
        if spec is None:
            return
        spec.finished_at = t
        self.tracer.end_task(spec.task_id, t)
        self.done.append(spec)
        self.collector.observe("pipeline.task_latency",
                               t - spec.submitted_at, t)
        self.collector.counter("pipeline.tasks_done", 1, t)
        if self.on_task_done is not None:
            self.on_task_done(spec)


# ---------------------------------------------------------------------------
# Generic workflow pipeline
# ---------------------------------------------------------------------------


@dataclass
class TierSpec:
    """One model-size tier of the shared engine pool."""

    model: str                           # configs/ architecture name
    chips: int = 4                       # TP degree per instance
    replicas: int = 2                    # instances of this tier
    slots: int = 16                      # continuous-batching slots
    # disaggregation plane: per-replica engine roles, cycled over the
    # replicas (e.g. ("prefill", "decode", "decode")).  Any non-unified
    # role makes the tier a role-typed pool: a DisaggPool wires the
    # prefill→decode handoff fabric over the tier's engines, and the
    # controller can re-partition it at runtime through the role knob.
    roles: tuple = ()


@dataclass
class WorkflowConfig:
    """Compilation parameters for non-fig1 graphs."""

    tiers: dict[str, TierSpec] = field(default_factory=lambda: {
        "large": TierSpec("agent-7b", chips=4, replicas=2, slots=16),
        "small": TierSpec("agent-1b", chips=1, replicas=2, slots=16),
    })
    router_policy: str = "stage_aware"   # static | least_loaded | stage_aware
    critical_path: bool = True           # stamp deadlines + admission boost
    deadline_slack: float = 2.0          # deadline = slack x cp estimate
    est_prompt_tokens: int = 128         # nominal task prompt for cp math
    granularity: Granularity = Granularity.PIPELINE
    stream_chunk: int = 8
    num_pages: int = 4096
    max_context: int = 8192
    page_size: int = 64
    # tool-call plane: "hold" suspends the live sequence across a TOOL
    # stage (the post-tool turn resumes its KV); "reissue" is the legacy
    # complete-and-reissue flow (every post-tool turn re-prefills)
    tool_context: str = "hold"
    host_capacity_pages: int = 4096      # per-engine host KV tier
    msg_bandwidth: float = 1.25e9
    msg_proc_time: float = 1.0e-3
    controller_interval: float = 0.05
    kv_bandwidth: float = 12.5e9         # disagg handoff interconnect
    adaptive_roles: bool = False         # install a RoleBalancerPolicy
                                         # per role-typed tier
    calib_dir: Optional[str] = None      # CALIB_*.json dir for tier
                                         # cost models (None = env/default)


class WorkflowPipeline(ServingFabric):
    """A compiled workflow graph: shared tier-labelled engine pool
    behind one router, a StageAgent per stage, a Channel per edge."""

    def __init__(self, graph: WorkflowGraph,
                 cfg: Optional[WorkflowConfig] = None,
                 loop: Optional[EventLoop] = None):
        cfg = cfg or WorkflowConfig()
        self.cfg = cfg
        super().__init__(loop, interval=cfg.controller_interval)
        self.graph = graph.validate()

        # --- shared engine pool, one router over every tier ----------------
        self.costmodels = {
            tier: costmodel_for(get_config(ts.model), chips=ts.chips,
                                calib_dir=cfg.calib_dir)
            for tier, ts in cfg.tiers.items()}
        self.router = Router(self.loop, "workflow-router",
                             policy=cfg.router_policy,
                             collector=self.collector)
        self.workers: list[EngineWorker] = []
        tier_engines: dict[str, list[SimEngine]] = {}
        for tier, ts in cfg.tiers.items():
            for i in range(ts.replicas):
                role = ts.roles[i % len(ts.roles)] if ts.roles else "unified"
                eng = SimEngine(
                    self.loop, self.costmodels[tier],
                    SchedulerConfig(max_slots=ts.slots,
                                    num_pages=cfg.num_pages,
                                    max_context=cfg.max_context,
                                    page_size=cfg.page_size,
                                    host_capacity_pages=(
                                        cfg.host_capacity_pages),
                                    role=role),
                    name=f"wf-{tier}-{i}", collector=self.collector)
                eng.tracer = self.tracer
                w = EngineWorker(eng, tier)
                self.workers.append(w)
                self.router.add_instance(w, tier=tier, engine=eng)
                self.registry.register(eng)
                tier_engines.setdefault(tier, []).append(eng)
        self.registry.register(self.router)
        self.router.rules = self.controller.rules
        self.router.tracer = self.tracer

        # --- role-typed pools: tiers whose replicas carry prefill/decode
        # roles get a DisaggPool (prefill→decode handoff fabric over the
        # tier's engines); the role knob stays live, so the controller —
        # or a RoleBalancerPolicy, when cfg.adaptive_roles — can
        # re-partition each tier from queue pressure at runtime
        self.disagg_pools: dict[str, DisaggPool] = {}
        for tier, ts in cfg.tiers.items():
            if not ts.roles or set(ts.roles) == {"unified"}:
                continue
            directory = SessionDirectory()
            kvx = KVTransferManager(
                self.loop, directory,
                bytes_fn=self.costmodels[tier].kv_transfer_bytes,
                bandwidth=cfg.kv_bandwidth, collector=self.collector,
                name=f"{tier}-kvx")
            pool = DisaggPool(self.loop, tier_engines[tier], kvx,
                              collector=self.collector,
                              name=f"{tier}-disagg",
                              cluster_prefix=f"cluster.{tier}",
                              tracer=self.tracer)
            self.disagg_pools[tier] = pool
            if cfg.adaptive_roles:
                from repro.core.policies import RoleBalancerPolicy
                self.controller.install(RoleBalancerPolicy(
                    [e.name for e in tier_engines[tier]],
                    prefix=f"cluster.{tier}"))

        # --- one StageAgent per stage, registered as stage.<name> ----------
        self.stages: dict[str, StageAgent] = {}
        for name, spec in graph.stages.items():
            ag = StageAgent(spec, self.loop, self, collector=self.collector)
            if spec.kind is StageKind.TOOL:
                ag.tool = ToolAgent(f"{name}.tool", self.loop,
                                    latency=spec.tool_latency,
                                    latency_cv=spec.tool_latency_cv,
                                    timeout=spec.tool_timeout,
                                    collector=self.collector)
                self.registry.register(ag.tool)
            self.stages[name] = ag
            self.registry.register(ag)

        # --- tool-call suspend/resume plane: which stage feeds which
        # TOOL stage (its calls hold their sequence open), and which
        # engine belongs to which tier (cross-engine resume placement)
        self._feeds_tool: dict[str, str] = {}
        for (u, v) in graph.edges:
            if graph.stages[v].kind is StageKind.TOOL:
                self._feeds_tool[u] = v
        self._engine_tier = {w.name: w.tier for w in self.workers}

        # --- one data-plane channel per graph edge -------------------------
        self.channels: dict[tuple[str, str], Channel] = {}
        for (u, v) in graph.edges:
            link = Link(self.loop, bandwidth=cfg.msg_bandwidth,
                        proc_time=cfg.msg_proc_time, name=f"{u}->{v}.link")
            ch = Channel(self.loop, link, u, self.stages[v],
                         name=f"{u}->{v}", collector=self.collector,
                         granularity=cfg.granularity,
                         stream_chunk=cfg.stream_chunk)
            self.channels[(u, v)] = ch
            self.registry.register(ch)
            self.stages[u].succs.append((v, ch))
        for name, ag in self.stages.items():
            ag.n_preds = len(graph.preds(name))

        self.controller.attach_graph(graph)
        self._pending: dict[str, int] = {}    # task -> activation refcount
        self._inflight: dict[str, GraphTask] = {}
        self._cp: dict[str, float] = {}
        self._cp_total = 0.0
        self._recompute_cp()
        self.collector.describe(
            "workflow.task_latency",
            "End-to-end workflow task latency in seconds; lower is better.")

    # -- critical path ------------------------------------------------------
    def tier_names(self) -> tuple[str, ...]:
        return tuple(self.cfg.tiers)

    def _stage_cost(self, spec, est_in: float) -> float:
        ag = self.stages.get(spec.name)
        tier = ag.model_tier if ag is not None else spec.model_tier
        if spec.kind is StageKind.TOOL:
            # the *expected* dwell under the heavy-tailed latency model,
            # not the median — tool-bound paths are systematically
            # longer than their nominal latency suggests
            return expected_tool_latency(spec.tool_latency,
                                         spec.tool_latency_cv,
                                         spec.tool_timeout)
        cm = self.costmodels.get(tier)
        ts = self.cfg.tiers.get(tier)
        if cm is None:                    # tier not in this pool: calls
            first = next(iter(self.cfg.tiers))   # fall back to the
            cm, ts = self.costmodels[first], self.cfg.tiers[first]  # default
        if spec.kind is StageKind.FAN_OUT:
            width = ag.width if ag is not None else spec.width
            serial = math.ceil(width / max(ts.replicas, 1))
            return serial * cm.call_time(
                spec.prompt_tokens + int(est_in // max(width, 1)),
                spec.out_tokens)
        return cm.call_time(spec.prompt_tokens + int(est_in),
                            spec.out_tokens)

    def _recompute_cp(self) -> None:
        self._cp = self.graph.critical_path(
            self._stage_cost, prompt_tokens=self.cfg.est_prompt_tokens)
        self._cp_total = self.graph.cp_total(self._cp)
        # per-stage deadline anchors, cached: dispatch is the hot path
        # and these only move when a tier/width knob does
        est_in = self.graph.est_inputs(self.cfg.est_prompt_tokens)
        self._through = {
            n: self._cp_total - max(
                self._cp[n] - self._stage_cost(spec, est_in[n]), 0.0)
            for n, spec in self.graph.stages.items()}

    def on_stage_retier(self, name: str) -> None:
        """A stage's model_tier/width knob moved: cost estimates — and
        therefore every propagated deadline — shift."""
        self._recompute_cp()

    def cp_enabled(self) -> bool:
        return self.cfg.critical_path

    def cp_remaining(self, stage: str) -> float:
        return self._cp.get(stage, 0.0)

    def cp_through(self, stage: str) -> float:
        """Critical-path work through the *end* of ``stage`` — the
        deadline anchor propagated along edges."""
        return self._through.get(stage, 0.0)

    # -- stage runtime hooks ------------------------------------------------
    def route_call(self, msg) -> None:
        self.router.deliver(msg)

    # -- tool-call suspend/resume plane --------------------------------------
    def hold_enabled(self) -> bool:
        return self.cfg.tool_context == "hold"

    def tool_hold_est(self, stage: str):
        """Expected tool dwell when ``stage`` feeds a TOOL stage — the
        price signal the engine's offload policy weighs a suspend
        against.  None when the stage feeds no tool (or the hold flow
        is off): its calls complete normally."""
        if not self.hold_enabled():
            return None
        tool = self._feeds_tool.get(stage)
        if tool is None:
            return None
        ag = self.stages[tool].tool
        return ag.mean_latency() if ag is not None else None

    def tool_fanin(self, stage: str) -> int:
        """How many input stages the TOOL fed by ``stage`` waits for.
        >1 means a held call parks while *sibling* stages still need
        slots — the configuration where a pinned hold can wedge an
        engine (debate's pro/con -> factcheck)."""
        tool = self._feeds_tool.get(stage)
        return len(self.graph.preds(tool)) if tool is not None else 0

    def engine_tier(self, req) -> str:
        eng = req.meta.get("engine")
        return self._engine_tier.get(getattr(eng, "name", ""), "")

    def resume_request(self, req) -> None:
        """Land a held-open request back on silicon after its tool
        returned: pay the host→HBM restore cost, then resume on the
        home engine — and when home is out of slots, migrate the host
        KV copy to the least-loaded same-tier peer (cache-aware
        placement: the resume runs where capacity is, not where the
        sequence happened to start)."""
        eng = req.meta.get("engine")
        if eng is None:
            return
        d = eng.restore_cost(req)
        if d > 0.0:
            self.loop.call_after(d, lambda: self._resume_land(eng, req))
        else:
            self._resume_land(eng, req)

    def _resume_land(self, eng, req) -> None:
        if eng.resume_suspended(req) != "wait":
            return
        tier = self._engine_tier.get(eng.name, "")
        peers = sorted((w.engine for w in self.workers
                        if w.tier == tier and w.engine is not eng
                        and w.engine.scheduler._free_slots),
                       key=lambda e: e.load())
        for peer in peers:
            if eng.migrate_suspended(req, peer):
                return
        # no capacity anywhere: stays on the home scheduler's
        # resume-pending list, retried ahead of fresh admissions

    def task_merge(self, task: GraphTask, arrived: int) -> None:
        """A stage dispatched after absorbing ``arrived`` input
        activations: they merge into the stage's single activation."""
        if arrived > 1:
            self._bump(task, -(arrived - 1))

    def task_advance(self, task: GraphTask, forwarded: int) -> None:
        """A stage completed: its activation ends, ``forwarded``
        successor activations begin."""
        self._bump(task, forwarded - 1)

    def task_drop(self, task: GraphTask) -> None:
        """A straggler input arrived after its join already fired."""
        self._bump(task, -1)

    def _bump(self, task: GraphTask, delta: int) -> None:
        tid = task.task_id
        if tid not in self._pending:
            return
        self._pending[tid] += delta
        if self._pending[tid] <= 0:
            del self._pending[tid]
            self._inflight.pop(tid, None)
            # a task can finish with sequences still parked (e.g. its
            # BRANCH arm never reached the post-tool stage): release them
            for r in (task.meta.pop("held", []) if task.meta else []):
                eng = r.meta.get("engine")
                if eng is not None and r.state == RequestState.SUSPENDED:
                    eng.finish_suspended(r)
            t = self.loop.now()
            task.finished_at = t
            self.tracer.end_task(tid, t)
            self.done.append(task)
            self.collector.observe("workflow.task_latency",
                                   t - task.submitted_at, t)
            self.collector.counter("workflow.tasks_done", 1, t)
            if self.on_task_done is not None:
                self.on_task_done(task)

    # -- workload entry -----------------------------------------------------
    def submit(self, task: GraphTask) -> None:
        task.submitted_at = self.loop.now()
        if self.cfg.critical_path and task.deadline == math.inf:
            task.deadline = (task.submitted_at
                             + self.cfg.deadline_slack * self._cp_total)
        self.tracer.begin_task(task.task_id, t=task.submitted_at,
                               session=task.session)
        sources = self.graph.sources()
        self._pending[task.task_id] = len(sources)
        self._inflight[task.task_id] = task
        for s in sources:
            self.stages[s].inject(task, task.prompt_tokens)
