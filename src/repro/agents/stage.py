"""Typed workflow stages + their runtime (the workflow graph plane's
data-plane half; the graph structure itself lives in agents/graph.py).

``StageSpec`` declares one stage of an agent DAG: its kind (chain,
fan-out, fan-in/join, branch, tool), how many tokens it reads/emits,
and which model-size tier its calls should run on.

``StageAgent`` executes a stage.  It is a channel endpoint (upstream
stages feed it through ordinary data-plane ``Channel``s), collects each
task's inputs (fan-in waits for all — or ``join_k`` — predecessors,
bounded by ``join_timeout``), then issues the stage's engine calls
through the pipeline's shared, tier-labelled engine pool via the
router.  Every agent registers as a ``stage.<name>`` controllable:

* knobs — ``model_tier`` (Aragog-style per-stage model choice the
  ``stage_aware`` router honors), ``deadline_slack`` (scales the
  edge-propagated deadline), ``join_timeout``, ``width``;
* gauges — ``stage.<name>.latency`` / ``.p95`` / ``.queue``, so intent
  programs can write ``on stage reviewer.p95 > 2 => set stage
  reviewer.model_tier small``.

Critical-path scheduling: each engine request is stamped with the
task's edge-propagated ``deadline`` (finish-by time for this stage) and
a ``cp_remaining`` estimate; the scheduler orders EDF-within-priority
with a longest-remaining-path tie-break, and a task that is *behind*
its critical-path schedule gets a one-level priority boost on
admission.
"""
from __future__ import annotations

import enum
import math
import zlib
from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.knobs import ControlSurface, KnobSpec
from repro.core.metrics import RollingStat
from repro.core.types import Message, Priority, Request, RequestState
from repro.sim.clock import EventLoop


class StageKind(str, enum.Enum):
    CHAIN = "chain"          # one LLM call per task
    FAN_OUT = "fan_out"      # `width` parallel LLM calls per task
    JOIN = "join"            # fan-in barrier (join_k / join_timeout)
    BRANCH = "branch"        # one call, output routed to ONE successor
    TOOL = "tool"            # non-LLM tool call (fixed latency)


@dataclass
class StageSpec:
    """Declarative description of one workflow stage."""

    name: str
    kind: StageKind = StageKind.CHAIN
    model_tier: str = "large"        # default tier for this stage's calls
    width: int = 4                   # FAN_OUT: parallel calls per task
    join_k: int = 0                  # JOIN: inputs needed (0 = all preds)
    join_timeout: float = 0.0        # JOIN: max wait for stragglers (0 = forever)
    prompt_tokens: int = 96          # stage-local instruction prompt
    out_tokens: int = 64             # tokens generated per call
    tool_latency: float = 0.05       # TOOL: median per-call latency
    tool_latency_cv: float = 0.0     # TOOL: lognormal tail (0 = fixed)
    tool_timeout: float = 0.0        # TOOL: per-attempt cap (0 = none)
    deadline_slack: float = 0.0      # 0 = inherit the pipeline default
    branch_fn: Optional[Callable[[str], int]] = None  # task_id -> succ index


@dataclass
class _StageRun:
    """Per-task state while the task is at (or queued for) this stage."""

    task: object                     # GraphTask
    tokens: int = 0                  # input tokens arrived
    inputs_done: int = 0             # predecessors that sent task_end
    dispatched: bool = False
    calls_open: int = 0
    out_tokens: int = 0
    started_at: float = 0.0
    timer: object = None             # join-timeout event
    trace_span: object = None        # open stage span (tracing plane)


class EngineWorker:
    """Router endpoint adapting one engine of the shared pool to stage
    calls: messages carry a prebuilt ``Request`` whose ``meta`` holds
    the per-call completion callbacks (engines are shared across
    stages, so per-engine ``on_finish`` must dispatch per request)."""

    def __init__(self, engine, tier: str):
        self.engine = engine
        self.tier = tier
        self.name = engine.name
        engine.on_finish = self._finish
        engine.on_token = self._token

    def deliver(self, msg: Message) -> None:
        self.engine.submit((msg.payload or {})["request"])

    def _finish(self, req: Request, t: float) -> None:
        cb = req.meta.get("on_finish")
        if cb is not None:
            cb(req, t)

    def _token(self, req: Request, tok: int, t: float) -> None:
        cb = req.meta.get("on_token")
        if cb is not None:
            cb(req, tok, t)

    def load(self) -> float:
        return self.engine.load()


class StageAgent(ControlSurface):
    """Executes one stage of a workflow graph (see module docstring)."""

    kind = "stage"
    CAPABILITIES = ("tier", "deadline")
    METRICS = ("latency", "p95", "queue")
    KNOB_SPECS = (
        KnobSpec("model_tier", kind="str", clamp="_clamp_tier",
                 on_change="_tier_changed",
                 doc="model-size tier this stage's calls route to "
                     "(stage_aware router policy)"),
        KnobSpec("deadline_slack", kind="float", lo=0.0,
                 doc="deadline = submit + slack x critical-path work "
                     "through this stage"),
        KnobSpec("join_timeout", kind="float", lo=0.0,
                 doc="fan-in: max seconds to wait for missing inputs "
                     "(0 = wait forever)"),
        KnobSpec("width", kind="int", lo=1, on_change="_width_changed",
                 doc="FAN_OUT: parallel calls per task"),
    )

    def __init__(self, spec: StageSpec, loop: EventLoop, pipeline,
                 collector=None):
        self.spec = spec
        self.name = f"stage.{spec.name}"
        self.loop = loop
        self.p = pipeline                # WorkflowPipeline
        self.collector = collector
        # knob-backed attributes (defaults from the spec / pipeline)
        self.model_tier = spec.model_tier
        self.deadline_slack = (spec.deadline_slack
                               or pipeline.cfg.deadline_slack)
        self.join_timeout = spec.join_timeout
        self.width = spec.width
        self.tool = None                 # ToolAgent, attached for TOOL kind
        self.succs: list[tuple[str, object]] = []   # (stage name, Channel)
        self.n_preds = 0                 # wired by the pipeline
        self._runs: dict[str, _StageRun] = {}
        self._done_ids: set[str] = set()
        self._lat = RollingStat(128)
        self.calls = 0
        if collector is not None:
            collector.describe(
                f"{self.name}.latency",
                "Stage service latency in seconds (input-complete to "
                "output-forwarded); lower is better.")

    # -- knob hooks ---------------------------------------------------------
    def _clamp_tier(self, value: str) -> str:
        tiers = self.p.tier_names()
        if tiers and value not in tiers:
            raise ValueError(f"{self.name}: unknown tier {value!r} "
                             f"(have {tiers})")
        return value

    def _tier_changed(self, old, new) -> None:
        self.p.on_stage_retier(self.spec.name)   # cp estimates shift

    def _width_changed(self, old, new) -> None:
        self.p.on_stage_retier(self.spec.name)

    # -- input side ---------------------------------------------------------
    def _need_inputs(self) -> int:
        need = max(self.n_preds, 1)
        if self.spec.kind is StageKind.JOIN and self.spec.join_k > 0:
            need = min(self.spec.join_k, need)
        return need

    def inject(self, task, tokens: int) -> None:
        """Source-stage entry: the pipeline feeds the task directly."""
        run = self._runs.setdefault(task.task_id, _StageRun(task))
        run.tokens += tokens
        run.inputs_done += 1
        self._dispatch(run)

    def deliver(self, msg: Message) -> None:
        pay = msg.payload or {}
        tid = msg.task_id
        if tid in self._done_ids or (tid in self._runs
                                     and self._runs[tid].dispatched):
            # straggler input after a join timeout already fired (or the
            # stage finished): absorb its activation so the task's
            # completion refcount still drains
            if pay.get("task_end"):
                task = pay.get("task")
                run = self._runs.get(tid)
                if task is None and run is not None:
                    task = run.task
                if task is not None:
                    self.p.task_drop(task)
            return
        run = self._runs.get(tid)
        if run is None:
            run = self._runs[tid] = _StageRun(pay.get("task"))
        run.tokens += msg.tokens
        if not pay.get("task_end"):
            return
        run.inputs_done += 1
        if run.inputs_done >= self._need_inputs():
            self._dispatch(run)
        elif run.timer is None and self.join_timeout > 0:
            run.timer = self.loop.call_after(
                self.join_timeout, lambda r=run: self._join_timeout(r))
        self._gauge_queue()

    def _join_timeout(self, run: _StageRun) -> None:
        if run.dispatched or run.task.task_id not in self._runs:
            return
        if run.inputs_done >= 1:         # proceed with what arrived
            self._dispatch(run)

    # -- dispatch -----------------------------------------------------------
    def _deadline_and_cp(self, task) -> tuple[float, float]:
        if not self.p.cp_enabled() or task.deadline == math.inf:
            return math.inf, 0.0
        cp_rem = self.p.cp_remaining(self.spec.name)
        through = self.p.cp_through(self.spec.name)
        return task.submitted_at + self.deadline_slack * through, cp_rem

    def _boosted(self, task, cp_rem: float) -> Priority:
        """Longest-remaining-path boost on admission: a task whose
        remaining critical path no longer fits before its deadline is
        behind schedule — bump it one priority level."""
        prio = task.priority
        if (self.p.cp_enabled() and task.deadline < math.inf
                and self.loop.now() + cp_rem > task.deadline
                and int(prio) < int(Priority.HIGH)):
            prio = Priority(int(prio) + 1)
        return prio

    def _trace_run(self, run: _StageRun) -> None:
        """Open the stage's span for a task: a child of the task root,
        and the parent every engine call made for this run links under
        (the DAG edges the trace report's critical path walks)."""
        tr = getattr(self.p, "tracer", None)
        if tr is None or run.task is None:
            return
        tid = run.task.task_id
        if not tr.decide(tid, stage=self.spec.name):
            return
        run.trace_span = tr.begin(
            f"stage:{self.spec.name}", tid, cat="stage",
            parent=tr.task_span(tid), stage=self.spec.name,
            kind=self.spec.kind.value, inputs=run.inputs_done)

    def _dispatch(self, run: _StageRun) -> None:
        run.dispatched = True
        run.started_at = self.loop.now()
        self._trace_run(run)
        if run.timer is not None:
            self.loop.cancel(run.timer)
            run.timer = None
        self.p.task_merge(run.task, run.inputs_done)
        if self.spec.kind is StageKind.TOOL:
            self._dispatch_tool(run)
        else:
            self._dispatch_llm(run)
        self._gauge_queue()

    def _dispatch_tool(self, run: _StageRun) -> None:
        # the tool is now in flight: its feeders' held requests are no
        # longer demotable — their resume is what frees the slot
        for r in run.task.meta.get("held", ()):
            r.meta.pop("tool_blocked", None)
        msg = Message(src=self.name, dst=self.tool.name, payload={},
                      tokens=run.tokens, task_id=run.task.task_id)
        run.calls_open = 1
        self.tool.deliver(msg, on_done=lambda m, r=run: self._tool_done(r))

    def _dispatch_llm(self, run: _StageRun) -> None:
        task = run.task
        parts = self.width if self.spec.kind is StageKind.FAN_OUT else 1
        share = max((run.tokens + parts - 1) // parts, 0)
        deadline, cp_rem = self._deadline_and_cp(task)
        prio = self._boosted(task, cp_rem)
        held = self._take_held(task, parts)
        if held is not None:
            self._continue_held(run, held, share, prio, deadline, cp_rem)
            return
        hold_est = self.p.tool_hold_est(self.spec.name)
        run.calls_open = parts
        for i in range(parts):
            req = Request(
                prompt_len=self.spec.prompt_tokens + share,
                max_new_tokens=self.spec.out_tokens,
                priority=prio, deadline=deadline, stage=self.spec.name,
                meta={"stage": self.spec.name, "task": task.task_id,
                      "part": i, "cp_remaining": cp_rem,
                      "trace_parent": run.trace_span,
                      "prefix": ((f"stage:{self.spec.name}",
                                  self.spec.prompt_tokens),
                                 (f"in:{task.task_id}", share)),
                      "on_finish":
                          lambda r, t, run=run: self._call_done(run, r, t)})
            if hold_est is not None:
                # this stage feeds a TOOL stage: keep the sequence alive
                # at completion so the post-tool turn resumes its KV
                # instead of re-prefilling the whole transcript
                req.meta["hold_open"] = True
                req.meta["tool_latency_est"] = hold_est
            self.p.route_call(Message(
                src=self.name, dst="pool",
                payload={"request": req, "tier": self.model_tier,
                         "session": task.session},
                tokens=share, priority=prio, task_id=task.task_id,
                created_at=self.loop.now()))
            self.calls += 1

    # -- tool-call suspend/resume continuations ------------------------------
    def _take_held(self, task, parts: int):
        """Claim the task's held-open (suspended) request if this stage
        can decode straight on top of its live KV: single call, same
        tier as the engine parking the cache.  Held requests this stage
        cannot use are released — the stage falls back to fresh calls."""
        meta = getattr(task, "meta", None)
        if not meta or "held" not in meta:
            return None
        held = meta.pop("held")
        keep = None
        if parts == 1:
            live = [r for r in held
                    if r.state == RequestState.SUSPENDED
                    and self.p.engine_tier(r) == self.model_tier]
            if live:
                keep = max(live, key=lambda r: r.total_len)
        for r in held:
            if r is not keep:
                self._release_held(r)
        return keep

    def _release_held(self, req: Request) -> None:
        eng = req.meta.get("engine")
        if eng is not None and req.state == RequestState.SUSPENDED:
            eng.finish_suspended(req)

    def _continue_held(self, run: _StageRun, req: Request, share: int,
                       prio: Priority, deadline: float,
                       cp_rem: float) -> None:
        """Resume the suspended pre-tool request in place of a fresh
        call: the tool result arrives as ``share`` appended prompt
        tokens (still prefilled — only the pre-tool context is warm),
        then this stage's out_tokens decode on top of it."""
        run.calls_open = 1
        req.meta.pop("tool_blocked", None)
        req.meta["continued_base"] = req.generated
        req.prompt_len += share
        req.available = req.prompt_len
        req.max_new_tokens += self.spec.out_tokens
        req.priority = prio
        req.deadline = deadline
        req.stage = self.spec.name
        req.meta["stage"] = self.spec.name
        req.meta["task"] = run.task.task_id
        req.meta["cp_remaining"] = cp_rem
        req.meta["on_finish"] = (
            lambda r, t, run=run: self._call_done(run, r, t))
        hold_est = self.p.tool_hold_est(self.spec.name)
        if hold_est is not None:
            req.meta["hold_open"] = True
            req.meta["tool_latency_est"] = hold_est
        req.meta["post_tool_t0"] = self.loop.now()
        self.calls += 1
        self.p.resume_request(req)

    # -- completion ---------------------------------------------------------
    def _tool_done(self, run: _StageRun) -> None:
        self._prune_held(run.task)
        run.calls_open = 0
        run.out_tokens = run.tokens       # tools pass content through
        self._complete(run, self.loop.now())

    def _prune_held(self, task) -> None:
        """The tool returned: keep only the richest-context held request
        (it carries the most reusable KV into the post-tool turn) and
        release the rest — e.g. only one of pro/con survives a join."""
        meta = getattr(task, "meta", None)
        if not meta or "held" not in meta:
            return
        live = [r for r in meta["held"]
                if r.state == RequestState.SUSPENDED]
        if not live:
            meta.pop("held", None)
            return
        keep = max(live, key=lambda r: r.total_len)
        for r in live:
            if r is not keep:
                self._release_held(r)
        meta["held"] = [keep]

    def _call_done(self, run: _StageRun, req: Request, t: float) -> None:
        run.calls_open -= 1
        run.out_tokens += req.generated - req.meta.pop("continued_base", 0)
        if req.state == RequestState.SUSPENDED:
            # the engine held the sequence open for our TOOL successor:
            # park it on the task until the post-tool stage claims it
            run.task.meta.setdefault("held", []).append(req)
            if self.p.tool_fanin(self.spec.name) > 1:
                # the TOOL this hold targets waits on *sibling* stages
                # whose calls still need slots: a pinned hold here can
                # wedge a fully parked engine (debate's pro holds the
                # slot its own con needs), so flag it demotable for the
                # scheduler's liveness rung until the tool dispatches
                req.meta["tool_blocked"] = True
        if run.calls_open <= 0:
            self._complete(run, t)

    def _complete(self, run: _StageRun, t: float) -> None:
        task = run.task
        self._runs.pop(task.task_id, None)
        self._done_ids.add(task.task_id)
        if run.trace_span is not None:
            run.trace_span.attrs["out_tokens"] = run.out_tokens
            getattr(self.p, "tracer").end(run.trace_span, t)
            run.trace_span = None
        lat = t - run.started_at
        self._lat.add(lat)
        if self.collector is not None:
            self.collector.observe(f"{self.name}.latency", lat, t)
            self.collector.gauge(f"{self.name}.p95",
                                 self._lat.pctl(0.95), t)
        self._gauge_queue()
        succs = self.succs
        if self.spec.kind is StageKind.BRANCH and len(succs) > 1:
            idx = (self.spec.branch_fn(task.task_id)
                   if self.spec.branch_fn is not None
                   else zlib.crc32(task.task_id.encode()))
            succs = [succs[idx % len(succs)]]
        for _, ch in succs:
            ch.begin_task(task.task_id, session=task.session,
                          speculative=task.speculative, task=task)
            ch.push_tokens(task.task_id, run.out_tokens)
            ch.end_unit(task.task_id)
            ch.end_task(task.task_id)
        self.p.task_advance(task, forwarded=len(succs))

    # -- introspection ------------------------------------------------------
    def _gauge_queue(self) -> None:
        if self.collector is not None:
            q = sum(1 for r in self._runs.values() if not r.dispatched)
            q += sum(r.calls_open for r in self._runs.values())
            self.collector.gauge(f"{self.name}.queue", q, self.loop.now())

    def p95(self) -> float:
        return self._lat.pctl(0.95)

    def load(self) -> float:
        return float(len(self._runs))
