"""Agents: LLM-backed roles wired through the data plane.

``DeveloperAgent`` — generates code for a task on its engine, emitting
tokens into its outbound channel; the channel's *current* granularity
decides how they leave (the agent itself is granularity-oblivious: late
binding, the paper's fix for §2.2 "early binding").

``TesterAgent`` — consumes messages, turns arrived content into engine
requests *incrementally* (progressive prefill under STREAM), maintains
per-session KV residency via the SessionDirectory, and triggers reactive
KV pulls when a session's state lives on a sibling instance.

``ToolAgent`` — a non-LLM tool (e.g. code executor) with heavy-tailed
latency, timeout/retry semantics, and the same set()/reset() surface,
demonstrating that the Table-1 interface covers tools, not just models.
"""
from __future__ import annotations

import math
import random
import zlib
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.dataplane import Channel
from repro.core.knobs import ControlSurface, KnobSpec
from repro.core.types import Message, Priority, Request
from repro.serving.engine_base import EngineCore
from repro.serving.kv_transfer import KVTransferManager, SessionDirectory
from repro.sim.clock import EventLoop


# ---------------------------------------------------------------------------
# Developer
# ---------------------------------------------------------------------------


class DeveloperAgent:
    """Generates ``n_functions × func_tokens`` for each task."""

    def __init__(self, name: str, engine: EngineCore, loop: EventLoop,
                 out: Channel, controller=None):
        self.name = name
        self.engine = engine
        self.loop = loop
        self.out = out
        self.controller = controller
        self._active: dict[str, object] = {}     # req_id -> spec
        engine.on_token = self._on_token
        engine.on_finish = self._on_finish

    def submit_task(self, spec) -> None:
        # prefix identity for the cache plane: the MetaGPT-style system
        # preamble is shared across every task; the task body is private
        sys_toks = min(int(getattr(spec, "system_tokens", 0) or 0),
                       spec.prompt_tokens)
        prefix = (("system-prompt", sys_toks),
                  (f"task:{spec.task_id}", spec.prompt_tokens - sys_toks))
        req = Request(prompt_len=spec.prompt_tokens,
                      max_new_tokens=spec.n_functions * spec.func_tokens,
                      priority=spec.priority, stage="developer",
                      meta={"spec": spec, "prefix": prefix,
                            "task": spec.task_id})
        self._active[req.req_id] = spec
        self.out.begin_task(
            spec.task_id, session=spec.session,
            speculative=spec.speculative,
            n_functions=spec.n_functions, func_tokens=spec.func_tokens,
            test_tokens=spec.test_tokens,
            total_tokens=spec.n_functions * spec.func_tokens)
        if self.controller is not None:
            # the hint hook: the controller learns a task started *before*
            # any tokens exist — early enough to pre-position KV state
            self.controller.event("task_start", session=spec.session,
                                  task=spec.task_id, agent=self.name)
        self.engine.submit(req)

    # engine callbacks ---------------------------------------------------------
    def _on_token(self, req: Request, tok: int, t: float) -> None:
        spec = self._active.get(req.req_id)
        if spec is None:
            return
        self.out.push_tokens(spec.task_id, 1)
        if req.generated % spec.func_tokens == 0:
            self.out.end_unit(spec.task_id)

    def _on_finish(self, req: Request, t: float) -> None:
        spec = self._active.pop(req.req_id, None)
        if spec is not None:
            self.out.end_task(spec.task_id)

    def load(self) -> float:
        return self.engine.load()


# ---------------------------------------------------------------------------
# Tester
# ---------------------------------------------------------------------------


@dataclass
class _TaskState:
    task_id: str
    session: str
    n_functions: int
    func_tokens: int
    test_tokens: int
    arrived: int = 0                 # content tokens arrived
    units_requested: int = 0         # units already covered by requests
    open_req: Optional[Request] = None
    open_fed: int = 0                # content tokens fed to open_req
    done_units: int = 0
    reqs: list = field(default_factory=list)
    started_at: float = 0.0
    extra_prefill: int = 0           # session-context recompute tokens


class TesterAgent:
    """One tester *instance* (Fig 7 runs two behind a router)."""

    def __init__(self, name: str, engine: EngineCore, loop: EventLoop,
                 directory: Optional[SessionDirectory] = None,
                 kvx: Optional[KVTransferManager] = None,
                 header_tokens: int = 64, on_task_done: Optional[Callable] = None,
                 recompute_on_miss: bool = True):
        self.name = name
        self.engine = engine
        self.loop = loop
        self.dir = directory
        self.kvx = kvx
        self.header_tokens = header_tokens
        self.on_task_done = on_task_done
        self.recompute_on_miss = recompute_on_miss
        self._tasks: dict[str, _TaskState] = {}
        self.recomputed_tokens = 0
        self.kv_waits: list[float] = []
        engine.on_finish = self._on_finish

    # -- data-plane endpoint -----------------------------------------------------
    def deliver(self, msg: Message) -> None:
        pay = msg.payload or {}
        task_id = msg.task_id
        st = self._tasks.get(task_id)
        if st is None:
            st = self._open_task(task_id, msg)
            if st is None:            # gated on KV transfer: redelivered later
                return
        st.arrived += msg.tokens
        if pay.get("task_end") and st.units_requested == 0 and st.open_req is None:
            self._request_units(st, st.n_functions, st.arrived,
                                priority=msg.priority, batch=True)
            return
        self._absorb(st, msg)

    def _open_task(self, task_id: str, msg: Message) -> Optional[_TaskState]:
        pay = msg.payload or {}
        session = pay.get("session") or task_id
        st = _TaskState(
            task_id=task_id, session=session,
            n_functions=pay.get("n_functions", 1),
            func_tokens=pay.get("func_tokens", msg.tokens or 1),
            test_tokens=pay.get("test_tokens", 32),
            started_at=self.loop.now())
        # --- session KV residency ------------------------------------------
        if self.dir is not None:
            rec = self.dir.get(session)
            if rec is None:
                self.dir.ensure(session, self.name)
            elif not self.dir.resident(session, self.name, self.loop.now()):
                wait = (self.kvx.wait_time(session, self.name)
                        if self.kvx else float("inf"))
                if wait == float("inf") and self.kvx is not None:
                    # reactive pull: fetch the state now that the request
                    # has arrived (the Fig-7 "without hints" arm)
                    self.kvx.transfer(session, rec.instance, self.name)
                    wait = self.kvx.wait_time(session, self.name)
                if wait != float("inf") and wait > 0:
                    self.kv_waits.append(wait)
                    self.loop.call_after(wait, lambda m=msg: self.deliver(m))
                    return None
                if wait == float("inf"):
                    # no transfer fabric: re-prefill the session context
                    if self.recompute_on_miss:
                        st.extra_prefill = rec.context_len
                        self.recomputed_tokens += rec.context_len
                        rec.instance = self.name
                else:
                    self.kv_waits.append(0.0)
        self._tasks[task_id] = st
        return st

    # -- unit/request bookkeeping ----------------------------------------------
    def _absorb(self, st: _TaskState, msg: Message) -> None:
        pay = msg.payload or {}
        full_units = min(st.arrived // st.func_tokens, st.n_functions)
        partial = st.arrived - full_units * st.func_tokens

        if st.open_req is not None:
            # feed the in-flight streaming request up to its unit boundary
            unit_start = (st.units_requested - 1) * st.func_tokens
            have_now = min(st.arrived - unit_start, st.func_tokens)
            delta = have_now - st.open_fed
            if delta > 0:
                st.open_req.feed(delta)
                st.open_fed = have_now
                self.engine.kick()
            if st.open_fed >= st.func_tokens:
                st.open_req = None    # its unit fully arrived
                st.open_fed = 0

        if pay.get("task_end"):
            remaining = st.n_functions - st.units_requested
            if remaining > 0:
                tokens = st.arrived - st.units_requested * st.func_tokens
                self._request_units(st, remaining, tokens,
                                    priority=msg.priority, batch=True)
            return

        # whole units that arrived but aren't covered yet (PIPELINE mode
        # delivers exactly one per message; BATCH after a switch several)
        if full_units > st.units_requested:
            k = full_units - st.units_requested
            self._request_units(st, k, k * st.func_tokens,
                                priority=msg.priority)

        # partial unit under STREAM: open a progressive-prefill request
        if (partial > 0 and st.open_req is None
                and st.units_requested == full_units
                and st.units_requested < st.n_functions):
            req = self._make_request(st, units=1,
                                     content_tokens=st.func_tokens,
                                     available_content=partial,
                                     priority=msg.priority)
            st.open_req = req
            st.open_fed = partial
            st.units_requested += 1

    def _request_units(self, st: _TaskState, units: int, content_tokens: int,
                       priority: Priority, batch: bool = False) -> None:
        self._make_request(st, units=units, content_tokens=content_tokens,
                           available_content=content_tokens,
                           priority=priority)
        st.units_requested += units

    def _make_request(self, st: _TaskState, units: int, content_tokens: int,
                      available_content: int, priority: Priority) -> Request:
        extra = st.extra_prefill
        base = self.header_tokens + extra
        st.extra_prefill = 0          # recompute cost paid once per task
        # prefix identity: the tester's system header is shared across
        # every request on this instance; the session-context recompute
        # is shared within the session; the unit content is private
        prefix = [("system-prompt", self.header_tokens)]
        if extra > 0:
            prefix.append((f"sess:{st.session}", extra))
        prefix.append((f"unit:{st.task_id}:{st.units_requested}",
                       content_tokens))
        req = Request(
            prompt_len=base + content_tokens,
            max_new_tokens=units * st.test_tokens,
            priority=priority, stage="tester",
            meta={"task": st.task_id, "units": units, "agent": self.name,
                  "prefix": tuple(prefix)})
        req.available = base + available_content
        st.reqs.append(req)
        self.engine.submit(req)
        return req

    def _on_finish(self, req: Request, t: float) -> None:
        task_id = req.meta.get("task")
        st = self._tasks.get(task_id)
        if st is None:
            return
        st.done_units += req.meta.get("units", 1)
        if st.done_units >= st.n_functions:
            del self._tasks[task_id]
            if self.dir is not None:
                self.dir.grow(st.session,
                              st.n_functions * (st.func_tokens
                                                + st.test_tokens))
            if self.on_task_done is not None:
                self.on_task_done(st, t)

    def load(self) -> float:
        return self.engine.load()


# ---------------------------------------------------------------------------
# Tool
# ---------------------------------------------------------------------------


class ToolAgent(ControlSurface):
    """A tool endpoint (code executor / retriever / file system).

    Not an LLM: its metrics are call latency and queue depth, and its
    knobs are concurrency and an artificial throttle — the §3.2 point
    that tools need *different* metrics under the same unified plane.

    Real tool latency is heavy-tailed, so beyond the fixed ``latency``
    a ``latency_cv`` coefficient of variation samples per-call
    durations from a lognormal with *median* ``latency`` (the mean is
    then ``latency * exp(sigma^2/2)`` — the tail pulls it up, which is
    exactly what critical-path estimates must account for).  A
    ``timeout`` knob caps any attempt: a timed-out call burns the full
    timeout, then retries with a fresh sample up to ``max_retries``
    times (fail-open after that), with timeout/retry counters on the
    bus for OffloadPolicy and the benchmarks.
    """

    kind = "tool"
    CAPABILITIES = ("throttle",)
    METRICS = ("tool_latency", "tool_queue", "tool_timeouts",
               "tool_retries")
    KNOB_SPECS = (
        KnobSpec("concurrency", kind="int", lo=1,
                 doc="max simultaneous tool calls"),
        KnobSpec("throttle", kind="float", lo=0.0,
                 doc="artificial per-call latency in seconds"),
        KnobSpec("timeout", kind="float", lo=0.0,
                 doc="per-attempt wall-clock cap in seconds; a timed-out "
                     "attempt retries with a fresh latency sample "
                     "(0 = no timeout)"),
    )

    def __init__(self, name: str, loop: EventLoop, latency: float = 0.05,
                 concurrency: int = 2, collector=None,
                 latency_cv: float = 0.0, timeout: float = 0.0,
                 max_retries: int = 1, seed: int | None = None):
        self.name = name
        self.loop = loop
        self.latency = latency
        self.latency_cv = latency_cv
        self.concurrency = concurrency
        self.throttle = 0.0
        self.timeout = timeout
        self.max_retries = max_retries
        self.collector = collector
        self._busy = 0
        self._queue: list[tuple[Message, Callable]] = []
        self.calls = 0
        self.timeouts = 0
        self.retries = 0
        self._rng = random.Random(
            seed if seed is not None else zlib.crc32(name.encode()))
        if collector is not None:
            collector.describe(
                f"{name}.tool_latency",
                "Tool call latency in seconds; lower is better.")

    def on_knob_set(self, name: str, old, new) -> None:
        self._pump()                    # raised concurrency drains the queue

    # -- latency model --------------------------------------------------------
    def sample_latency(self) -> float:
        """One attempt's duration: lognormal(median=latency) when
        latency_cv > 0, the fixed latency otherwise; throttle on top."""
        if self.latency_cv <= 0:
            return self.latency + self.throttle
        sigma = math.sqrt(math.log1p(self.latency_cv ** 2))
        z = self._rng.gauss(0.0, 1.0)
        return self.latency * math.exp(sigma * z) + self.throttle

    def mean_latency(self) -> float:
        """Expected per-call wall clock including the heavy tail and
        timeout retries — what suspend policies and critical-path
        estimates should charge, not the fixed median."""
        return expected_tool_latency(self.latency + self.throttle,
                                     self.latency_cv, self.timeout,
                                     self.max_retries)

    # -- endpoint -------------------------------------------------------------
    def deliver(self, msg: Message, on_done: Optional[Callable] = None) -> None:
        self._queue.append((msg, on_done))
        if self.collector is not None:
            self.collector.gauge(f"{self.name}.tool_queue",
                                 len(self._queue), self.loop.now())
        self._pump()

    def _pump(self) -> None:
        while self._busy < self.concurrency and self._queue:
            msg, on_done = self._queue.pop(0)
            self._busy += 1
            t0 = self.loop.now()
            self._attempt(msg, on_done, t0, tries=0)

    def _attempt(self, msg, on_done, t0: float, tries: int) -> None:
        dur = self.sample_latency()
        if 0 < self.timeout < dur and tries < self.max_retries:
            # the attempt burns the whole timeout window, then retries
            def _retry(msg=msg, on_done=on_done, t0=t0, tries=tries):
                self.timeouts += 1
                self.retries += 1
                if self.collector is not None:
                    now = self.loop.now()
                    self.collector.gauge(f"{self.name}.tool_timeouts",
                                         self.timeouts, now)
                    self.collector.gauge(f"{self.name}.tool_retries",
                                         self.retries, now)
                self._attempt(msg, on_done, t0, tries + 1)

            self.loop.call_after(self.timeout, _retry)
            return
        if 0 < self.timeout < dur:
            # retry budget exhausted: fail open at the timeout so a
            # pathological tail can't wedge the workflow
            dur = self.timeout
            self.timeouts += 1
            if self.collector is not None:
                self.collector.gauge(f"{self.name}.tool_timeouts",
                                     self.timeouts, self.loop.now())

        def _fin(msg=msg, on_done=on_done, t0=t0):
            self._busy -= 1
            self.calls += 1
            if self.collector is not None:
                self.collector.observe(f"{self.name}.tool_latency",
                                       self.loop.now() - t0,
                                       self.loop.now())
            if on_done is not None:
                on_done(msg)
            self._pump()

        self.loop.call_after(dur, _fin)

    def load(self) -> float:
        return self._busy + len(self._queue)


def expected_tool_latency(latency: float, cv: float = 0.0,
                          timeout: float = 0.0,
                          max_retries: int = 1) -> float:
    """Expected wall clock of one tool call under the lognormal model.

    ``latency`` is the distribution's *median*; the heavy tail lifts the
    mean to ``latency * exp(sigma^2/2)``.  With a timeout, each attempt
    is capped (first order: ``min(mean, timeout)``) but a timed-out
    attempt burns the full window before retrying, adding
    ``P(X > timeout) * timeout`` per allowed retry."""
    if latency <= 0:
        return max(latency, 0.0)
    if cv <= 0:
        return latency if timeout <= 0 else min(latency, timeout)
    sigma2 = math.log1p(cv * cv)
    mean = latency * math.exp(0.5 * sigma2)
    if timeout <= 0:
        return mean
    # lognormal tail: P(X > T) = 1 - Phi(ln(T/median)/sigma)
    sigma = math.sqrt(sigma2)
    x = math.log(timeout / latency) / sigma
    p_tail = 0.5 * (1.0 - math.erf(x / math.sqrt(2.0)))
    return min(mean, timeout) + p_tail * timeout * max(max_retries, 0)
