"""Workflow graph plane: agent DAGs as first-class control-plane objects.

The data plane no longer hard-codes the paper's Fig-1 topology: a
``WorkflowGraph`` declares typed stages (chain, fan-out, fan-in/join,
branch, tool) and the edges between them, and
``AgenticPipeline.build(graph)`` compiles it into wired engines,
channels and routers (agents/pipeline.py).  The graph itself stays on
the *control* side of the line — the scheduler consumes its
critical-path structure (longest-remaining-path priorities,
edge-propagated deadlines), the router consumes its per-stage model
tiers, and the controller reaches every stage through a registered
``stage.<name>`` knob surface.

Graph analysis lives here and is deliberately dependency-free (no
engines, no event loop): ``topo_order``, ``est_inputs`` (expected token
flow along edges) and ``critical_path`` (longest remaining work per
stage under a pluggable per-stage cost function) are pure functions of
the DAG, so policies and tests can reason about workflows without
building one.

Prebuilt topologies:

* ``fig1()``        — the paper's developer→tester pipeline (template
  marker: ``build`` routes it to the classic ``AgenticPipeline``).
* ``map_reduce()``  — planner → fan-out map workers → fan-in reducer.
* ``deep_review()`` — a depth-d review chain (author → reviewers → editor).
* ``debate()``      — moderator → pro/con branches → fact-check tool →
  judge → verdict branch (accept | revise).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable

from repro.agents.stage import StageKind, StageSpec
from repro.core.types import Priority, fresh_id


class GraphError(ValueError):
    pass


@dataclass
class GraphTask:
    """One task flowing through a workflow graph.

    ``deadline`` is absolute virtual time; ``inf`` means "stamp from the
    graph's critical path at submit" (pipeline default) — the workflow
    runtime propagates per-stage deadlines from it along edges.
    """

    session: str
    prompt_tokens: int = 128
    priority: Priority = Priority.NORMAL
    speculative: bool = False
    deadline: float = math.inf
    task_id: str = field(default_factory=lambda: fresh_id("wtask"))
    submitted_at: float = 0.0
    finished_at: float = 0.0
    # runtime scratch (e.g. live requests held open across a TOOL stage
    # by the suspend/resume plane)
    meta: dict = field(default_factory=dict)


class WorkflowGraph:
    """A DAG of ``StageSpec``s — the control-plane view of a workflow."""

    def __init__(self, name: str, template: str = ""):
        self.name = name
        self.template = template          # "fig1" routes build() to the
        self.meta: dict = {}              # classic pipeline
        self.stages: dict[str, StageSpec] = {}
        self.edges: list[tuple[str, str]] = []
        self._preds: dict[str, list[str]] = {}
        self._succs: dict[str, list[str]] = {}

    # -- construction -------------------------------------------------------
    def add_stage(self, spec: StageSpec) -> StageSpec:
        if spec.name in self.stages:
            raise GraphError(f"duplicate stage {spec.name!r}")
        self.stages[spec.name] = spec
        self._preds[spec.name] = []
        self._succs[spec.name] = []
        return spec

    def stage(self, name: str, **kw) -> StageSpec:
        """Sugar: declare-and-add in one call."""
        return self.add_stage(StageSpec(name, **kw))

    def add_edge(self, src: str, dst: str) -> None:
        for n in (src, dst):
            if n not in self.stages:
                raise GraphError(f"edge {src}->{dst}: unknown stage {n!r}")
        if (src, dst) in self.edges:
            raise GraphError(f"duplicate edge {src}->{dst}")
        if src == dst:
            raise GraphError(f"self-edge on {src!r}")
        self.edges.append((src, dst))
        self._succs[src].append(dst)
        self._preds[dst].append(src)

    def chain(self, *names: str) -> None:
        for u, v in zip(names, names[1:]):
            self.add_edge(u, v)

    # -- structure ----------------------------------------------------------
    def preds(self, name: str) -> list[str]:
        return list(self._preds[name])

    def succs(self, name: str) -> list[str]:
        return list(self._succs[name])

    def sources(self) -> list[str]:
        return [n for n in self.stages if not self._preds[n]]

    def sinks(self) -> list[str]:
        return [n for n in self.stages if not self._succs[n]]

    def topo_order(self) -> list[str]:
        indeg = {n: len(p) for n, p in self._preds.items()}
        ready = [n for n, d in indeg.items() if d == 0]
        order: list[str] = []
        while ready:
            n = ready.pop(0)
            order.append(n)
            for s in self._succs[n]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    ready.append(s)
        if len(order) != len(self.stages):
            raise GraphError(f"graph {self.name!r} has a cycle")
        return order

    def validate(self) -> "WorkflowGraph":
        if not self.stages:
            raise GraphError("empty graph")
        self.topo_order()                  # raises on cycles
        if not self.sources():
            raise GraphError("no source stage")
        for name, spec in self.stages.items():
            if spec.kind is StageKind.JOIN and not self._preds[name]:
                raise GraphError(f"JOIN stage {name!r} has no inputs")
            if (spec.kind is StageKind.BRANCH
                    and len(self._succs[name]) < 2):
                raise GraphError(
                    f"BRANCH stage {name!r} needs >= 2 successors")
            if spec.kind is StageKind.FAN_OUT and spec.width < 1:
                raise GraphError(f"FAN_OUT stage {name!r}: width < 1")
        self._check_fanin_liveness()
        return self

    def _check_fanin_liveness(self) -> None:
        """Reject fan-ins that can never fire.  A BRANCH activates only
        ONE successor per task, so a stage that waits for ALL of its
        inputs (multi-pred, join_k=0, join_timeout=0) deadlocks if any
        input is only *maybe* produced — e.g. the natural
        ``branch -> arm_a | arm_b -> merge`` pattern.  Forward pass:
        ``guaranteed[n]`` = this stage runs (and feeds all successors)
        for every task.  Wait-for-all stages need every input
        guaranteed; others fire on any guaranteed input (join_k /
        join_timeout stages fire once anything arrives)."""
        guaranteed: dict[str, bool] = {}
        for n in self.topo_order():
            preds = self._preds[n]
            if not preds:
                guaranteed[n] = True
                continue
            spec = self.stages[n]
            fed = {p: guaranteed[p]
                   and self.stages[p].kind is not StageKind.BRANCH
                   for p in preds}
            waits_all = (len(preds) > 1 and spec.join_k == 0
                         and spec.join_timeout == 0)
            if waits_all and not all(fed.values()):
                starved = sorted(p for p, ok in fed.items() if not ok)
                raise GraphError(
                    f"stage {n!r} waits for ALL inputs but "
                    f"{starved} may never fire (downstream of a "
                    "BRANCH arm) — set join_k or join_timeout on it")
            guaranteed[n] = (all(fed.values()) if waits_all
                             else any(fed.values()))

    # -- analysis -----------------------------------------------------------
    def est_out_tokens(self, spec: StageSpec, est_in: float) -> float:
        """Expected tokens a stage emits downstream per task."""
        if spec.kind is StageKind.TOOL:
            return est_in                  # tools pass content through
        if spec.kind is StageKind.FAN_OUT:
            return float(spec.width * spec.out_tokens)
        return float(spec.out_tokens)

    def est_inputs(self, prompt_tokens: int = 128) -> dict[str, float]:
        """Expected input tokens arriving at each stage (forward pass in
        topological order; sources see the task prompt)."""
        est: dict[str, float] = {}
        for n in self.topo_order():
            if not self._preds[n]:
                est[n] = float(prompt_tokens)
            else:
                est[n] = sum(
                    self.est_out_tokens(self.stages[p], est[p])
                    for p in self._preds[n])
        return est

    def critical_path(
            self, cost_fn: Callable[[StageSpec, float], float],
            prompt_tokens: int = 128,
    ) -> dict[str, float]:
        """Longest remaining work per stage (the stage's own estimated
        cost plus the heaviest downstream path), under ``cost_fn(spec,
        est_input_tokens) -> seconds``.  Reverse topological pass; for a
        BRANCH the max over arms is the conservative remaining path."""
        est_in = self.est_inputs(prompt_tokens)
        cp: dict[str, float] = {}
        for n in reversed(self.topo_order()):
            tail = max((cp[s] for s in self._succs[n]), default=0.0)
            cp[n] = cost_fn(self.stages[n], est_in[n]) + tail
        return cp

    def cp_total(self, cp: dict[str, float]) -> float:
        return max((cp[s] for s in self.sources()), default=0.0)

    def describe(self) -> str:
        lines = [f"workflow {self.name!r}:"]
        for n in self.topo_order():
            spec = self.stages[n]
            succ = ", ".join(self._succs[n]) or "(sink)"
            lines.append(f"  {n} [{spec.kind.value}"
                         f"{'x%d' % spec.width if spec.kind is StageKind.FAN_OUT else ''}"
                         f", tier={spec.model_tier}] -> {succ}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Prebuilt topologies
# ---------------------------------------------------------------------------


def fig1(n_functions: int = 6, func_tokens: int = 48,
         test_tokens: int = 40) -> WorkflowGraph:
    """The paper's Fig-1 developer→tester pipeline as a graph.  Carries
    the ``fig1`` template marker: ``AgenticPipeline.build`` compiles it
    through the classic pipeline (DeveloperAgent/TesterAgent semantics,
    KV-transfer fabric, elastic tester group) rather than the generic
    stage runtime."""
    g = WorkflowGraph("fig1", template="fig1")
    g.stage("developer", kind=StageKind.CHAIN,
            out_tokens=n_functions * func_tokens)
    g.stage("tester", kind=StageKind.FAN_OUT, width=n_functions,
            out_tokens=test_tokens)
    g.add_edge("developer", "tester")
    return g


def map_reduce(width: int = 8, out_tokens: int = 48,
               worker_tier: str = "large") -> WorkflowGraph:
    """Planner fans a task out to ``width`` map workers; a fan-in
    reducer joins their results.  The map stage is the natural
    down-tiering target (many short parallel calls)."""
    g = WorkflowGraph(f"map_reduce_w{width}")
    g.stage("planner", kind=StageKind.CHAIN, out_tokens=64)
    g.stage("map", kind=StageKind.FAN_OUT, width=width,
            out_tokens=out_tokens, model_tier=worker_tier)
    g.stage("reduce", kind=StageKind.JOIN, out_tokens=96)
    g.chain("planner", "map", "reduce")
    return g


def deep_review(depth: int = 4, out_tokens: int = 64,
                reviewer_tier: str = "large", tool_latency: float = 0.0,
                tool_latency_cv: float = 0.0,
                tool_timeout: float = 0.0) -> WorkflowGraph:
    """An author draft walked through a depth-``depth`` reviewer chain,
    closed by an editor — the long-critical-path shape where EDF over
    propagated deadlines matters most.  ``tool_latency > 0`` inserts a
    research TOOL stage after each reviewer (a literature lookup), which
    turns the chain into the suspend/resume plane's stress shape:
    every reviewer's context parks for a heavy-tailed tool wait."""
    g = WorkflowGraph(f"deep_review_d{depth}")
    g.stage("author", kind=StageKind.CHAIN, out_tokens=128)
    names = ["author"]
    for i in range(depth):
        g.stage(f"reviewer-{i}", kind=StageKind.CHAIN,
                out_tokens=out_tokens, model_tier=reviewer_tier)
        names.append(f"reviewer-{i}")
        if tool_latency > 0:
            g.stage(f"research-{i}", kind=StageKind.TOOL,
                    tool_latency=tool_latency,
                    tool_latency_cv=tool_latency_cv,
                    tool_timeout=tool_timeout)
            names.append(f"research-{i}")
    g.stage("editor", kind=StageKind.CHAIN, out_tokens=96)
    names.append("editor")
    g.chain(*names)
    return g


def debate(side_tokens: int = 80, side_tier: str = "large",
           tool_latency: float = 0.05, tool_latency_cv: float = 0.0,
           tool_timeout: float = 0.0) -> WorkflowGraph:
    """Branching debate with a tool stage: a moderator frames the
    question, pro and con argue in parallel, a fact-check *tool* joins
    both transcripts, a judge rules, and a verdict BRANCH routes each
    task to exactly one of accept/revise."""
    g = WorkflowGraph("debate")
    g.stage("moderator", kind=StageKind.CHAIN, out_tokens=48)
    g.stage("pro", kind=StageKind.CHAIN, out_tokens=side_tokens,
            model_tier=side_tier)
    g.stage("con", kind=StageKind.CHAIN, out_tokens=side_tokens,
            model_tier=side_tier)
    g.stage("factcheck", kind=StageKind.TOOL, tool_latency=tool_latency,
            tool_latency_cv=tool_latency_cv, tool_timeout=tool_timeout)
    g.stage("judge", kind=StageKind.CHAIN, out_tokens=72)
    g.stage("verdict", kind=StageKind.BRANCH, out_tokens=24)
    g.stage("accept", kind=StageKind.CHAIN, out_tokens=16,
            model_tier=side_tier)
    g.stage("revise", kind=StageKind.CHAIN, out_tokens=64,
            model_tier=side_tier)
    g.add_edge("moderator", "pro")
    g.add_edge("moderator", "con")
    g.add_edge("pro", "factcheck")
    g.add_edge("con", "factcheck")
    g.chain("factcheck", "judge", "verdict")
    g.add_edge("verdict", "accept")
    g.add_edge("verdict", "revise")
    return g


GALLERY: dict[str, Callable[..., WorkflowGraph]] = {
    "fig1": fig1,
    "map_reduce": map_reduce,
    "deep_review": deep_review,
    "debate": debate,
}
