"""Fault-tolerance drill: kill a tester instance mid-flight, watch the
heartbeat monitor detect it and the elastic group fail over (sessions
re-homed, in-flight work re-queued on survivors), then scale back up.

    PYTHONPATH=src python examples/failover_drill.py
"""
from repro.agents import AgenticPipeline, PipelineConfig, WorkloadConfig
from repro.agents.workloads import launch_clients
from repro.core.types import Granularity
from repro.runtime import HeartbeatMonitor
from repro.runtime.heartbeat import attach_engine


def main():
    p = AgenticPipeline(PipelineConfig(granularity=Granularity.PIPELINE,
                                       n_testers=2))
    mon = HeartbeatMonitor(p.loop, miss_timeout=1.0)
    for t in p.testers:
        attach_engine(mon, t.engine)
    # reuse the pipeline's registered group — one drain/scale authority
    # per fleet (a second ElasticGroup would track draining separately)
    grp = p.elastic
    grp.monitor = mon

    events = []

    def on_failure(name):
        events.append((p.loop.now(), f"FAILURE detected: {name}"))
        moved = grp.fail_over(name)
        events.append((p.loop.now(),
                       f"failed over {moved} sessions/requests to "
                       f"{[t.name for t in p.testers]}"))
        # restore capacity
        new = grp.scale_up()
        events.append((p.loop.now(), f"scaled up replacement: {new}"))

    mon.on_failure = on_failure
    mon.start()

    launch_clients(p, WorkloadConfig(n_clients=8, think_time=0.2),
                   stop_at=20.0)

    # pull the plug on tester-0 at t=6s: it stops stepping (pause) and
    # stops heartbeating (unwatch happens only via failover)
    def kill():
        victim = p.testers[0]
        victim.engine.paused = True           # stops stepping...
        victim.engine.dead = True             # ...and stops liveness pings
        events.append((p.loop.now(), f"injected crash: {victim.name}"))

    p.loop.call_at(6.0, kill)
    p.run(until=40.0)

    print("timeline:")
    for t, e in events:
        print(f"  t={t:6.2f}s  {e}")
    print(f"\ntasks completed: {len(p.done)} "
          f"(work continued through the failure)")
    assert len(p.done) > 20
    assert any("FAILURE" in e for _, e in events)
    print("OK")


if __name__ == "__main__":
    main()
