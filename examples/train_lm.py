"""End-to-end training driver: a ~100M-param LM for a few hundred steps
on CPU, through the full production path — sharded train step (1-device
mesh), synthetic token pipeline, AdamW with warmup+cosine, atomic
checkpointing, and supervised restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--chaos]

``--chaos`` injects a failure mid-run to demonstrate checkpoint/restart
(the resumed loss curve continues exactly where it left off).
"""
import argparse
import time

import jax
import numpy as np

from repro import models
from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, TokenPipeline
from repro.launch.mesh import make_mesh
from repro.launch.train import AdamWConfig, TrainPlan, make_train_step
from repro.optim.adamw import adamw_init
from repro.runtime import SimulatedFailure, TrainSupervisor
from repro.runtime.supervisor import SupervisorConfig


def main():
    ap = argparse.ArgumentParser()
    # full deliverable: --model lm-100m --steps 300 (hours on this CPU
    # container; minutes on one accelerator). CPU-friendly default below.
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--model", default="lm-100m")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="artifacts/train_lm")
    ap.add_argument("--chaos", action="store_true",
                    help="inject a failure at step 2/3 of the run")
    args = ap.parse_args()

    cfg = get_config(args.model)
    n = models.param_count(cfg)
    print(f"model {cfg.name}: {n/1e6:.1f}M params")

    mesh = make_mesh((1, 1), ("data", "model"))
    acfg = AdamWConfig(lr=1e-3, warmup_steps=min(30, args.steps // 5),
                       total_steps=args.steps)
    from repro.configs.base import ShapeConfig
    shape = ShapeConfig("train", args.seq, args.batch, "train")
    with jax.set_mesh(mesh):
        step_fn, _ = make_train_step(cfg, mesh, TrainPlan(), acfg,
                                     shape=shape)

        params = models.init(cfg, jax.random.key(0))
        opt = adamw_init(params, acfg)

        pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                        global_batch=args.batch, seed=1))
        mgr = CheckpointManager(args.ckpt_dir, keep=2)
        sup = TrainSupervisor(mgr, SupervisorConfig(ckpt_every=50))

        losses = []
        t0 = time.time()
        chaos_at = {args.steps * 2 // 3} if args.chaos else set()

        def train_one(state, batch, step):
            if step in chaos_at:
                chaos_at.discard(step)
                raise SimulatedFailure(f"injected failure at step {step}")
            p, o = state
            batch = {k: np.asarray(v) for k, v in batch.items()}
            p, o, metrics = step_fn(p, o, batch)
            losses.append(float(metrics["loss"]))
            if (step + 1) % 25 == 0:
                rate = (step + 1) / (time.time() - t0)
                print(f"step {step+1:4d}  loss {losses[-1]:.4f}  "
                      f"lr {float(metrics['lr']):.2e}  "
                      f"({rate:.2f} steps/s)", flush=True)
            return (p, o)

        state = sup.run(state=(params, opt), pipeline=pipe,
                        step_fn=train_one, total_steps=args.steps)
        pipe.close()

    first = np.mean(losses[:20])
    last = np.mean(losses[-20:])
    print(f"\nloss: {first:.3f} -> {last:.3f} "
          f"(improved {first-last:.3f} nats)")
    if sup.restarts:
        print(f"survived {sup.restarts} failure(s); log: {sup.log}")
    need = 0.2 if args.steps >= 150 else 0.04
    assert last < first - need, "training did not make progress"
    print("OK")


if __name__ == "__main__":
    main()
