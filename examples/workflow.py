"""Workflow graph plane end-to-end: a first-class agent DAG under
critical-path scheduling, per-stage model tiering, and a stage-selector
intent program.

    PYTHONPATH=src python examples/workflow.py

What happens:

1. ``map_reduce(width=8)`` — planner → 8-way map fan-out → fan-in
   reducer — compiles through ``AgenticPipeline.build`` into a shared
   tier-labelled engine pool (two 7B-class instances + four 1B-class),
   one ``stage_aware`` router, a ``Channel`` per graph edge, and a
   registered ``stage.<name>`` controllable per stage.
2. The graph is a control-plane object: every engine request carries a
   deadline propagated along the DAG's edges from the critical-path
   estimate, schedulers run EDF-within-priority with a longest-
   remaining-path tie-break, and behind-schedule tasks get an admission
   priority boost.
3. An intent program uses the v2 ``stage`` selectors: when the map
   stage's own p95 gauge breaches, the bus-triggered rule re-tiers it
   to the small model through the same audited ``set()`` surface as
   every other knob — and the critical-path estimates (and therefore
   every downstream deadline) shift with it.
"""
from repro.agents import (AgenticPipeline, GraphBurst, TierSpec,
                          WorkflowConfig, map_reduce)
from repro.core import compile_intent

INTENT = """
objective: minimize p95(workflow.task_latency)

# stage selector, event path: the map stage publishes its own rolling
# p95 gauge; a breach pushes over the MetricBus and re-tiers the stage
rule map_slow on stage map.p95 > 0.35 hold 2:
    => set stage map.model_tier small; note map stage down-tiered

# stage selector, interval path: a calm map stage earns the big model back
rule map_calm hold 4: when p95(stage map.latency, 3.0) <= 0.1
    => reset stage map.model_tier
"""


def main():
    graph = map_reduce(width=8)          # every stage starts on "large"
    print(graph.describe())
    wp = AgenticPipeline.build(graph, WorkflowConfig(
        tiers={"large": TierSpec("agent-7b", chips=4, replicas=2),
               "small": TierSpec("agent-1b", chips=1, replicas=4)}))
    intent = compile_intent(INTENT)
    wp.controller.install(intent)
    print("intent:", intent.objective.describe())
    print(f"critical path estimate: {wp._cp_total:.3f}s "
          f"(deadline slack x{wp.cfg.deadline_slack})")

    GraphBurst(wp, n_tasks=24, stagger=0.05).start()
    wp.run(until=120.0)

    lats = sorted(wp.latencies())
    print(f"\ntasks completed: {len(wp.done)}")
    print(f"p95 task latency: {lats[int(0.95 * len(lats)) - 1]:.3f}s")
    print(f"map stage tier now: "
          f"{wp.registry.get_param('stage.map', 'model_tier')}")
    print(f"router picks won on tier match: {wp.router.tier_routed}")
    print(f"rule firings: {intent.stats()}")
    print("\ncontroller audit (stage + event actions):")
    for a in wp.controller.actions:
        if "stage." in a.target or a.kind == "event":
            print(f"  t={a.t:6.2f}s  [{a.kind}] {a.target}: {a.detail}")


if __name__ == "__main__":
    main()
