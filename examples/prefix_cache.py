"""Prefix-cache plane end-to-end: cache-aware routing + an intent
program that pins the shared system prompt when the hit rate sags.

    PYTHONPATH=src python examples/prefix_cache.py

What happens:

1. The pipeline runs two tester instances with per-instance prefix
   caches behind a ``cache_aware`` router: fan-out requests land where
   their shared system header is already resident.
2. The installed intent program watches the cache plane's own metric
   (``tester-0.cache.hit_rate``, pushed like every other gauge) and
   reacts through the same audited control surface as every other knob:

       rule pin_hot: when last(tester-0.cache.hit_rate) < 0.9
           => pin system-prompt; note pinned system prompt

   Pinned blocks are exempt from eviction, so the hottest prefix
   survives page-pool pressure.
3. The run prints per-instance hit rates, tokens saved, routing stats,
   and the controller's audit trail.
"""
from repro.agents import AgenticPipeline, PipelineConfig, TaskSpec
from repro.core.intent import compile_intent

PROGRAM = """
# keep the system prompt resident while the cache is still warming up
rule pin_hot: when last(tester-0.cache.hit_rate) < 0.9
    => pin system-prompt; note pinned system prompt
"""


def main() -> int:
    p = AgenticPipeline(PipelineConfig(
        n_testers=2, header_tokens=256, router_policy="cache_aware"))
    p.controller.install(compile_intent(PROGRAM))

    for i in range(12):
        p.submit(TaskSpec(session=f"sess-{i % 3}", n_functions=3))
    p.run(until=60.0)

    print(f"tasks done: {len(p.done)}")
    for name, cache in sorted(p.cache_dir.caches.items()):
        pinned = sum(e.pinned for e in cache._entries.values())
        print(f"{name}: hit_rate={cache.hit_rate:.2f} "
              f"saved_prefill_tokens={cache.saved_prefill_tokens} "
              f"blocks={cache.blocks_resident} pinned={pinned} "
              f"evictions={cache.evictions}")
    print(f"router: routed={p.router.routed} "
          f"cache_routed={p.router.cache_routed}")
    print("audit trail:")
    for a in p.controller.actions[:12]:
        print(f"  t={a.t:7.3f}  {a.kind:<8} {a.target:<24} {a.detail}")

    assert len(p.done) == 12
    assert p.router.cache_routed > 0
    assert any(a.kind == "pin" for a in p.controller.actions)
    assert sum(c.saved_prefill_tokens
               for c in p.cache_dir.caches.values()) > 0
    print("OK: pin fired, cache-aware routing used, prefill tokens saved")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
