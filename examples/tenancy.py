"""Tenancy plane walkthrough: throttle a noisy tenant from intent.

Two tenants share a 2-engine pool: ``gold`` runs small interactive
requests in closed-loop sessions, ``noisy`` floods long prompts
open-loop.  Every request is tenant-stamped; the router meters each
tenant's traffic through its token bucket, and the TenantDirectory
publishes per-tenant rollups (``tenant.gold.p95_ttft``, ...) on the
metric bus.  An intent rule watches the gold tenant's p95 TTFT and — on
breach — *throttles the noisy tenant at runtime* by setting its
``tenant.noisy.rate`` knob: the noisy prompts are held (never dropped)
at the router and drip through on refill, while the gold tenant's
latency recovers.  A second rule relaxes the throttle once gold has
stayed healthy.

    PYTHONPATH=src python examples/tenancy.py
"""
from repro.agents.workloads import TenantLoad, TenantMix
from repro.configs import get_config
from repro.core.controller import Controller
from repro.core.intent import compile_intent
from repro.core.metrics import CentralPoller, Collector, MetricBus, StateStore
from repro.core.registry import Registry
from repro.core.tenancy import TenantDirectory, TenantSpec
from repro.serving.disagg import DisaggPool
from repro.serving.engine_sim import SimEngine
from repro.serving.kv_transfer import KVTransferManager, SessionDirectory
from repro.serving.scheduler import SchedulerConfig
from repro.sim.clock import EventLoop
from repro.sim.costmodel import costmodel_for

INTENT = """
# throttle the noisy tenant the moment gold's p95 TTFT breaches
rule guard on tenant gold.p95_ttft > 0.15 hold 2:
    => set tenant noisy.rate 4000; note guard: noisy tenant throttled
# relax once gold has stayed healthy for a while
rule relax hold 8: when p95(tenant gold.ttft, 3.0) < 0.05
    => reset tenant noisy.rate
"""


def main():
    loop = EventLoop()
    bus = MetricBus()
    collector = Collector("tenancy-example", bus=bus)
    store = StateStore()
    poller = CentralPoller(store)
    poller.attach(collector)
    registry = Registry()
    controller = Controller(loop, registry, poller, interval=0.05, bus=bus)

    tenants = TenantDirectory(collector=collector, registry=registry)
    tenants.add(TenantSpec("gold", weight=4.0, slo_class="gold",
                           p95_ttft_target=0.15))
    tenants.add(TenantSpec("noisy", weight=1.0, slo_class="batch"))

    cm = costmodel_for(get_config("agent-7b"), chips=4)
    engines = [
        SimEngine(loop, cm,
                  SchedulerConfig(max_slots=8, num_pages=4096,
                                  max_context=4096, prefill_chunk=512),
                  name=f"e{i}", collector=collector)
        for i in range(2)]
    for e in engines:
        registry.register(e)
    kvx = KVTransferManager(loop, SessionDirectory(),
                            bytes_fn=cm.kv_transfer_bytes,
                            collector=collector)
    pool = DisaggPool(loop, engines, kvx, collector=collector,
                      tenants=tenants)
    controller.install(compile_intent(INTENT))

    mix = TenantMix(loop, pool.submit, [
        TenantLoad("gold", slo_class="gold", mode="closed", sessions=6,
                   think=0.05, prompt=128, gen=64),
        TenantLoad("noisy", slo_class="batch", mode="open", rate=60.0,
                   prompt=1024, gen=48),
    ], t_end=16.0, seed=0)
    TenantMix.wire_pool(pool)
    mix.start()

    controller.start()
    loop.run_until(40.0)

    noisy = tenants.get("noisy")
    gold_ttfts = sorted(
        r.first_token_time - r.arrival_time
        for r in mix.requests["gold"] if r.first_token_time is not None)
    p95 = gold_ttfts[int(0.95 * (len(gold_ttfts) - 1))] if gold_ttfts else 0

    print("controller actions:")
    for a in controller.action_log("set") + controller.action_log("note"):
        print(f"  t={a.t:5.2f}s  {a.kind:4s} {a.target}: {a.detail}")
    print(f"\ngold requests: {len(mix.requests['gold'])}  "
          f"p95 TTFT: {p95:.3f}s")
    print(f"noisy messages throttled: {noisy.throttled_count}  "
          f"(admitted {noisy.admitted_tokens:.0f} tokens)")
    n_gold = len(mix.requests["gold"])
    n_done = sum(1 for r in mix.requests["gold"]
                 if r.state.value == "finished")
    print(f"tasks completed: {n_done}/{n_gold} gold")
    assert n_done == n_gold, "every gold request must finish"
    throttled = any("tenant.noisy" in a.target
                    for a in controller.action_log("set"))
    assert throttled, "the guard rule must have throttled the noisy tenant"
    assert noisy.throttled_count > 0, "the router meter must have held work"
    print("OK")


if __name__ == "__main__":
    main()
