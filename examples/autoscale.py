"""Event-driven autoscaling from a declarative intent v2 program.

The controller no longer has to poll every metric every 50 ms tick to
notice a burst: the ``on tester-0.queue_len > 10`` trigger becomes a
MetricBus threshold subscription, so the *data plane pushes* the breach
to the control plane the moment an engine records it, and the ``scale``
action reaches the ElasticGroup through the same Table-1 ``set()``
surface as every other knob (``tester-group.replicas``).

    PYTHONPATH=src python examples/autoscale.py
"""
from repro.agents import AgenticPipeline, PipelineConfig, WorkloadConfig
from repro.agents.workloads import Phase, PhasedLoad
from repro.core import compile_intent
from repro.core.types import Granularity


INTENT = """
objective: maximize throughput under p95(pipeline.task_latency) <= 6.0

# event path: the bus pushes the queue-length breach between polls;
# hold 6 = at most one scale-up per 6 s
rule burst on tester-0.queue_len > 10 hold 6:
    => scale tester-group +1; note burst: grew the tester fleet

# interval path: sustained calm across the WHOLE fleet (glob pools
# every tester's series) shrinks it back; replicas clamps at 1, so
# repeated firing is safe
rule calm hold 8: when mean(tester-*.queue_len, 4.0) <= 1
    => scale tester-group -1
"""


def main():
    p = AgenticPipeline(PipelineConfig(granularity=Granularity.PIPELINE,
                                       n_testers=1))
    intent = compile_intent(INTENT)
    p.controller.install(intent)
    print("intent:", intent.objective.describe())
    print("bus subscriptions:",
          [s.metric for s in p.bus.subscriptions()])

    load = PhasedLoad(p, WorkloadConfig(think_time=0.3),
                      [Phase(10.0, 2), Phase(20.0, 40), Phase(20.0, 2)])
    load.start()
    p.run(until=55.0)

    print(f"\ntasks completed: {len(p.done)}")
    print(f"final replicas:  {p.registry.get_param('tester-group', 'replicas')}")
    print(f"rule firings:    {intent.stats()}")
    print(f"bus events:      published={p.bus.published} "
          f"delivered={p.bus.delivered}")
    print("\ncontroller audit log (event + scale actions):")
    for a in p.controller.actions:
        if a.kind in ("event", "scale"):
            print(f"  t={a.t:6.2f}s  [{a.kind}] {a.target}: {a.detail}")


if __name__ == "__main__":
    main()
