"""Tracing plane walkthrough: turn on request tracing from an intent at
runtime, then explain a workload from the exported trace alone.

The fig1 pipeline runs two waves of tasks.  Tracing is OFF at build
time — an intent rule watching ``developer.queue_len`` fires on the
first wave's arrival burst and enables span capture (``trace on``), so
only the second wave is sampled: tracing is a control-plane decision
made from runtime state, exactly like every other knob.  A second rule
fires mid-second-wave and flips the dev->tester channel to token
streaming; the flight recorder captures both actions and the exporter
causally links them onto the request spans they overlapped.

The trace is exported as Chrome-trace JSON and re-read by
``tools/trace_report.py`` — everything printed at the end (critical
path, dominant segments, segment-sum vs e2e tiling, linked control
actions) comes from the JSON file, not from live objects.

    PYTHONPATH=src python examples/trace.py
"""
import importlib.util
import sys
import tempfile
from pathlib import Path

from repro.agents.pipeline import AgenticPipeline, PipelineConfig, TaskSpec
from repro.core.intent import compile_intent

INTENT = """
# span capture is a runtime decision: the arrival burst itself
# enables tracing for everything sampled after this fires
rule enable on developer.queue_len > 1:
    => trace on; note tracing enabled from queue pressure
# mid-run reconfiguration while traced requests are in flight — the
# flight recorder links this action onto the spans it overlapped
rule stream on pipeline.tasks_done > 3:
    => granularity dev->tester stream; note streaming under load
"""


def _load_report_tool():
    path = Path(__file__).resolve().parent.parent / "tools" / "trace_report.py"
    spec = importlib.util.spec_from_file_location("trace_report", path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[spec.name] = mod
    spec.loader.exec_module(mod)
    return mod


def main():
    pipe = AgenticPipeline(PipelineConfig(n_testers=2))
    pipe.controller.install(compile_intent(INTENT))
    pipe.recorder.watch("tester-*.queue_len")    # rolling metric windows

    assert pipe.tracer.enabled is False          # off until the rule fires
    for i in range(3):                           # wave 1: triggers `enable`
        pipe.submit(TaskSpec(session=f"s{i}", n_functions=4))
    pipe.loop.call_after(2.0, lambda: [
        pipe.submit(TaskSpec(session=f"s{3 + i}", n_functions=4))
        for i in range(5)])                      # wave 2: fully traced
    pipe.run(until=60.0)

    assert pipe.tracer.enabled, "intent never enabled tracing"
    assert len(pipe.done) == 8, f"only {len(pipe.done)}/8 tasks finished"
    traced = [a for a in pipe.controller.action_log("trace")]
    assert traced, "no trace action in the audit log"

    out = Path(tempfile.mkdtemp(prefix="trace_example_")) / "TRACE_fig1.json"
    doc = pipe.tracer.export(out, recorder=pipe.recorder)
    assert doc["otherData"]["links"] >= 1, "no action causally linked"

    rpt = _load_report_tool()
    loaded = rpt.load(out)
    assert rpt.validate(loaded) == [], "exported trace failed schema check"
    print(rpt.report(loaded, limit=3))
    checks = rpt.decomposition_check(rpt.spans_from(loaded))
    assert checks, "no closed request spans in the export"
    for span, seg_sum, dur in checks:
        assert abs(seg_sum - dur) <= 0.01 * max(dur, 1e-9), (
            f"{span.name}: segments {seg_sum:.4f}s != e2e {dur:.4f}s")
    win = pipe.recorder.window("tester-0.queue_len")
    print(f"recorder: {len(pipe.recorder.actions)} control actions, "
          f"{len(win)} samples of tester-0.queue_len")
    print(f"tasks completed: {len(pipe.done)}")


if __name__ == "__main__":
    main()
