"""Serve a (tiny, real-JAX) model with batched requests through the full
stack: continuous-batching engine, metrics plane, controller with an SLO
intent, and the Table-1 set()/reset() surface.

    PYTHONPATH=src python examples/serve_llm.py
"""
import numpy as np

import jax

from repro import models
from repro.configs import get_config
from repro.core import Controller, Registry, compile_intent
from repro.core.metrics import CentralPoller, Collector, StateStore
from repro.core.types import Priority, Request
from repro.serving.engine import Engine
from repro.serving.scheduler import SchedulerConfig
from repro.sim.clock import EventLoop


def main():
    cfg = get_config("tiny-agent")
    params = models.init(cfg, jax.random.key(0))
    collector = Collector("serve")
    eng = Engine(cfg, params,
                 SchedulerConfig(max_slots=4, num_pages=128,
                                 max_context=128),
                 name="llm-0", collector=collector)

    # control plane wiring (the engine registers its card + knobs)
    loop = EventLoop()
    registry = Registry()
    card = registry.register(eng)
    print(f"registered {card.name}: knobs={sorted(card.knobs)}")

    store = StateStore()
    poller = CentralPoller(store)
    poller.attach(collector)
    controller = Controller(loop, registry, poller)
    controller.install(compile_intent("""
objective: minimize p95(llm-0.latency)
rule shed: when last(llm-0.queue_len) > 6 => set llm-0.admit_priority_min 1
rule open: when last(llm-0.queue_len) <= 2 => reset llm-0.admit_priority_min
"""))

    # batched requests: mixed priorities and prompt lengths
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(10):
        plen = int(rng.integers(4, 24))
        prio = Priority.INTERACTIVE if i % 3 == 0 else Priority.LOW
        r = Request(prompt_len=plen, max_new_tokens=12, priority=prio,
                    prompt_tokens=rng.integers(
                        0, cfg.vocab, plen).astype(np.int32))
        reqs.append(r)
        eng.submit(r)

    # drive the engine; poll the controller between steps
    for step in range(200):
        if not eng.busy:
            break
        eng.step()
        poller.poll(eng.now())
        controller._tick_once = True      # manual tick (wall-clock engine)
        from repro.core.controller import ControlContext
        ctx = ControlContext(controller)
        for pol in controller.policies:
            pol.on_tick(ctx)

    done = [r for r in reqs if r.state.value == "finished"]
    print(f"\ncompleted {len(done)}/10 requests")
    for r in done[:4]:
        print(f"  {r.req_id}: prio={r.priority.name:11s} "
              f"prompt={r.prompt_len:3d} tokens={r.output_tokens[:8]}...")
    lat = [r.finish_time - r.arrival_time for r in done]
    print(f"latency mean={np.mean(lat):.3f}s p95={np.quantile(lat,0.95):.3f}s")
    print(f"controller actions: {[(a.kind, a.detail) for a in controller.actions]}")
    # demonstrate the uniform shim: retune batch size live
    registry.set("llm-0", "max_num_seqs", 2)
    print(f"set('max_num_seqs', 2) -> engine slots now "
          f"{eng.scheduler.cfg.max_slots}")


if __name__ == "__main__":
    main()
