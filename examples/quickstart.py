"""Quickstart: the software-defined agentic serving stack in ~60 lines.

Builds the paper's Fig-1 pipeline (developer → shim channel → router →
tester), installs a declarative intent program on the controller, drives
a bursty workload, and prints what the control plane did.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.agents import AgenticPipeline, PipelineConfig, WorkloadConfig
from repro.agents.workloads import Phase, PhasedLoad
from repro.core import compile_intent
from repro.core.types import Granularity


def main():
    # 1. the pipeline: one developer, one tester, a controllable channel
    p = AgenticPipeline(PipelineConfig(granularity=Granularity.PIPELINE,
                                       n_testers=1, stream_chunk=2))

    # 2. operator intent, not code: the controller compiles this into a
    #    closed-loop policy over the metrics plane
    intent = compile_intent("""
objective: maximize throughput under p95(pipeline.task_latency) <= 4.0

rule overload:  when mean(tester-0.queue_len, 1.0) > 12
    => granularity dev->tester batch; set tester-0.decode_first true
rule loaded:    when mean(tester-0.queue_len, 1.0) > 3
    => granularity dev->tester pipeline; reset tester-0.decode_first
rule idle:      when mean(tester-0.queue_len, 1.0) <= 3
    => granularity dev->tester stream
""")
    p.controller.install(intent)
    print("intent:", intent.objective.describe())

    # 3. load that shifts: quiet -> burst -> quiet
    load = PhasedLoad(p, WorkloadConfig(think_time=0.3),
                      [Phase(15.0, 2), Phase(15.0, 48), Phase(15.0, 2)])
    load.start()
    p.run(until=50.0)

    # 4. what happened
    lats = p.latencies()
    print(f"\ntasks completed: {len(p.done)}")
    print(f"mean latency:    {sum(lats)/len(lats):.2f}s")
    print(f"rule firings:    {intent.stats()}")
    print("\ncontroller action log (granularity switches):")
    for a in p.controller.action_log("set"):
        if "granularity" in a.detail:
            print(f"  t={a.t:6.2f}s  {a.target}: {a.detail}")


if __name__ == "__main__":
    main()
