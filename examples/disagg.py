"""Disaggregation plane walkthrough: software-defined engine roles.

A 3-engine fleet starts as 1 prefill / 2 decode behind a DisaggPool.
Requests prefill on the prefill-role engine (first token there), then
their KV rides the chunk-streamed handoff pipeline to a decode engine
that carries the decode tail.  An intent rule watches the fleet's
``cluster.prefill_pressure`` gauge and *conscripts* a decode engine to
prefill duty when a fan-out burst lands — then a second rule returns it
to decode duty once the backlog clears.  Engine role is just a knob:
the same ``set()`` surface every other serving attribute uses.

    PYTHONPATH=src python examples/disagg.py
"""
from repro.configs import get_config
from repro.core.controller import Controller
from repro.core.intent import compile_intent
from repro.core.metrics import CentralPoller, Collector, MetricBus, StateStore
from repro.core.registry import Registry
from repro.core.types import Request
from repro.serving.disagg import DisaggPool
from repro.serving.engine_sim import SimEngine
from repro.serving.kv_transfer import KVTransferManager, SessionDirectory
from repro.serving.scheduler import SchedulerConfig
from repro.sim.clock import EventLoop
from repro.sim.costmodel import costmodel_for

INTENT = """
# conscript e2 the moment fleet prefill backlog exceeds half a step
rule surge on cluster.prefill_pressure > 0.5 hold 2:
    => set engine e2.role prefill; note surge: e2 conscripted to prefill
# return it to decode duty once the backlog has stayed clear
rule relax hold 2: when mean(cluster.prefill_pressure, 1.0) < 0.05
    => set engine e2.role decode
"""


def main():
    loop = EventLoop()
    bus = MetricBus()
    collector = Collector("disagg-example", bus=bus)
    store = StateStore()
    poller = CentralPoller(store)
    poller.attach(collector)
    registry = Registry()
    controller = Controller(loop, registry, poller, interval=0.05, bus=bus)

    cm = costmodel_for(get_config("agent-7b"), chips=4)
    roles = ("prefill", "decode", "decode")
    engines = [
        SimEngine(loop, cm,
                  SchedulerConfig(max_slots=8, num_pages=2048,
                                  max_context=4096, prefill_chunk=512,
                                  role=role),
                  name=f"e{i}", collector=collector)
        for i, role in enumerate(roles)]
    for e in engines:
        registry.register(e)
    kvx = KVTransferManager(loop, SessionDirectory(),
                            bytes_fn=cm.kv_transfer_bytes,
                            collector=collector)
    pool = DisaggPool(loop, engines, kvx, collector=collector)
    controller.install(compile_intent(INTENT))

    # steady trickle of requests, then a fan-out burst at t=2s
    reqs = []

    def submit(prompt, gen):
        r = Request(prompt_len=prompt, max_new_tokens=gen)
        reqs.append(r)
        pool.submit(r)

    for i in range(10):
        loop.call_at(0.2 * i, lambda: submit(256, 48))
    loop.call_at(2.0, lambda: [submit(1024, 16) for _ in range(16)])

    role_log = []

    def snap_roles():
        role_log.append((round(loop.now(), 2), dict(pool.roles())))
    for t in (1.0, 2.5, 8.0):
        loop.call_at(t, snap_roles)

    controller.start()
    loop.run_until(20.0)

    print("role timeline:")
    for t, roles_at in role_log:
        print(f"  t={t:5.2f}s  {roles_at}")
    print("controller actions:")
    for a in controller.action_log("set") + controller.action_log("note"):
        print(f"  t={a.t:5.2f}s  {a.kind:4s} {a.target}: {a.detail}")
    n_done = sum(1 for r in reqs if r.state.value == "finished")
    print(f"\nhandoffs: {pool.handoffs}  (KV bytes moved: "
          f"{kvx.handoff_bytes / 1e6:.1f} MB)")
    print(f"tasks completed: {n_done}/{len(reqs)}")
    assert n_done == len(reqs), "every request must finish"
    assert pool.handoffs > 0, "prefill->decode handoffs must occur"
    surged = any("role=prefill" in a.detail
                 for a in controller.action_log("set"))
    assert surged, "the surge rule must have flipped a role"
    print("OK")


if __name__ == "__main__":
    main()
