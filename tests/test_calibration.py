"""Calibration plane: roofline fit recovery, CALIB artifact round-trip,
CostModel.from_calibration, the decode attention-FLOPs term, and the
measured-grid tolerance gate (skipped where no JAX device exists)."""
import json

import pytest

from repro.configs import get_config
from repro.sim.calibration import (CALIB_VERSION, CalibrationPoint,
                                   calibrate, fit_roofline,
                                   load_calibration, save_calibration)
from repro.sim.costmodel import (HBM_BW, PEAK_FLOPS, STEP_OVERHEAD,
                                 CostModel)


def _synthetic_points(fs, bs, c, chips=1, noise=None):
    """Grid spanning both roofline branches under the true scales."""
    grid = [(1e9, 1e6), (5e9, 2e6), (2e10, 8e6), (4e10, 3e7),   # compute
            (1e8, 4e7), (5e7, 1e8), (2e8, 6e7), (1e7, 2e8)]     # memory
    pts = []
    for i, (f, by) in enumerate(grid):
        t = max(f * fs / (chips * PEAK_FLOPS),
                by * bs / (chips * HBM_BW)) + c
        if noise is not None:
            t *= 1.0 + noise[i % len(noise)]
        pts.append(CalibrationPoint("decode", 1, 128, f, by, t))
    return pts


# ---------------------------------------------------------------------------
# fit
# ---------------------------------------------------------------------------

def test_fit_recovers_synthetic_parameters():
    fs, bs, c = 2.3, 1.6, 3e-4
    got_fs, got_bs, got_c = fit_roofline(_synthetic_points(fs, bs, c))
    assert got_fs == pytest.approx(fs, rel=0.05)
    assert got_bs == pytest.approx(bs, rel=0.05)
    assert got_c == pytest.approx(c, rel=0.05)


def test_fit_handles_noise_within_tolerance():
    noise = [0.04, -0.03, 0.05, -0.05, 0.02, -0.04, 0.03, -0.02]
    pts = _synthetic_points(1.8, 1.2, 2e-4, noise=noise)
    calib = calibrate("synthetic", "cpu", pts, tolerance=0.2)
    assert calib.within_tolerance
    assert calib.max_rel_err < 0.2


def test_fit_single_branch_keeps_other_scale():
    # all points compute-bound: bytes_scale is unconstrained by the data
    # and must not explode/collapse the memory branch above the fit
    pts = [CalibrationPoint("decode", 1, 128, f, 1e3,
                            f * 2.0 / PEAK_FLOPS + 1e-4)
           for f in (1e9, 5e9, 2e10, 8e10)]
    fs, bs, c = fit_roofline(pts)
    assert fs == pytest.approx(2.0, rel=0.05)
    assert bs > 0
    calib = calibrate("synthetic", "cpu", pts, tolerance=0.05)
    assert calib.within_tolerance


def test_fit_empty_points_is_identity():
    assert fit_roofline([]) == (1.0, 1.0, 0.0)


# ---------------------------------------------------------------------------
# artifact round-trip + CostModel hook
# ---------------------------------------------------------------------------

def test_calibration_artifact_roundtrip(tmp_path):
    calib = calibrate("synthetic", "cpu", _synthetic_points(2.0, 1.5, 1e-4))
    path = save_calibration(calib, tmp_path / "CALIB_synthetic.json")
    loaded = load_calibration(path)
    assert loaded is not None
    assert loaded.flops_scale == pytest.approx(calib.flops_scale)
    assert loaded.bytes_scale == pytest.approx(calib.bytes_scale)
    assert loaded.step_overhead == pytest.approx(calib.step_overhead)
    assert loaded.tolerance == calib.tolerance
    assert len(loaded.points) == len(calib.points)
    assert loaded.points[0].kind == "decode"


def test_load_calibration_rejects_garbage(tmp_path):
    assert load_calibration(tmp_path / "missing.json") is None
    bad = tmp_path / "bad.json"
    bad.write_text("not json{")
    assert load_calibration(bad) is None
    wrong = tmp_path / "wrong.json"
    wrong.write_text(json.dumps({"version": CALIB_VERSION + 99}))
    assert load_calibration(wrong) is None


def test_from_calibration_applies_fit(tmp_path):
    cfg = get_config("agent-7b")
    calib = calibrate("agent-7b", "tpu",
                      _synthetic_points(2.0, 1.5, 5e-4), chips=4)
    path = save_calibration(calib, tmp_path / "CALIB_agent-7b.json")
    cm = CostModel.from_calibration(cfg, 4, path)
    assert cm.flops_scale == pytest.approx(calib.flops_scale)
    assert cm.bytes_scale == pytest.approx(calib.bytes_scale)
    assert cm.step_overhead == pytest.approx(calib.step_overhead)
    # the loaded overhead flows into every step prediction
    base = CostModel(cfg, 4)
    assert cm.decode_time(1, 1024) != base.decode_time(1, 1024)
    # missing artifact -> analytic defaults, not an error
    fallback = CostModel.from_calibration(cfg, 4, tmp_path / "nope.json")
    assert fallback.flops_scale == 1.0
    assert fallback.step_overhead == STEP_OVERHEAD
    assert CostModel.from_calibration(cfg, 4, None).bytes_scale == 1.0


# ---------------------------------------------------------------------------
# decode attention-FLOPs term (satellite c)
# ---------------------------------------------------------------------------

def test_decode_cost_charges_attention_flops():
    cfg = get_config("agent-7b")
    cm = CostModel(cfg, chips=4)
    batch, ctx = 8, 4096
    flops, bytes_ = cm.decode_cost(batch, ctx)
    attn = 4.0 * cfg.n_layers * cfg.n_heads * cfg.d_head * ctx * batch
    assert flops == pytest.approx(2.0 * cm.n_active_params() * batch + attn)
    # pinned delta: the attention term is exactly the before/after gap
    flops0, bytes0 = cm.decode_cost(batch, 0)
    kv = batch * ctx * cm.kv_bytes_per_token()
    assert flops - flops0 == pytest.approx(attn)
    assert bytes_ - bytes0 == pytest.approx(kv)


def test_decode_time_grows_with_context_when_compute_bound():
    # huge batch × long context: attention FLOPs dominate, so decode_time
    # must grow with context even though weight reads are constant
    cfg = get_config("agent-7b")
    cm = CostModel(cfg, chips=4)
    b = 256
    t_short, t_long = cm.decode_time(b, 1_000), cm.decode_time(b, 500_000)
    assert t_long > t_short
    f_long, by_long = cm.decode_cost(b, 500_000)
    want = max(f_long / (4 * PEAK_FLOPS), by_long / (4 * HBM_BW)) \
        + STEP_OVERHEAD
    assert t_long == pytest.approx(want)


def test_decode_cost_ssm_has_no_attention_term():
    cfg = get_config("agent-7b").replace(family="ssm", ssm_state=16)
    cm = CostModel(cfg, chips=1)
    f1, _ = cm.decode_cost(4, 100)
    f2, _ = cm.decode_cost(4, 100_000)
    assert f1 == f2                      # constant state: no ctx FLOPs


def test_decode_cost_window_clamps_context():
    cfg = get_config("agent-7b").replace(window=1024)
    cm = CostModel(cfg, chips=1)
    assert cm.decode_cost(2, 2048) == cm.decode_cost(2, 8192)


# ---------------------------------------------------------------------------
# measured tolerance gate (the CI check; skips cleanly off-device)
# ---------------------------------------------------------------------------

def _have_jax_device() -> bool:
    try:
        import jax
        return len(jax.devices()) > 0
    except Exception:                    # pragma: no cover - env dependent
        return False


@pytest.mark.skipif(not _have_jax_device(),
                    reason="no JAX device — the calibration tolerance gate "
                           "needs measured step times")
def test_calibration_tolerance_on_measured_grid(tmp_path):
    """End-to-end: measure the real jitted prefill/decode steps on a tiny
    config, fit, and require every grid point's from_calibration
    prediction inside the declared tolerance band."""
    try:
        from benchmarks import calibrate as bc
    except ImportError:
        pytest.skip("benchmarks package not importable from this rootdir")
    import jax
    pts = bc.measure_points(bc.TINY, prefill_lens=(32, 64),
                            decode_grid=((1, 64), (2, 128), (4, 128)),
                            reps=3)
    calib = calibrate(bc.TINY.name, jax.default_backend(), pts)
    assert calib.within_tolerance, (
        f"max_rel_err {calib.max_rel_err:.3f} > tolerance "
        f"{calib.tolerance} on backend {calib.backend}")
    path = save_calibration(calib, tmp_path / "CALIB_calib-tiny.json")
    cm = CostModel.from_calibration(bc.TINY, 1, path)
    for p in calib.points:
        if p.kind == "prefill":
            pred = cm.prefill_time(p.context, batch=p.batch)
        else:
            pred = cm.decode_time(p.batch, p.context)
        assert abs(pred - p.measured_s) / p.measured_s <= calib.tolerance
