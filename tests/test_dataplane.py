"""Data plane shim: granularity buffering, runtime switching, pacing,
speculative gating."""
from repro.core.dataplane import Channel
from repro.core.types import Granularity, Message
from repro.sim.clock import EventLoop
from repro.sim.network import Link


class Sink:
    name = "sink"

    def __init__(self):
        self.msgs: list[Message] = []

    def deliver(self, msg: Message) -> None:
        self.msgs.append(msg)


def _mk(granularity, stream_chunk=4, **link_kw):
    loop = EventLoop()
    sink = Sink()
    link = Link(loop, bandwidth=1e9, latency=1e-4, **link_kw)
    ch = Channel(loop, link, "src", sink, granularity=granularity,
                 stream_chunk=stream_chunk)
    return loop, sink, ch


def _task(ch, task_id="t0", units=3, tokens_per_unit=10, **kw):
    ch.begin_task(task_id, session="s0", **kw)
    for _ in range(units):
        for _ in range(tokens_per_unit):
            ch.push_tokens(task_id, 1)
        ch.end_unit(task_id)
    ch.end_task(task_id)


def test_batch_one_message_per_task():
    loop, sink, ch = _mk(Granularity.BATCH)
    _task(ch)
    loop.run_until(1.0)
    assert len(sink.msgs) == 1
    m = sink.msgs[0]
    assert m.tokens == 30 and m.payload["task_end"]


def test_pipeline_one_message_per_unit():
    loop, sink, ch = _mk(Granularity.PIPELINE)
    _task(ch)
    loop.run_until(1.0)
    # 3 unit messages + one zero-token end-of-task marker (EOS frame)
    assert len(sink.msgs) == 4
    content = [m for m in sink.msgs if m.tokens]
    assert [m.tokens for m in content] == [10, 10, 10]
    assert all(m.payload["unit_end"] for m in content)
    assert sink.msgs[-1].payload["task_end"] and sink.msgs[-1].tokens == 0


def test_stream_chunked_messages():
    loop, sink, ch = _mk(Granularity.STREAM, stream_chunk=4)
    _task(ch)
    loop.run_until(1.0)
    # 10 tokens/unit -> 2 chunks of 4 + unit-end flush of 2, per unit,
    # plus the zero-token task_end marker
    assert sum(m.tokens for m in sink.msgs) == 30
    assert len(sink.msgs) == 10
    assert max(m.tokens for m in sink.msgs) == 4


def test_midtask_granularity_switch():
    loop, sink, ch = _mk(Granularity.BATCH)
    ch.begin_task("t0", session="s0")
    for _ in range(10):
        ch.push_tokens("t0", 1)
    ch.end_unit("t0")
    # controller switches to pipeline mid-task: buffered unit flushes
    ch.set_param("granularity", "pipeline")
    loop.run_until(0.5)
    assert len(sink.msgs) == 1 and sink.msgs[0].tokens == 10
    for _ in range(10):
        ch.push_tokens("t0", 1)
    ch.end_unit("t0")
    ch.end_task("t0")
    loop.run_until(1.0)
    assert sum(m.tokens for m in sink.msgs) == 20


def test_set_reset_knobs():
    loop, sink, ch = _mk(Granularity.BATCH)
    ch.set_param("granularity", Granularity.STREAM)
    ch.set_param("stream_chunk", 2)
    assert ch.granularity is Granularity.STREAM
    ch.reset_param("granularity")
    assert ch.granularity is Granularity.BATCH
    card = ch.card()
    assert "granularity" in card.knobs and card.kind == "channel"


def test_pacing_spaces_messages():
    loop, sink, ch = _mk(Granularity.PIPELINE)
    ch.set_param("pace", 0.1)
    _task(ch, units=3)
    loop.run_until(5.0)
    # 3 unit messages + end-of-task marker
    assert len(sink.msgs) == 4


def test_speculative_gating_holds_and_releases():
    loop, sink, ch = _mk(Granularity.BATCH)
    ch.set_param("gate_speculative", True)
    _task(ch, task_id="spec", speculative=True)
    loop.run_until(0.5)
    assert len(sink.msgs) == 0 and ch.held_count == 1
    ch.set_param("gate_speculative", False)     # release
    loop.run_until(1.0)
    assert len(sink.msgs) == 1 and sink.msgs[0].speculative


def test_normal_traffic_not_gated():
    loop, sink, ch = _mk(Granularity.BATCH)
    ch.set_param("gate_speculative", True)
    _task(ch, task_id="normal", speculative=False)
    loop.run_until(0.5)
    assert len(sink.msgs) == 1


def test_link_serialization_and_proc_time():
    loop = EventLoop()
    link = Link(loop, bandwidth=1e3, latency=0.0, proc_time=0.5)
    done = []
    link.transfer(1000, lambda: done.append(loop.now()))   # 1s + 0.5
    link.transfer(1000, lambda: done.append(loop.now()))   # queued behind
    loop.run_until(10.0)
    assert abs(done[0] - 1.5) < 1e-9
    assert abs(done[1] - 3.0) < 1e-9
