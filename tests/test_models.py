"""Per-architecture smoke tests: reduced configs, one forward/train step
on CPU, output shapes + finiteness, and prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import ARCHS, get_config, get_smoke


def _batch(cfg, b=2, s=32):
    tokens = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend == "patch":
        batch["vision_embeds"] = jnp.ones((b, 8, cfg.d_model),
                                          cfg.dtype) * 0.01
        batch["positions"] = models.default_positions(cfg, b, s)
    if cfg.is_encdec:
        batch["frames"] = jnp.ones((b, 16, cfg.d_model), cfg.dtype) * 0.01
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    params = models.init(cfg, jax.random.key(0))
    batch = _batch(cfg)
    loss, metrics = models.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss)), arch
    assert 0.0 < float(loss) < 20.0
    grads = jax.grad(lambda p: models.loss_fn(p, cfg, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = get_smoke(arch)
    params = models.init(cfg, jax.random.key(0))
    b, s = 2, 32
    batch = _batch(cfg, b, s)
    cache = models.init_cache(cfg, b, 64, 16 if cfg.is_encdec else 0)
    logits_p, cache = models.prefill(
        params, cfg, batch["tokens"], cache,
        vision_embeds=batch.get("vision_embeds"),
        positions=batch.get("positions"),
        frames=batch.get("frames"))
    assert logits_p.shape == (b, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits_p, np.float32)))
    nxt = jnp.argmax(logits_p, -1)[:, None]
    logits_d, cache = models.decode_step(params, cfg, nxt, cache)
    assert logits_d.shape == (b, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits_d, np.float32)))
    assert int(cache["pos"][0]) == s + 1


@pytest.mark.parametrize("arch", ["h2o-danube-3-4b", "xlstm-350m",
                                  "kimi-k2-1t-a32b"])
def test_decode_matches_forward(arch):
    """Greedy decode over the cache must agree with teacher-forced
    forward logits (same positions, full attention context).
    capacity_factor is raised so MoE dispatch never drops — prefill
    (t=23) and forward (t=24) otherwise round capacity differently."""
    cfg = get_smoke(arch).replace(dtype="float32", capacity_factor=8.0)
    params = models.init(cfg, jax.random.key(0))
    b, s = 1, 24
    tokens = jax.random.randint(jax.random.key(2), (b, s), 0, cfg.vocab)
    full_logits, _ = models.forward(params, cfg, tokens)

    cache = models.init_cache(cfg, b, 64)
    _, cache = models.prefill(params, cfg, tokens[:, :s - 1], cache)
    step_logits, _ = models.decode_step(params, cfg, tokens[:, s - 1:], cache)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits[:, -1]),
                               atol=2e-3, rtol=2e-3)


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned dimensions."""
    expect = {
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == h, arch
        assert cfg.n_kv_heads == kv, arch
        # MoE archs list the per-expert FF width in the assignment
        assert ff in (cfg.d_ff, cfg.d_ff_expert), arch
        assert cfg.vocab == v, arch


def test_moe_configs():
    arctic = get_config("arctic-480b")
    assert arctic.n_experts == 128 and arctic.top_k == 2
    assert arctic.dense_residual
    kimi = get_config("kimi-k2-1t-a32b")
    assert kimi.n_experts == 384 and kimi.top_k == 8


def test_param_count_scales():
    """Full-config param counts are in the right ballpark."""
    approx = {"llama3-405b": 405e9, "arctic-480b": 480e9,
              "kimi-k2-1t-a32b": 1.0e12, "gemma3-27b": 27e9,
              "h2o-danube-3-4b": 4e9, "qwen2-vl-2b": 2e9,
              "hymba-1.5b": 1.5e9, "xlstm-350m": 350e6}
    for arch, n in approx.items():
        got = models.param_count(get_config(arch))
        assert 0.55 * n < got < 1.6 * n, (arch, got, n)


def test_scan_vs_unroll_equivalence():
    cfg = get_smoke("gemma3-27b").replace(dtype="float32")
    params = models.init(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    l1, _ = models.loss_fn(params, cfg, batch)
    l2, _ = models.loss_fn(params, cfg.replace(scan_layers=False), batch)
    assert abs(float(l1) - float(l2)) < 1e-5


def test_long_500k_skip_rules():
    """Assignment rule: long_500k runs only on sub-quadratic archs."""
    from repro.configs import SHAPES, shape_applicable
    runs = {a: shape_applicable(get_config(a), SHAPES["long_500k"])[0]
            for a in ARCHS}
    assert runs == {
        "h2o-danube-3-4b": True,          # pure SWA
        "llama3-405b": False,
        "command-r-plus-104b": False,
        "gemma3-27b": True,               # 5:1 local:global
        "arctic-480b": False,
        "kimi-k2-1t-a32b": False,
        "qwen2-vl-2b": False,
        "hymba-1.5b": True,               # hybrid
        "xlstm-350m": True,               # recurrent
        "seamless-m4t-large-v2": False,
    }


def test_moe_groupwise_matches_global_dispatch():
    """The GShard-style per-row dispatch must agree with the global-sort
    path up to capacity-dropping differences (none at low load)."""
    import jax.numpy as jnp
    from repro.models import moe as moe_mod
    cfg = get_smoke("kimi-k2-1t-a32b").replace(dtype="float32",
                                               capacity_factor=4.0)
    spec = cfg.plan()[-1].pattern[0][0]
    params = models.init(cfg, jax.random.key(0))
    # one decoder moe layer's params
    seg = params["decoder"][-1]["e0"]
    layer_moe = jax.tree.map(lambda p: p[0], seg["moe"])
    layer_moe = {k: v for k, v in layer_moe.items() if k != "shared"}
    b, s, d = 2, moe_mod.GROUPWISE_MIN_TOKENS, cfg.d_model
    x = jax.random.normal(jax.random.key(1), (b, s, d), jnp.float32) * 0.3
    y_grouped, _ = moe_mod.moe_ffn(layer_moe, x, cfg, spec)
    yt, _, _ = moe_mod._moe_tokens(layer_moe, x.reshape(b * s, d), cfg)
    y_global = yt.reshape(b, s, d)
    # generous capacity => no drops on either path => identical routing
    np.testing.assert_allclose(np.asarray(y_grouped), np.asarray(y_global),
                               atol=2e-4, rtol=2e-4)
