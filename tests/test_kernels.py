"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _jax_caps import HAVE_PALLAS_API, PALLAS_SKIP_REASON
from repro.kernels import ops, ref

pytestmark = pytest.mark.skipif(not HAVE_PALLAS_API,
                                reason=PALLAS_SKIP_REASON)


def _assert_close(a, b, dtype, tol_f32=2e-5, tol_bf16=2e-2):
    tol = tol_bf16 if dtype == jnp.bfloat16 else tol_f32
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# flash attention (prefill/training)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,hkv,dh", [
    (1, 128, 4, 4, 64),       # MHA
    (2, 256, 8, 2, 64),       # GQA 4:1
    (1, 192, 4, 1, 32),       # MQA, ragged seq vs 128 blocks
    (2, 64, 2, 2, 128),       # short seq, wide head
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, h, hkv, dh, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, dh), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, dh), dtype)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention_ref(jnp.moveaxis(q, 2, 1),
                                   jnp.moveaxis(k, 2, 1),
                                   jnp.moveaxis(v, 2, 1), causal=True)
    _assert_close(out, jnp.moveaxis(want, 1, 2), dtype)


@pytest.mark.parametrize("window", [32, 64])
def test_flash_attention_window(window):
    b, s, h, dh = 1, 256, 4, 64
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, h, dh))
    v = jax.random.normal(ks[2], (b, s, h, dh))
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              interpret=True)
    want = ref.flash_attention_ref(jnp.moveaxis(q, 2, 1),
                                   jnp.moveaxis(k, 2, 1),
                                   jnp.moveaxis(v, 2, 1),
                                   causal=True, window=window)
    _assert_close(out, jnp.moveaxis(want, 1, 2), jnp.float32)


# ---------------------------------------------------------------------------
# decode attention (flash-decoding, split-KV)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,hkv,dh,t,qpos", [
    (2, 8, 2, 64, 256, 200),
    (1, 4, 4, 64, 128, 5),      # near-empty cache
    (3, 4, 1, 128, 384, 380),   # MQA, nearly full
])
@pytest.mark.parametrize("window", [-1, 64])
def test_decode_attention_sweep(b, h, hkv, dh, t, qpos, window):
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (b, 1, h, dh))
    ck = jax.random.normal(ks[1], (b, t, hkv, dh))
    cv = jax.random.normal(ks[2], (b, t, hkv, dh))
    kpos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    # slots past qpos are "unwritten" — mark invalid
    kpos = jnp.where(kpos <= qpos, kpos, -1)
    qp = jnp.full((b,), qpos)
    out = ops.decode_attention(q, ck, cv, kpos, qp, window=window,
                               interpret=True)
    qg = q.reshape(b, hkv, h // hkv, dh)
    want = ref.decode_attention_ref(qg, jnp.moveaxis(ck, 2, 1),
                                    jnp.moveaxis(cv, 2, 1), kpos,
                                    qp[:, None], window=window)
    _assert_close(out.reshape(b, hkv, h // hkv, dh), want, jnp.float32)


# ---------------------------------------------------------------------------
# grouped matmul (MoE experts)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("e,c,d,f", [
    (4, 64, 128, 256),
    (8, 32, 64, 64),
    (2, 128, 256, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_sweep(e, c, d, f, dtype):
    ks = jax.random.split(jax.random.key(3), 2)
    x = jax.random.normal(ks[0], (e, c, d), dtype)
    w = jax.random.normal(ks[1], (e, d, f), dtype)
    counts = jnp.array([c, c // 2, 0, 1][:e].ljust if False else
                       [min(c, max(0, c - i * (c // max(e - 1, 1))))
                        for i in range(e)])
    out = ops.grouped_matmul(x, w, counts, interpret=True)
    want = ref.grouped_matmul_ref(x, w, counts)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol * d, rtol=tol)


def test_grouped_matmul_empty_experts_are_zero():
    x = jax.random.normal(jax.random.key(4), (4, 16, 32))
    w = jax.random.normal(jax.random.key(5), (4, 32, 64))
    counts = jnp.array([16, 0, 3, 0])
    out = np.asarray(ops.grouped_matmul(x, w, counts, interpret=True))
    assert np.all(out[1] == 0) and np.all(out[3] == 0)
    assert np.all(out[2, 3:] == 0)          # rows past count zeroed


# ---------------------------------------------------------------------------
# chunked SSM scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,t,dk,dv,chunk", [
    (1, 2, 128, 16, 16, 32),
    (2, 4, 96, 32, 16, 32),     # ragged tail chunk
    (1, 1, 64, 64, 64, 64),     # single chunk
])
def test_ssm_scan_sweep(b, h, t, dk, dv, chunk):
    ks = jax.random.split(jax.random.key(6), 4)
    q = jax.random.normal(ks[0], (b, t, h, dk)) * 0.3
    k = jax.random.normal(ks[1], (b, t, h, dk)) * 0.3
    v = jax.random.normal(ks[2], (b, t, h, dv)) * 0.3
    log_a = -jax.random.uniform(ks[3], (b, t, h)) * 0.1
    h0 = jnp.zeros((b, h, dk, dv))
    y, hT = ops.ssm_scan(q, k, v, log_a, h0, chunk=chunk, interpret=True)
    y_ref, hT_ref = ref.ssm_scan_ref(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
        jnp.moveaxis(log_a, 2, 1)[..., None], h0)
    _assert_close(y, jnp.moveaxis(y_ref, 1, 2), jnp.float32, tol_f32=1e-4)
    _assert_close(hT, hT_ref, jnp.float32, tol_f32=1e-4)


def test_ssm_scan_nonzero_initial_state():
    b, h, t, dk, dv = 1, 2, 64, 16, 16
    ks = jax.random.split(jax.random.key(7), 5)
    q = jax.random.normal(ks[0], (b, t, h, dk)) * 0.3
    k = jax.random.normal(ks[1], (b, t, h, dk)) * 0.3
    v = jax.random.normal(ks[2], (b, t, h, dv)) * 0.3
    log_a = -jax.random.uniform(ks[3], (b, t, h)) * 0.05
    h0 = jax.random.normal(ks[4], (b, h, dk, dv)) * 0.5
    y, hT = ops.ssm_scan(q, k, v, log_a, h0, chunk=16, interpret=True)
    y_ref, hT_ref = ref.ssm_scan_ref(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
        jnp.moveaxis(log_a, 2, 1)[..., None], h0)
    _assert_close(y, jnp.moveaxis(y_ref, 1, 2), jnp.float32, tol_f32=1e-4)
    _assert_close(hT, hT_ref, jnp.float32, tol_f32=1e-4)
