"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _jax_caps import HAVE_PALLAS_API, PALLAS_SKIP_REASON
from repro.kernels import ops, ref

pytestmark = pytest.mark.skipif(not HAVE_PALLAS_API,
                                reason=PALLAS_SKIP_REASON)


def _assert_close(a, b, dtype, tol_f32=2e-5, tol_bf16=2e-2):
    tol = tol_bf16 if dtype == jnp.bfloat16 else tol_f32
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# flash attention (prefill/training)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,hkv,dh", [
    (1, 128, 4, 4, 64),       # MHA
    (2, 256, 8, 2, 64),       # GQA 4:1
    (1, 192, 4, 1, 32),       # MQA, ragged seq vs 128 blocks
    (2, 64, 2, 2, 128),       # short seq, wide head
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, h, hkv, dh, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, dh), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, dh), dtype)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention_ref(jnp.moveaxis(q, 2, 1),
                                   jnp.moveaxis(k, 2, 1),
                                   jnp.moveaxis(v, 2, 1), causal=True)
    _assert_close(out, jnp.moveaxis(want, 1, 2), dtype)


@pytest.mark.parametrize("window", [32, 64])
def test_flash_attention_window(window):
    b, s, h, dh = 1, 256, 4, 64
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, h, dh))
    v = jax.random.normal(ks[2], (b, s, h, dh))
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              interpret=True)
    want = ref.flash_attention_ref(jnp.moveaxis(q, 2, 1),
                                   jnp.moveaxis(k, 2, 1),
                                   jnp.moveaxis(v, 2, 1),
                                   causal=True, window=window)
    _assert_close(out, jnp.moveaxis(want, 1, 2), jnp.float32)


# ---------------------------------------------------------------------------
# decode attention (flash-decoding, split-KV)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,hkv,dh,t,qpos", [
    (2, 8, 2, 64, 256, 200),
    (1, 4, 4, 64, 128, 5),      # near-empty cache
    (3, 4, 1, 128, 384, 380),   # MQA, nearly full
])
@pytest.mark.parametrize("window", [-1, 64])
def test_decode_attention_sweep(b, h, hkv, dh, t, qpos, window):
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (b, 1, h, dh))
    ck = jax.random.normal(ks[1], (b, t, hkv, dh))
    cv = jax.random.normal(ks[2], (b, t, hkv, dh))
    kpos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    # slots past qpos are "unwritten" — mark invalid
    kpos = jnp.where(kpos <= qpos, kpos, -1)
    qp = jnp.full((b,), qpos)
    out = ops.decode_attention(q, ck, cv, kpos, qp, window=window,
                               interpret=True)
    qg = q.reshape(b, hkv, h // hkv, dh)
    want = ref.decode_attention_ref(qg, jnp.moveaxis(ck, 2, 1),
                                    jnp.moveaxis(cv, 2, 1), kpos,
                                    qp[:, None], window=window)
    _assert_close(out.reshape(b, hkv, h // hkv, dh), want, jnp.float32)


def test_decode_attention_tail_not_truncated():
    """Regression: the low-level kernel used nk = t // blk_k, silently
    dropping the last t % blk_k keys from the softmax whenever the cache
    length was not block-divisible."""
    da = importlib.import_module("repro.kernels.decode_attention")
    b, hkv, g, dh, t, blk = 2, 1, 8, 128, 200, 128
    ks = jax.random.split(jax.random.key(8), 3)
    q = jax.random.normal(ks[0], (b, hkv, g, dh))
    k = jax.random.normal(ks[1], (b, hkv, t, dh))
    v = jax.random.normal(ks[2], (b, hkv, t, dh))
    kpos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    qp = jnp.full((b, 1), t - 1)
    out = da.decode_attention(q, k, v, kpos, qp, blk_k=blk, interpret=True)
    want = ref.decode_attention_ref(q, k, v, kpos, qp)
    _assert_close(out, want, jnp.float32)
    # the truncated-softmax bug reproduced by masking the tail away:
    # results must actually depend on those last t % blk_k keys
    trunc = ref.decode_attention_ref(q, k, v,
                                     jnp.where(kpos < blk, kpos, -1), qp)
    assert float(jnp.abs(want - trunc).max()) > 1e-2


# ---------------------------------------------------------------------------
# paged decode attention (block-table indirection over a KV page pool)
# ---------------------------------------------------------------------------

def _paged_case(b, hkv, g, dh, page, per_seq, shared=0, seed=9):
    """Pool + block tables: ``shared`` leading physical pages appear in
    every row (a cached prefix), the rest are per-sequence private."""
    n = shared + b * (per_seq - shared)
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (b, 1, hkv * g, dh))
    k_pages = jax.random.normal(ks[1], (n, page, hkv, dh))
    v_pages = jax.random.normal(ks[2], (n, page, hkv, dh))
    rows, nxt = [], shared
    for _ in range(b):
        rows.append(list(range(shared))
                    + list(range(nxt, nxt + per_seq - shared)))
        nxt += per_seq - shared
    return q, k_pages, v_pages, jnp.asarray(rows, jnp.int32)


def _check_paged(q, k_pages, v_pages, bt, ctx, window=-1):
    b, _, h, dh = q.shape
    hkv = k_pages.shape[2]
    out = ops.paged_decode_attention(q, k_pages, v_pages, bt, ctx,
                                     window=window, interpret=True)
    want = ref.paged_decode_attention_ref(q.reshape(b, hkv, h // hkv, dh),
                                          k_pages, v_pages, bt, ctx,
                                          window=window)
    _assert_close(out.reshape(b, hkv, h // hkv, dh), want, q.dtype)


@pytest.mark.parametrize("b,hkv,g,dh,page,per_seq", [
    (2, 2, 4, 64, 16, 4),       # GQA
    (1, 1, 1, 128, 32, 3),      # MQA, single row, wide head
    (3, 4, 2, 32, 16, 5),
])
@pytest.mark.parametrize("aligned", [True, False])
def test_paged_decode_attention_sweep(b, hkv, g, dh, page, per_seq,
                                      aligned):
    q, kp, vp, bt = _paged_case(b, hkv, g, dh, page, per_seq)
    full = per_seq * page
    ctx = jnp.full((b,), full, jnp.int32) if aligned else \
        jnp.asarray([full - 1 - 7 * i for i in range(b)], jnp.int32)
    _check_paged(q, kp, vp, bt, ctx)


def test_paged_decode_attention_shared_prefix_rows():
    b, hkv, g, dh, page, per_seq = 3, 2, 2, 64, 16, 6
    q, kp, vp, bt = _paged_case(b, hkv, g, dh, page, per_seq, shared=2)
    ctx = jnp.asarray([per_seq * page, per_seq * page - 5, 2 * page + 3],
                      jnp.int32)
    _check_paged(q, kp, vp, bt, ctx)
    # two rows given identical tables, lengths AND query must agree
    # exactly — the prefix really is one physical copy
    bt2 = bt.at[1].set(bt[0])
    q2 = q.at[1].set(q[0])
    ctx2 = ctx.at[1].set(ctx[0])
    out = ops.paged_decode_attention(q2, kp, vp, bt2, ctx2, interpret=True)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out[1]))


def test_paged_decode_attention_unmapped_tail():
    # rows of different logical length: short rows carry -1 page ids,
    # which must contribute nothing to the softmax
    b, hkv, g, dh, page = 2, 2, 2, 64, 16
    q, kp, vp, bt = _paged_case(b, hkv, g, dh, page, per_seq=4)
    bt = bt.at[1, 2:].set(-1)                  # row 1 maps only 2 pages
    ctx = jnp.asarray([4 * page - 2, page + 5], jnp.int32)
    _check_paged(q, kp, vp, bt, ctx)


@pytest.mark.parametrize("window", [24, 64])
def test_paged_decode_attention_window(window):
    b, hkv, g, dh, page = 2, 2, 4, 64, 16
    q, kp, vp, bt = _paged_case(b, hkv, g, dh, page, per_seq=5)
    ctx = jnp.asarray([5 * page - 3, 3 * page + 9], jnp.int32)
    _check_paged(q, kp, vp, bt, ctx, window=window)


def test_paged_decode_attention_bf16():
    b, hkv, g, dh, page = 2, 2, 4, 64, 16
    q, kp, vp, bt = _paged_case(b, hkv, g, dh, page, per_seq=4)
    ctx = jnp.asarray([4 * page, 3 * page - 6], jnp.int32)
    q, kp, vp = (x.astype(jnp.bfloat16) for x in (q, kp, vp))
    _check_paged(q, kp, vp, bt, ctx)


# ---------------------------------------------------------------------------
# grouped matmul (MoE experts)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("e,c,d,f", [
    (4, 64, 128, 256),
    (8, 32, 64, 64),
    (2, 128, 256, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_sweep(e, c, d, f, dtype):
    ks = jax.random.split(jax.random.key(3), 2)
    x = jax.random.normal(ks[0], (e, c, d), dtype)
    w = jax.random.normal(ks[1], (e, d, f), dtype)
    counts = jnp.array([c, c // 2, 0, 1][:e].ljust if False else
                       [min(c, max(0, c - i * (c // max(e - 1, 1))))
                        for i in range(e)])
    out = ops.grouped_matmul(x, w, counts, interpret=True)
    want = ref.grouped_matmul_ref(x, w, counts)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol * d, rtol=tol)


def test_grouped_matmul_empty_experts_are_zero():
    x = jax.random.normal(jax.random.key(4), (4, 16, 32))
    w = jax.random.normal(jax.random.key(5), (4, 32, 64))
    counts = jnp.array([16, 0, 3, 0])
    out = np.asarray(ops.grouped_matmul(x, w, counts, interpret=True))
    assert np.all(out[1] == 0) and np.all(out[3] == 0)
    assert np.all(out[2, 3:] == 0)          # rows past count zeroed


# ---------------------------------------------------------------------------
# chunked SSM scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,t,dk,dv,chunk", [
    (1, 2, 128, 16, 16, 32),
    (2, 4, 96, 32, 16, 32),     # ragged tail chunk
    (1, 1, 64, 64, 64, 64),     # single chunk
])
def test_ssm_scan_sweep(b, h, t, dk, dv, chunk):
    ks = jax.random.split(jax.random.key(6), 4)
    q = jax.random.normal(ks[0], (b, t, h, dk)) * 0.3
    k = jax.random.normal(ks[1], (b, t, h, dk)) * 0.3
    v = jax.random.normal(ks[2], (b, t, h, dv)) * 0.3
    log_a = -jax.random.uniform(ks[3], (b, t, h)) * 0.1
    h0 = jnp.zeros((b, h, dk, dv))
    y, hT = ops.ssm_scan(q, k, v, log_a, h0, chunk=chunk, interpret=True)
    y_ref, hT_ref = ref.ssm_scan_ref(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
        jnp.moveaxis(log_a, 2, 1)[..., None], h0)
    _assert_close(y, jnp.moveaxis(y_ref, 1, 2), jnp.float32, tol_f32=1e-4)
    _assert_close(hT, hT_ref, jnp.float32, tol_f32=1e-4)


def test_ssm_scan_nonzero_initial_state():
    b, h, t, dk, dv = 1, 2, 64, 16, 16
    ks = jax.random.split(jax.random.key(7), 5)
    q = jax.random.normal(ks[0], (b, t, h, dk)) * 0.3
    k = jax.random.normal(ks[1], (b, t, h, dk)) * 0.3
    v = jax.random.normal(ks[2], (b, t, h, dv)) * 0.3
    log_a = -jax.random.uniform(ks[3], (b, t, h)) * 0.05
    h0 = jax.random.normal(ks[4], (b, h, dk, dv)) * 0.5
    y, hT = ops.ssm_scan(q, k, v, log_a, h0, chunk=16, interpret=True)
    y_ref, hT_ref = ref.ssm_scan_ref(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
        jnp.moveaxis(log_a, 2, 1)[..., None], h0)
    _assert_close(y, jnp.moveaxis(y_ref, 1, 2), jnp.float32, tol_f32=1e-4)
    _assert_close(hT, hT_ref, jnp.float32, tol_f32=1e-4)
