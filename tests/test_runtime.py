"""Fault tolerance: supervised restart bit-exactness, heartbeats,
stragglers, elastic scaling, serving failover."""
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, TokenPipeline
from repro.runtime import (ElasticGroup, HeartbeatMonitor, SimulatedFailure,
                           StragglerPolicy, TrainSupervisor)
from repro.runtime.heartbeat import attach_engine
from repro.runtime.supervisor import SupervisorConfig
from repro.sim.clock import EventLoop


# ---------------------------------------------------------------------------
# Supervisor: crash mid-training, resume, identical trajectory
# ---------------------------------------------------------------------------

def _toy_step(state, batch, step):
    # state: {"w": vector} — deterministic "training" on batch stats
    inc = float(batch["tokens"].mean()) * 1e-3
    return {"w": state["w"] + inc, "n": state["n"] + 1}


def test_supervisor_restart_bit_exact(tmp_path):
    data_cfg = DataConfig(vocab=100, seq_len=8, global_batch=2)

    # uninterrupted reference
    ref_mgr = CheckpointManager(tmp_path / "ref", keep=2)
    sup0 = TrainSupervisor(ref_mgr, SupervisorConfig(ckpt_every=5,
                                                     async_ckpt=False))
    ref = sup0.run(state={"w": np.zeros(()), "n": np.zeros((), np.int64)},
                   pipeline=TokenPipeline(data_cfg), step_fn=_toy_step,
                   total_steps=20)

    # crashy run: fails at steps 7 and 13
    mgr = CheckpointManager(tmp_path / "crashy", keep=2)
    sup = TrainSupervisor(mgr, SupervisorConfig(ckpt_every=5,
                                                async_ckpt=False))
    fail_at = {7, 13}
    calls = {"n": 0}

    def crashy(state, batch, step):
        calls["n"] += 1
        if step in fail_at:
            fail_at.discard(step)
            raise SimulatedFailure(f"chaos at {step}")
        return _toy_step(state, batch, step)

    got = sup.run(state={"w": np.zeros(()), "n": np.zeros((), np.int64)},
                  pipeline=TokenPipeline(data_cfg), step_fn=crashy,
                  total_steps=20)
    assert sup.restarts == 2
    np.testing.assert_allclose(got["w"], ref["w"], rtol=0, atol=0)
    assert int(got["n"]) == int(ref["n"]) == 20


def test_supervisor_restart_budget(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    sup = TrainSupervisor(mgr, SupervisorConfig(ckpt_every=100,
                                                max_restarts=2))

    def always_fail(state, batch, step):
        raise SimulatedFailure("doomed")

    with pytest.raises(RuntimeError):
        sup.run(state={"w": np.zeros(())},
                pipeline=TokenPipeline(
                    DataConfig(vocab=10, seq_len=4, global_batch=1)),
                step_fn=always_fail, total_steps=5)


# ---------------------------------------------------------------------------
# Heartbeats
# ---------------------------------------------------------------------------

def test_heartbeat_failure_and_recovery():
    loop = EventLoop()
    mon = HeartbeatMonitor(loop, miss_timeout=1.0, check_interval=0.2)
    events = []
    mon.on_failure = lambda n: events.append(("fail", n, loop.now()))
    mon.on_recovery = lambda n: events.append(("recover", n, loop.now()))
    mon.watch("eng0")
    mon.start()
    # beats until t=0.5, then silence
    for t in (0.1, 0.3, 0.5):
        loop.call_at(t, lambda: mon.beat("eng0"))
    loop.call_at(3.0, lambda: mon.beat("eng0"))     # comes back
    loop.run_until(4.0)
    kinds = [e[0] for e in events]
    assert kinds == ["fail", "recover"]
    assert 1.5 <= events[0][2] <= 2.0


def test_heartbeat_attach_engine():
    from repro.configs import get_config
    from repro.core.types import Request
    from repro.serving.engine_sim import SimEngine
    from repro.serving.scheduler import SchedulerConfig
    from repro.sim.costmodel import CostModel
    loop = EventLoop()
    mon = HeartbeatMonitor(loop, miss_timeout=5.0)
    eng = SimEngine(loop, CostModel(get_config("agent-7b"), chips=4),
                    SchedulerConfig(max_slots=2, num_pages=64))
    attach_engine(mon, eng)
    eng.submit(Request(prompt_len=8, max_new_tokens=2))
    loop.run_until(10.0)
    assert mon.last_beat["sim-engine"] > 0


# ---------------------------------------------------------------------------
# Straggler detection
# ---------------------------------------------------------------------------

def test_straggler_demotes_slow_instance():
    from repro.core.metrics import CentralPoller, Collector, StateStore
    from repro.core.registry import Registry
    from repro.core.controller import Controller
    from tests.test_controller import FakeKnobbed

    loop = EventLoop()
    reg = Registry()
    fast = FakeKnobbed("t0")
    slow = FakeKnobbed("t1")
    fast.values["admit_priority_min"] = 0
    slow.values["admit_priority_min"] = 0
    reg.register(fast)
    reg.register(slow)
    store = StateStore()
    poller = CentralPoller(store, window=10.0)
    col = Collector()
    poller.attach(col)
    c = Controller(loop, reg, poller, interval=0.1)
    pol = StragglerPolicy(["t0", "t1"], ratio=2.0, window=10.0)
    c.install(pol)
    for i in range(10):
        col.observe("t0.step_time", 0.01, 0.1 * i)
        col.observe("t1.step_time", 0.08, 0.1 * i)   # 8x slower
    c.start()
    loop.run_until(1.0)
    assert "t1" in pol.demoted
    assert slow.values["admit_priority_min"] == 1
    # straggler recovers
    for i in range(40):
        col.observe("t1.step_time", 0.01, 1.0 + 0.1 * i)
    loop.run_until(8.0)
    assert "t1" not in pol.demoted
    assert slow.values["admit_priority_min"] == 0


# ---------------------------------------------------------------------------
# Elastic scaling + serving failover
# ---------------------------------------------------------------------------

def _pipeline(n_testers=2):
    from repro.agents import AgenticPipeline, PipelineConfig
    return AgenticPipeline(PipelineConfig(n_testers=n_testers))


def test_elastic_scale_up():
    p = _pipeline(1)
    grp = ElasticGroup(p)
    name = grp.scale_up()
    assert name == "tester-1"
    assert len(p.router.instances) == 2
    assert name in p.registry.names()


def test_failover_requeues_and_reroutes():
    from repro.agents import TaskSpec
    p = _pipeline(2)
    grp = ElasticGroup(p)
    # run some sessions so tester-0 owns state
    for i in range(6):
        p.submit(TaskSpec(session=f"fs-{i}", n_functions=2, func_tokens=16,
                          test_tokens=16))
    p.run(until=3.0)
    victim = p.testers[0].name
    moved = grp.fail_over(victim)
    assert victim not in p.router.instances
    # all session homes now point at survivors
    for rec in p.directory.records.values():
        assert rec.instance != victim
    p.loop.run_until(60.0)
    # pipeline still makes progress after the failure
    assert len(p.done) >= 1
