"""Prefix-cache plane: digest chains, refcounted shared pages, admission
reuse, eviction/pinning knobs, cache-aware routing, and the intent-v2
``pin`` action end-to-end through the pipeline."""
import random

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # pragma: no cover - env dependent
    HAVE_HYPOTHESIS = False

    def settings(**kw):                 # no-op decorators so module-level
        return lambda fn: fn            # @settings/@given still evaluate

    def given(*a, **kw):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def skipped():              # zero-arg: no fixture resolution
                pass
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **kw: None

    st = _AnyStrategy()

from repro.configs import get_config
from repro.core.types import Message, Request, RequestState
from repro.serving.engine_sim import SimEngine
from repro.serving.kv_cache import PageAllocator
from repro.serving.prefix_cache import (CacheDirectory, PrefixCache,
                                        chain_for)
from repro.serving.router import Router
from repro.serving.scheduler import SchedulerConfig
from repro.sim.clock import EventLoop
from repro.sim.costmodel import CostModel


# ---------------------------------------------------------------------------
# digest chains
# ---------------------------------------------------------------------------

def test_chain_shared_prefix_property():
    a = chain_for((("sys", 256), ("task:a", 100)), 64)
    b = chain_for((("sys", 256), ("task:b", 100)), 64)
    # the 4 blocks fully inside the shared segment agree; the 5th holds
    # private content and diverges
    assert len(a) == len(b) == 5
    assert [x.digest for x in a[:4]] == [y.digest for y in b[:4]]
    assert a[4].digest != b[4].digest
    assert a[0].labels == ("sys",)
    assert a[4].labels == ("task:a",)
    # an unaligned boundary block carries both covering labels
    c = chain_for((("sys", 230), ("task:a", 126)), 64)
    assert set(c[3].labels) == {"sys", "task:a"}


def test_chain_tokens_and_segment_offsets():
    toks = list(range(130))
    c = chain_for(toks, 64)
    assert len(c) == 2                   # trailing partial block dropped
    assert c == chain_for(toks[:128] + [999, 998], 64)[:2]
    # same label, different segment split points -> different chains
    x = chain_for((("s", 64), ("t", 64)), 64)
    y = chain_for((("s", 32), ("t", 96)), 64)
    assert x[1].digest != y[1].digest


# ---------------------------------------------------------------------------
# PageAllocator refcount invariants
# ---------------------------------------------------------------------------

def _conserved(a: PageAllocator) -> bool:
    return (a.free_pages + a.private_pages + a.shared_pages == a.num_pages
            and a.free_pages >= 0)


def test_allocator_share_acquire_free_drop():
    a = PageAllocator(num_pages=10, page_size=64)
    assert a.share("b0", 2) and a.block_resident("b0")
    assert a.idle_pages == 2 and a.shared_pages == 2
    assert a.acquire("s1", "b0") and a.block_refs("b0") == 1
    assert a.acquire("s1", "b0") and a.block_refs("b0") == 1   # idempotent
    assert a.acquire("s2", "b0") and a.block_refs("b0") == 2
    assert not a.drop_block("b0")        # referenced: not evictable
    a.free("s1")
    a.free("s2")
    assert a.block_refs("b0") == 0 and a.block_resident("b0")
    assert a.drop_block("b0") and a.free_pages == 10
    assert _conserved(a)


def test_allocator_promote_moves_private_to_shared():
    a = PageAllocator(num_pages=10, page_size=64)
    assert a.allocate("s1", 64 * 6)      # 6 private pages
    assert a.promote("s1", "blk", 2)
    assert a.holds("s1") == 4 and a.shared_pages == 2
    assert a.block_refs("blk") == 1 and _conserved(a)
    # a second promoter of the same block just references it
    assert a.allocate("s2", 64)
    assert a.promote("s2", "blk", 2)
    assert a.holds("s2") == 1 and a.block_refs("blk") == 2
    assert not a.promote("s2", "blk2", 99)   # more than it holds
    assert _conserved(a)


def _random_walk(a: PageAllocator, ops):
    blocks = [f"b{i}" for i in range(4)]
    seqs = [f"s{i}" for i in range(4)]
    for op, i, n in ops:
        if op == "alloc":
            a.allocate(seqs[i % 4], n)
        elif op == "share":
            a.share(blocks[i % 4], 1 + n % 3)
        elif op == "acquire":
            a.acquire(seqs[i % 4], blocks[n % 4])
        elif op == "promote":
            a.promote(seqs[i % 4], blocks[n % 4], 1 + n % 2)
        elif op == "free":
            a.free(seqs[i % 4])
        elif op == "drop":
            a.drop_block(blocks[i % 4])
        assert _conserved(a), (op, i, n)
        for b in blocks:
            assert a.block_refs(b) >= 0


def test_allocator_conservation_random_walk():
    """Deterministic stand-in for the hypothesis property (runs even
    where hypothesis is not installed)."""
    rng = random.Random(7)
    kinds = ["alloc", "share", "acquire", "promote", "free", "drop"]
    for trial in range(50):
        a = PageAllocator(num_pages=12, page_size=64)
        ops = [(rng.choice(kinds), rng.randrange(4), rng.randrange(500))
               for _ in range(60)]
        _random_walk(a, ops)


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(
    st.sampled_from(["alloc", "share", "acquire", "promote", "free",
                     "drop"]),
    st.integers(0, 3), st.integers(0, 500)), max_size=60))
def test_allocator_conservation_property(ops):
    """Total pages conserved under any allocate/share/promote/free/drop
    interleaving; refcounts never go negative."""
    _random_walk(PageAllocator(num_pages=12, page_size=64), ops)


# ---------------------------------------------------------------------------
# PrefixCache over a SimEngine: admission reuse
# ---------------------------------------------------------------------------

def _engine(block_tokens=64, num_pages=1024, reserve_frac=0.5, slots=8,
            evict_policy="lru"):
    loop = EventLoop()
    cm = CostModel(get_config("agent-7b"), chips=4)
    cfg = SchedulerConfig(max_slots=slots, num_pages=num_pages,
                          max_context=8192, page_size=64)
    eng = SimEngine(loop, cm, cfg, name="eng")
    cache = PrefixCache(eng.scheduler.alloc, name="eng.cache",
                        instance="eng", block_tokens=block_tokens,
                        reserve_frac=reserve_frac,
                        evict_policy=evict_policy, clock=loop.now)
    eng.attach_cache(cache)
    return loop, eng, cache


def _freq(shared, tag, suffix=64, gen=4):
    return Request(prompt_len=shared + suffix, max_new_tokens=gen,
                   meta={"prefix": (("ctx", shared), (f"p:{tag}", suffix))})


def test_admission_reuses_committed_prefix():
    loop, eng, cache = _engine()
    r0 = _freq(512, "a")
    eng.submit(r0)
    loop.run_until(100.0)
    assert r0.state == RequestState.FINISHED
    assert r0.meta["cached_prompt_tokens"] == 0
    r1 = _freq(512, "b")
    eng.submit(r1)
    loop.run_until(200.0)
    assert r1.state == RequestState.FINISHED
    assert r1.meta["cached_prompt_tokens"] == 512
    assert cache.saved_prefill_tokens == 512
    assert 0 < cache.hit_rate < 1


def test_cached_fanout_charges_under_70pct_and_is_faster():
    """The acceptance-bar scenario in miniature: warm prefix, then a
    fan-out; >=30% of prefill tokens must come from the cache."""
    def run(enabled):
        loop, eng, cache = _engine()
        cache.enabled = enabled
        warm = _freq(1024, "warm")
        eng.submit(warm)
        loop.run_until(100.0)
        t0 = loop.now()
        reqs = [_freq(1024, f"w{i}") for i in range(8)]
        for r in reqs:
            eng.submit(r)
        loop.run_until(1e4)
        assert all(r.done for r in reqs)
        charged = sum(r.prompt_len - r.meta.get("cached_prompt_tokens", 0)
                      for r in reqs)
        return charged, max(r.finish_time for r in reqs) - t0

    charged_off, span_off = run(False)
    charged_on, span_on = run(True)
    assert charged_on <= 0.7 * charged_off
    assert span_on < span_off


def test_disabled_cache_never_matches():
    loop, eng, cache = _engine()
    cache.set_param("enabled", False)
    for tag in ("a", "b"):
        eng.submit(_freq(512, tag))
    loop.run_until(200.0)
    assert cache.saved_prefill_tokens == 0
    assert cache.blocks_resident == 0


def test_full_block_aligned_prompt_still_prefils_last_token():
    """A prompt whose every block is resident must still recompute the
    final token (first-token logits), never admit at prefilled==prompt."""
    loop, eng, cache = _engine()
    r0 = Request(prompt_len=256, max_new_tokens=2,
                 meta={"prefix": (("ctx", 256),)})
    eng.submit(r0)
    loop.run_until(100.0)
    r1 = Request(prompt_len=256, max_new_tokens=2,
                 meta={"prefix": (("ctx", 256),)})
    eng.submit(r1)
    loop.run_until(200.0)
    assert r1.state == RequestState.FINISHED
    assert r1.meta["cached_prompt_tokens"] == 192   # capped < prompt_len


# ---------------------------------------------------------------------------
# eviction, reservation, pinning
# ---------------------------------------------------------------------------

def test_reserve_frac_caps_idle_pages():
    loop, eng, cache = _engine(num_pages=64, reserve_frac=0.1)
    for tag in range(8):
        eng.submit(_freq(256, str(tag), suffix=64))
        loop.run_until(loop.now() + 50.0)
    assert eng.scheduler.alloc.idle_pages <= 0.1 * 64
    assert cache.evictions > 0


def test_lru_vs_lfu_eviction_order():
    for policy, survivor in (("lru", "hot"), ("lfu", "hot")):
        loop, eng, cache = _engine(num_pages=4096, reserve_frac=1.0,
                                   evict_policy=policy)
        # hot prefix used 3x, cold once
        for tag in ("h0", "h1", "h2"):
            eng.submit(Request(prompt_len=128 + 64, max_new_tokens=2,
                               meta={"prefix": (("hot", 128),
                                                (f"p:{tag}", 64))}))
            loop.run_until(loop.now() + 50.0)
        eng.submit(Request(prompt_len=128 + 64, max_new_tokens=2,
                           meta={"prefix": (("cold", 128), ("p:c", 64))}))
        loop.run_until(loop.now() + 50.0)
        assert cache.evict_one()          # evicts a cold-side block
        assert cache.probe((("hot", 128),)) == 128


def test_pin_blocks_survive_make_room_and_unpin_releases():
    loop, eng, cache = _engine(num_pages=4096, reserve_frac=1.0)
    eng.submit(_freq(256, "a"))
    loop.run_until(100.0)
    assert cache.pin("ctx") > 0
    drained = 0
    while cache.evict_one():
        drained += 1
    assert cache.probe((("ctx", 256),)) == 256   # pinned chain intact
    assert cache.unpin("ctx") > 0
    while cache.evict_one():
        pass
    assert cache.probe((("ctx", 256),)) == 0
    assert _conserved(eng.scheduler.alloc)


def test_admission_survives_evicting_its_own_probed_blocks():
    """Regression: _admissible's make_room could evict the admitting
    request's own idle prefix blocks between probe and begin; _admit
    must degrade (requeue) instead of crashing on the stale estimate."""
    loop, eng, cache = _engine(num_pages=4, reserve_frac=1.0, slots=4)
    a = Request(prompt_len=191, max_new_tokens=1,
                meta={"prefix": (("p", 128), ("a", 63))})
    eng.submit(a)
    loop.run_until(100.0)
    assert a.state == RequestState.FINISHED
    assert eng.scheduler.alloc.idle_pages == 2          # p's two blocks
    b = Request(prompt_len=100, max_new_tokens=28)      # occupies the rest
    eng.submit(b)
    loop.run_until(loop.now() + 0.05)
    assert b.state in (RequestState.PREFILL, RequestState.RUNNING)
    c = Request(prompt_len=191, max_new_tokens=1,
                meta={"prefix": (("p", 128), ("c", 63))})
    eng.submit(c)                                        # must not crash
    loop.run_until(loop.now() + 1000.0)
    assert b.state == RequestState.FINISHED
    assert c.state == RequestState.FINISHED
    assert _conserved(eng.scheduler.alloc)


def test_admission_evicts_idle_blocks_when_pool_full():
    loop, eng, cache = _engine(num_pages=16, reserve_frac=1.0)
    eng.submit(_freq(512, "a", suffix=64, gen=2))    # 512+64+2 -> 10 pages
    loop.run_until(100.0)
    assert eng.scheduler.alloc.idle_pages > 0
    # a different prefix needs the whole pool: idle blocks must go
    big = Request(prompt_len=640, max_new_tokens=2,
                  meta={"prefix": (("other", 640),)})
    eng.submit(big)
    loop.run_until(300.0)
    assert big.state == RequestState.FINISHED
    assert _conserved(eng.scheduler.alloc)


# ---------------------------------------------------------------------------
# cache-aware routing
# ---------------------------------------------------------------------------

class _Inst:
    def __init__(self, name, load=0.0):
        self.name = name
        self.msgs = []
        self._load = load

    def deliver(self, msg):
        self.msgs.append(msg)

    def load(self):
        return self._load


def test_router_cache_aware_prefers_resident_prefix():
    loop = EventLoop()
    directory = CacheDirectory()
    a0 = PageAllocator(64, 64)
    a1 = PageAllocator(64, 64)
    c0 = PrefixCache(a0, name="i0.cache", instance="i0",
                     directory=directory, block_tokens=64)
    PrefixCache(a1, name="i1.cache", instance="i1",
                directory=directory, block_tokens=64)
    # make the header resident on i1 only
    seq = Request(prompt_len=129, max_new_tokens=1,
                  meta={"prefix": (("hdr", 128),)})
    cache1 = directory.caches["i1"]
    a1.allocate(seq.req_id, 129)
    cache1.begin(seq, limit=128)
    seq.prefilled = 128
    cache1.commit(seq)
    assert directory.estimate_hit((("hdr", 128),), "i1") == 128
    assert directory.estimate_hit((("hdr", 128),), "i0") == 0

    r = Router(loop, policy="cache_aware", cache_dir=directory,
               prefix_fn=lambda m: (("hdr", 128),))
    i0, i1 = _Inst("i0", load=0.0), _Inst("i1", load=5.0)
    r.add_instance(i0)
    r.add_instance(i1)
    m = Message(src="s", dst="r", payload={"session": "x"}, task_id="t")
    r.deliver(m)
    assert i1.msgs == [m]                # prefix hit beats lower load
    assert r.cache_routed == 1
    # no signal -> falls back to least loaded
    r.prefix_fn = lambda m: None
    m2 = Message(src="s", dst="r", payload={"session": "x"}, task_id="t2")
    r.deliver(m2)
    assert i0.msgs == [m2]
    assert c0 is directory.caches["i0"]


# ---------------------------------------------------------------------------
# intent v2: pin + cache_aware routing end-to-end
# ---------------------------------------------------------------------------

def test_default_pipeline_config_actually_shares_blocks():
    """Regression: the pipeline clamps page/block size to header_tokens,
    so the default config (64-token header) produces real cache hits and
    cache-aware routing gets a usable signal."""
    from repro.agents import AgenticPipeline, PipelineConfig, TaskSpec
    p = AgenticPipeline(PipelineConfig(n_testers=2,
                                       router_policy="cache_aware"))
    for i in range(6):
        p.submit(TaskSpec(session=f"sess-{i % 2}", n_functions=2))
    p.run(until=40.0)
    assert len(p.done) == 6
    assert sum(c.saved_prefill_tokens
               for c in p.cache_dir.caches.values()) > 0
    assert p.router.cache_routed > 0


def test_intent_pin_and_cache_aware_routing_end_to_end():
    from repro.agents import AgenticPipeline, PipelineConfig, TaskSpec
    from repro.core.intent import compile_intent

    # header_tokens must span full pages (128) to be block-shareable
    p = AgenticPipeline(PipelineConfig(n_testers=2, header_tokens=256,
                                       router_policy="cache_aware"))
    prog = compile_intent(
        "rule pin_hot: when last(tester-0.cache.hit_rate) < 0.9 "
        "=> pin system-prompt\n")
    p.controller.install(prog)
    for i in range(6):
        p.submit(TaskSpec(session=f"sess-{i % 2}", n_functions=2))
    p.run(until=40.0)
    assert len(p.done) == 6
    # the rule fired and the pin action reached every registered cache
    assert prog.rules[0].fire_count >= 1
    assert p.controller.action_log("pin")
    pinned = [e for c in p.cache_dir.caches.values()
              for e in c._entries.values() if e.pinned]
    assert pinned, "system-prompt blocks should be pinned"
    assert all("system-prompt" in e.block.labels for e in pinned)
    # cache-aware routing actually used prefix scores, and the shared
    # header was served from cache at least once
    assert p.router.cache_routed > 0
    saved = sum(c.saved_prefill_tokens for c in p.cache_dir.caches.values())
    assert saved > 0
    # knob surface reachable through the registry (Table-1 uniformity)
    p.registry.set("tester-0.cache", "evict_policy", "lfu")
    assert p.registry.get_param("tester-0.cache", "evict_policy") == "lfu"
