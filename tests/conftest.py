"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see the real
single CPU device (the 512-device override is exclusively dryrun.py's)."""
import numpy as np
import pytest


@pytest.fixture(autouse=True)
def _seed():
    np.random.seed(0)
