"""Capability probes for JAX-environment-dependent test modules.

The Pallas kernels and the launch layer are written against the
accelerator toolchain's JAX API surface; on an older CPU-only JAX those
modules fail at the API level (``pltpu.CompilerParams``,
``jax.sharding.AxisType`` / ``jax.set_mesh``) before any numerics run.
These probes detect the exact capabilities the modules use so their
tests gate behind ``pytest.mark.skipif`` — green signal on CPU CI,
full coverage wherever the real toolchain is installed.
"""
from __future__ import annotations


def _why_no_pallas() -> str:
    try:
        import jax  # noqa: F401
        from jax.experimental import pallas as pl  # noqa: F401
        from jax.experimental.pallas import tpu as pltpu
    except Exception as e:  # pragma: no cover - env dependent
        return f"pallas import failed: {e!r}"
    if not (hasattr(pltpu, "CompilerParams")
            or hasattr(pltpu, "TPUCompilerParams")):
        # kernels/compat.py bridges the CompilerParams rename; older jax
        # lacking both generations has no usable Mosaic params API
        return ("jax too old for kernels API (pallas.tpu.CompilerParams/"
                "TPUCompilerParams missing)")
    return ""


def _why_no_mesh() -> str:
    try:
        import jax
    except Exception as e:  # pragma: no cover - env dependent
        return f"jax import failed: {e!r}"
    if not hasattr(jax.sharding, "AxisType"):
        return "jax too old for launch API (sharding.AxisType missing)"
    if not hasattr(jax, "set_mesh"):
        return "jax too old for launch API (jax.set_mesh missing)"
    return ""


PALLAS_SKIP_REASON = _why_no_pallas()
HAVE_PALLAS_API = not PALLAS_SKIP_REASON
MESH_SKIP_REASON = _why_no_mesh()
HAVE_MESH_API = not MESH_SKIP_REASON
