"""Tracing plane: sampling policy, segment tiling, the flight recorder,
Chrome-trace export + causal action links, and the bounded audit log."""
import importlib.util
import json
import sys
from pathlib import Path

import pytest

from repro.agents import AgenticPipeline, PipelineConfig, TaskSpec
from repro.configs import get_config
from repro.core import (Controller, IntentError, MetricBus, Registry,
                        compile_intent)
from repro.core.metrics import CentralPoller, Collector, StateStore
from repro.core.trace import (SEGMENTS, FlightRecorder, Tracer,
                              request_decomposition)
from repro.core.types import Request, RequestState
from repro.serving.disagg import DisaggPool
from repro.serving.engine_sim import SimEngine
from repro.serving.kv_transfer import KVTransferManager, SessionDirectory
from repro.serving.scheduler import SchedulerConfig
from repro.sim.clock import EventLoop
from repro.sim.costmodel import CostModel

from tests.test_controller import FakeKnobbed

_ROOT = Path(__file__).resolve().parent.parent


def _report_tool():
    path = _ROOT / "tools" / "trace_report.py"
    spec = importlib.util.spec_from_file_location("trace_report", path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[spec.name] = mod
    spec.loader.exec_module(mod)
    return mod


def _traced_fig1(n_tasks=3, intent=None, watch=None):
    pipe = AgenticPipeline(PipelineConfig(n_testers=2))
    if intent:
        pipe.controller.install(compile_intent(intent))
    if watch:
        pipe.recorder.watch(watch)
    pipe.tracer.set_scope(None, 1.0)
    for i in range(n_tasks):
        pipe.submit(TaskSpec(session=f"s{i}", n_functions=4))
    pipe.run(until=120.0)
    assert len(pipe.done) == n_tasks
    return pipe


# ---------------------------------------------------------------------------
# Sampling policy
# ---------------------------------------------------------------------------

def test_decide_uncached_while_disabled_enables_mid_run():
    tr = Tracer(lambda: 0.0)
    assert tr.decide("t1") is False          # off by default, zero cost
    tr.set_scope(None, 1.0)                  # ... flipped at runtime
    assert tr.enabled is True
    assert tr.decide("t1") is True           # earlier False was NOT cached


def test_sampling_is_deterministic_and_partitions():
    a = Tracer(lambda: 0.0)
    b = Tracer(lambda: 0.0)
    a.set_scope(None, 0.4)
    b.set_scope(None, 0.4)
    ids = [f"task-{i}" for i in range(200)]
    da = [a.decide(t) for t in ids]
    assert da == [b.decide(t) for t in ids]  # replay traces the same tasks
    assert 0 < sum(da) < len(ids)            # rate actually partitions


def test_scope_precedence_stage_over_tenant_over_global():
    tr = Tracer(lambda: 0.0)
    tr.set_scope(None, 0.0)                  # global off
    tr.set_scope("tenant:gold", 1.0)         # scoped rate implies enabled
    assert tr.enabled is True
    assert tr.decide("t1", tenant="gold") is True
    assert tr.decide("t2", tenant="bronze") is False
    tr.set_scope("stage:editor", 1.0)        # stage is most specific
    assert tr.decide("t2", tenant="bronze", stage="editor") is True
    assert tr.decided("t1") is True          # cached-decision-only lookup
    assert tr.decided("never-seen") is False


def test_span_store_is_bounded():
    tr = Tracer(lambda: 0.0, cap=8)
    for i in range(20):
        tr.record(f"s{i}", "t", float(i), float(i) + 1.0)
    assert len(tr.spans) <= 8
    assert tr.spans_total == 20
    assert tr.spans_dropped > 0


# ---------------------------------------------------------------------------
# Intent verb
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("program,fragment", [
    ("rule r: when mean(x) > 1 => trace 1.5", "outside [0, 1]"),
    ("rule r: when mean(x) > 1 => trace maybe", "on|off|FLOAT"),
    ("rule r: when mean(x) > 1 => trace cluster gold on",
     "selector must be tenant|stage"),
])
def test_trace_verb_parse_errors(program, fragment):
    with pytest.raises(IntentError) as ei:
        compile_intent(program)
    assert fragment in str(ei.value)


def test_trace_verb_scopes_tracer_and_audits():
    loop = EventLoop()
    bus = MetricBus()
    reg = Registry()
    tr = Tracer(loop.now)
    reg.register(tr)
    reg.register(FakeKnobbed())
    store = StateStore()
    poller = CentralPoller(store)
    col = Collector(bus=bus)
    poller.attach(col)
    c = Controller(loop, reg, poller, interval=0.05, bus=bus)
    c.install(compile_intent("""
rule a on eng.queue_len > 10: => trace tenant gold 0.5
rule b on eng.queue_len > 20: => trace stage editor on
"""))
    col.gauge("eng.queue_len", 15, 0.01)
    loop.run_until(0.02)
    assert tr.scopes == {"tenant:gold": 0.5}
    assert tr.enabled is True
    col.gauge("eng.queue_len", 25, 0.05)
    loop.run_until(0.1)
    assert tr.scopes["stage:editor"] == 1.0
    kinds = [a.kind for a in c.action_log("trace")]
    assert len(kinds) == 2                   # both verbs audited


# ---------------------------------------------------------------------------
# Segment tiling (the acceptance bound)
# ---------------------------------------------------------------------------

def test_fig1_segments_tile_request_latency_within_1pct():
    pipe = _traced_fig1()
    decomp = request_decomposition(pipe.tracer.all_spans())
    assert decomp, "no closed request spans"
    for span, segs, dur in decomp:
        assert set(segs) <= set(SEGMENTS)
        total = sum(segs.values())
        assert abs(total - dur) <= 0.01 * max(dur, 1e-9), (
            f"{span.name}: segments {total:.6f}s != e2e {dur:.6f}s")
    # the decomposition is also published as request.<segment> gauges
    names = {s.name for s, _, _ in decomp}
    assert names                              # every traced request closed


def test_segment_gauges_reach_metric_plane():
    pipe = AgenticPipeline(PipelineConfig(n_testers=2))
    hits = []
    pipe.bus.subscribe("request.decode", above=0.0, edge=False,
                       fn=lambda n, v, t: hits.append((v, t)))
    pipe.tracer.set_scope(None, 1.0)
    for i in range(3):
        pipe.submit(TaskSpec(session=f"s{i}", n_functions=4))
    pipe.run(until=120.0)
    assert hits, "closed decode segments never reached the bus"
    assert all(v > 0 for v, _ in hits)
    # ... and land in the collector rings the poller scrapes
    assert pipe.collector._rings["request.queue_wait"].last() is not None


# ---------------------------------------------------------------------------
# Export: schema, causal links, critical path
# ---------------------------------------------------------------------------

def test_export_is_valid_chrome_trace_with_causal_links(tmp_path):
    intent = """
rule widen on developer.queue_len > 1:
    => set developer.max_num_seqs 48; note widened under burst
"""
    pipe = _traced_fig1(intent=intent, watch="tester-*.queue_len")
    out = tmp_path / "TRACE_fig1.json"
    doc = pipe.tracer.export(out, recorder=pipe.recorder)
    rpt = _report_tool()
    assert rpt.validate(rpt.load(out)) == []
    assert doc["otherData"]["links"] >= 1, "no action causally linked"
    evs = doc["traceEvents"]
    starts = [e for e in evs if e["ph"] == "s"]
    ends = [e for e in evs if e["ph"] == "f"]
    assert len(starts) == len(ends) == doc["otherData"]["links"]
    assert any(e["ph"] == "i" for e in evs)       # instant control events
    # the linked span carries the action text for the report tool
    linked = [e for e in evs
              if e["ph"] == "X" and (e["args"].get("actions"))]
    assert linked
    # recorder windows captured the watched series
    assert pipe.recorder.window("tester-0.queue_len")


def test_workflow_critical_path_reproduced_from_export_alone(tmp_path):
    from repro.agents import WorkflowConfig, deep_review
    from repro.agents.workloads import GraphBurst
    wf = AgenticPipeline.build(deep_review(depth=2),
                               WorkflowConfig(router_policy="least_loaded"))
    wf.tracer.set_scope(None, 1.0)
    GraphBurst(wf, n_tasks=2).start()
    wf.run(until=240.0)
    assert wf.done
    out = tmp_path / "TRACE_workflow.json"
    wf.tracer.export(out, recorder=wf.recorder)
    rpt = _report_tool()
    doc = rpt.load(out)
    assert rpt.validate(doc) == []
    spans = rpt.spans_from(doc)
    path = rpt.critical_path(spans, wf.done[0].task_id)
    assert len(path) >= 2, "critical path did not chain stages"
    assert all(s.cat == "stage" for s in path)
    assert path[0].name.startswith("stage:author")
    # dominant segment attribution works from the file alone
    seg, sec, frac = rpt.dominant_segment(path[-1], rpt._children(spans))
    assert seg in SEGMENTS and sec > 0


def test_trace_artifacts_are_valid_chrome_trace():
    """CI schema gate: every TRACE_*.json the benchmark smoke emitted
    must load as valid Chrome-trace JSON (skips when none exist)."""
    arts = sorted((_ROOT / "artifacts" / "bench").glob("TRACE_*.json"))
    if not arts:
        pytest.skip("no trace artifacts (run benchmarks.run --only trace)")
    rpt = _report_tool()
    for p in arts:
        doc = json.loads(p.read_text())
        assert rpt.validate(doc) == [], f"{p.name} failed schema check"
        assert rpt.spans_from(doc), f"{p.name} exported no spans"


# ---------------------------------------------------------------------------
# Flight recorder + bounded audit log
# ---------------------------------------------------------------------------

def test_controller_audit_log_is_bounded_ring():
    loop = EventLoop()
    reg = Registry()
    store = StateStore()
    poller = CentralPoller(store)
    col = Collector()
    c = Controller(loop, reg, poller, interval=0.05, collector=col,
                   actions_cap=8)
    rec = FlightRecorder(loop.now, action_cap=6)
    c.attach_recorder(rec)
    for i in range(20):
        c._log("note", f"t{i}", f"detail {i}")
    assert len(c.actions) <= 8
    assert c.actions_total == 20
    assert c.actions[-1].target == "t19"          # newest survives
    assert len(rec.actions) <= 6                  # recorder has its own bound
    assert rec.actions_total == 20
    # filtering API intact on the bounded list
    assert all(a.kind == "note" for a in c.action_log("note"))
    assert c.action_log("set") == []
    # retained-size gauge published for the dashboard
    assert col._rings["controller.actions_retained"].last() == len(c.actions)


def test_flight_recorder_windows_and_snapshot():
    bus = MetricBus()
    rec = FlightRecorder(lambda: 5.0, bus=bus, window_cap=4)
    rec.watch("eng-*.queue_len")
    for t in range(10):
        bus.publish("eng-0.queue_len", float(t), float(t))
        bus.publish("eng-1.queue_len", 100.0 + t, float(t))
    bus.publish("other.latency", 1.0, 9.0)        # unwatched
    assert len(rec.window("eng-0.queue_len")) == 4          # bounded ring
    assert [v for _, v in rec.window("eng-1.queue_len", since=8.0)] \
        == [108.0, 109.0]
    assert rec.window("other.latency") == []
    snap = rec.snapshot(since=8.0)
    assert snap["t"] == 5.0
    assert set(snap["metrics"]) == {"eng-0.queue_len", "eng-1.queue_len"}
    assert all(t >= 8.0 for series in snap["metrics"].values()
               for t, _ in series)


def test_recorder_actions_between_filters_by_time_and_kind():
    rec = FlightRecorder(lambda: 0.0)

    class A:
        def __init__(self, t, kind):
            self.t, self.kind, self.target, self.detail = t, kind, "x", ""
    for t, k in [(0.0, "set"), (1.0, "note"), (2.0, "set"), (3.0, "scale")]:
        rec.record_action(A(t, k))
    assert [a.t for a in rec.actions_between(0.5, 2.5)] == [1.0, 2.0]
    assert [a.t for a in rec.actions_between(kind="set")] == [0.0, 2.0]


# ---------------------------------------------------------------------------
# Disagg: handoff_wait segment + kv chunk spans
# ---------------------------------------------------------------------------

def test_disagg_handoff_traced_with_kv_chunk_spans():
    loop = EventLoop()
    col = Collector("t")
    cm = CostModel(get_config("agent-7b"), chips=4)
    engines = [
        SimEngine(loop, cm,
                  SchedulerConfig(max_slots=8, num_pages=2048,
                                  max_context=4096, role=r),
                  name=f"e{i}", collector=col)
        for i, r in enumerate(("prefill", "decode"))]
    kvx = KVTransferManager(loop, SessionDirectory(),
                            bytes_fn=cm.kv_transfer_bytes, collector=col)
    tr = Tracer(loop.now)
    tr.set_scope(None, 1.0)
    pool = DisaggPool(loop, engines, kvx, collector=col, tracer=tr)
    r = Request(prompt_len=2048, max_new_tokens=16)
    pool.submit(r)
    loop.run_until(60.0)
    assert r.state == RequestState.FINISHED
    spans = tr.all_spans()
    segs = {s.name for s in spans if s.cat == "segment"}
    assert "handoff_wait" in segs             # release→resume gap captured
    assert "prefill" in segs and "decode" in segs
    kv = [s for s in spans if s.cat == "kv"]
    assert kv, "no kv chunk spans for a chunk-streamed handoff"
    assert any(s.name == "kv_chunk_tail" for s in kv)
    assert all(s.attrs["src"] == "e0" and s.attrs["dst"] == "e1"
               for s in kv)
    # the request's segments still tile its latency across BOTH engines
    for span, sgs, dur in request_decomposition(spans):
        assert abs(sum(sgs.values()) - dur) <= 0.01 * max(dur, 1e-9)
