"""Stall-free mixed batching: chunked prefill fused into the live
decode step.

Gates for the ISSUE-9 tentpole: the scheduler's MIXED plan budget
semantics, honored ``prefill_chunk`` on the live paged engine
(regression: it used to be silently overridden to one-shot), bit-exact
greedy token parity of the fused step against the serialized oracle
across GQA/MQA and chunk sizes, the compile-once guarantee of the
jitted mixed step under admission/allocator churn, the ``itl_p95``
decode-stall gauge and its bus-threshold path, tracer segment tiling
with mixed steps, the CostModel mixed roofline, and the adaptive
ChunkPolicy / intent loop closed over the ``prefill_chunk`` knob.
"""
import jax
import numpy as np
import pytest

from repro import models
from repro.configs import get_config
from repro.core import Controller, MetricBus, Registry, compile_intent
from repro.core.metrics import (BUILTIN_SPECS, CentralPoller, Collector,
                                StateStore)
from repro.core.policies import ChunkPolicy
from repro.core.trace import SEGMENTS, Tracer, request_decomposition
from repro.core.types import Request, RequestState
from repro.serving.engine import Engine
from repro.serving.engine_sim import SimEngine
from repro.serving.scheduler import (Scheduler, SchedulerConfig, StepKind)
from repro.sim.clock import EventLoop
from repro.sim.costmodel import BYTES_PER_PARAM, CostModel


BASE = get_config("tiny-agent").replace(dtype="float32")
PAGE = 16


def _params(cfg):
    return models.init(cfg, jax.random.key(0))


def _engine(cfg, params, *, mixed=False, chunk=0, layout="paged",
            max_slots=3, max_batch_tokens=64, name=None):
    sched = SchedulerConfig(max_slots=max_slots, num_pages=64,
                            max_context=128, page_size=PAGE,
                            max_batch_tokens=max_batch_tokens,
                            prefill_chunk=chunk, mixed=mixed)
    name = name or f"mx-{'mixed' if mixed else 'serial'}-{chunk}"
    return Engine(cfg, params, sched, name=name, cache_layout=layout)


def _run(eng, prompts, max_new=6):
    reqs = [Request(prompt_len=len(p), max_new_tokens=max_new,
                    prompt_tokens=np.asarray(p, np.int32)) for p in prompts]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    for r in reqs:
        assert r.state == RequestState.FINISHED
    return [r.output_tokens for r in reqs]


def _prompts(*lens, seed=3):
    return [np.arange(seed + i, seed + i + n) % BASE.vocab
            for i, n in enumerate(lens)]


# ---------------------------------------------------------------------------
# Scheduler: MIXED plan semantics
# ---------------------------------------------------------------------------

def _sched(**kw):
    cfg = SchedulerConfig(max_slots=4, num_pages=64, max_context=128,
                          page_size=PAGE, role="unified", **kw)
    return Scheduler(cfg)


def _admit_running(s, n, ctx=20):
    """n requests already decoding (state RUNNING, fully prefilled)."""
    out = []
    for _ in range(n):
        r = Request(prompt_len=ctx, max_new_tokens=64)
        s.submit(r)
        out.append(r)
    # drain admission: plan until everyone is resident, then mark prefilled
    s.plan_step()
    for r in out:
        r.prefilled = r.prompt_len
        r.state = RequestState.RUNNING
        r.generated = 1
    return out


def test_mixed_plan_fills_budget_decodes_first():
    s = _sched(mixed=True, prefill_chunk=256, max_batch_tokens=32)
    decs = _admit_running(s, 2)
    pf = Request(prompt_len=100, max_new_tokens=4)
    s.submit(pf)
    s.plan_step()                      # admits pf into a slot (PREFILL)
    plan = s.plan_step()
    assert plan.kind == StepKind.MIXED
    assert set(r.req_id for r in plan.decodes) == {r.req_id for r in decs}
    w = plan.prefills[0]
    assert w.req is pf
    # budget = max_batch_tokens - decodes; chunk clamped to it
    assert w.chunk == 32 - 2


def test_mixed_plan_chunk_knob_caps_chunk():
    s = _sched(mixed=True, prefill_chunk=8, max_batch_tokens=64)
    pf = Request(prompt_len=100, max_new_tokens=4)
    s.submit(pf)
    s.plan_step()
    plan = s.plan_step()
    assert plan.kind == StepKind.MIXED and plan.prefills[0].chunk == 8
    # chunk 0 = whole remaining prompt (still budget-clamped)
    s2 = _sched(mixed=True, prefill_chunk=0, max_batch_tokens=64)
    pf2 = Request(prompt_len=100, max_new_tokens=4)
    s2.submit(pf2)
    s2.plan_step()
    plan2 = s2.plan_step()
    assert plan2.kind == StepKind.MIXED and plan2.prefills[0].chunk == 64


def test_mixed_plan_degrades_to_decode_when_budget_exhausted():
    s = _sched(mixed=True, prefill_chunk=256, max_batch_tokens=2)
    _admit_running(s, 2)
    pf = Request(prompt_len=100, max_new_tokens=4)
    s.submit(pf)
    s.plan_step()
    plan = s.plan_step()
    assert plan.kind == StepKind.DECODE     # no headroom for even 1 token


def test_mixed_off_keeps_serialized_prefill():
    s = _sched(mixed=False, prefill_chunk=8)
    pf = Request(prompt_len=100, max_new_tokens=4)
    s.submit(pf)
    s.plan_step()
    plan = s.plan_step()
    assert plan.kind == StepKind.PREFILL


# ---------------------------------------------------------------------------
# Satellite 1 regression: prefill_chunk honored on the live paged engine
# ---------------------------------------------------------------------------

def test_live_engine_honors_prefill_chunk_across_steps():
    """A 35-token prompt with prefill_chunk=8 must take ceil(35/8)=5
    serialized prefill steps — the engine used to override work.chunk
    with the whole remaining prompt, making the knob a no-op — and the
    chunked run must emit the same tokens as the one-shot run."""
    params = _params(BASE)
    prompts = _prompts(35)

    oneshot = _run(_engine(BASE, params, chunk=0, name="os"), prompts)

    eng = _engine(BASE, params, chunk=8, name="ck")
    kinds = []
    orig = eng.scheduler.plan_step

    def spy():
        plan = orig()
        kinds.append(plan.kind)
        return plan

    eng.scheduler.plan_step = spy
    chunked = _run(eng, prompts)
    assert chunked == oneshot
    n_prefill = sum(1 for k in kinds if k == StepKind.PREFILL)
    assert n_prefill == 5, f"expected 5 chunked prefill steps, got {n_prefill}"


# ---------------------------------------------------------------------------
# Tentpole: fused-step token parity vs the serialized oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_kv_heads", [2, 1], ids=["gqa", "mqa"])
@pytest.mark.parametrize("chunk", [0, 7, 16], ids=["whole", "c7", "c16"])
def test_mixed_token_parity(n_kv_heads, chunk):
    """Greedy decode is bit-identical whether prefills run serialized
    one-shot or chunked + fused into the live decode step: each token
    depends only on its own sequence history, so interleaving cannot
    change it."""
    cfg = BASE.replace(n_kv_heads=n_kv_heads)
    params = _params(cfg)
    prompts = _prompts(35, 27, 37)

    ref = _run(_engine(cfg, params, name=f"ref{n_kv_heads}"), prompts)
    got = _run(_engine(cfg, params, mixed=True, chunk=chunk,
                       name=f"mx{n_kv_heads}-{chunk}"), prompts)
    assert got == ref


def test_mixed_parity_with_pallas_kernel_path():
    cfg = BASE.replace(use_pallas=True)
    params = _params(cfg)
    prompts = _prompts(33, 21)
    ref = _run(_engine(cfg, params, name="pl-ref"), prompts)
    got = _run(_engine(cfg, params, mixed=True, chunk=8, name="pl-mx"),
               prompts)
    assert got == ref


# ---------------------------------------------------------------------------
# Satellite 2: the jitted mixed step compiles exactly once per engine
# ---------------------------------------------------------------------------

def test_mixed_step_compiles_once_across_churn():
    """Admission churn, freed/reallocated pages, varying chunk fill and
    varying live-decode counts must all replay the SAME traced program:
    the counter inside the jitted body increments per trace, not per
    call."""
    params = _params(BASE)
    eng = _engine(BASE, params, mixed=True, chunk=8, name="once")
    _run(eng, _prompts(35, 27, 37), max_new=5)
    assert eng.mixed_step_traces == 1
    # second wave: different lengths, recycled slots/pages, partial tail
    # chunks of different sizes
    _run(eng, _prompts(19, 41, seed=11), max_new=3)
    assert eng.mixed_step_traces == 1
    # knob move changes chunk geometry — still the same traced shapes
    eng.set_param("prefill_chunk", 5)
    _run(eng, _prompts(23, seed=29), max_new=3)
    assert eng.mixed_step_traces == 1


def test_mixed_requires_paged_layout():
    params = _params(BASE)
    with pytest.raises(RuntimeError, match="paged"):
        _engine(BASE, params, mixed=True, layout="ring")
    # flipping the knob on a ring engine fails AND reverts
    eng = _engine(BASE, params, layout="ring", name="ring-guard")
    with pytest.raises(RuntimeError, match="paged"):
        eng.set_param("mixed", True)
    assert eng.get_param("mixed") is False
    # flipping a mixed paged engine to ring refuses too
    mx = _engine(BASE, params, mixed=True, name="flip-guard")
    with pytest.raises(RuntimeError):
        mx.set_param("cache_layout", "ring")
    assert mx.get_param("cache_layout") == "paged"


# ---------------------------------------------------------------------------
# Satellite 3: itl_p95 gauge + bus threshold path
# ---------------------------------------------------------------------------

def test_itl_p95_builtin_spec_and_engine_metric():
    assert "itl_p95" in BUILTIN_SPECS
    assert "itl_p95" in Engine.METRICS
    spec = BUILTIN_SPECS["itl_p95"]
    assert spec.direction == "lower_better"
    assert "inter-token" in spec.description.lower()


def test_itl_p95_tracks_decode_stall():
    """Per-request token gaps land in the rolling window; a stall (one
    long gap) drags the p95 up."""
    loop = EventLoop()
    cm = CostModel(get_config("agent-7b"))
    eng = SimEngine(loop, cm, SchedulerConfig(max_slots=4, num_pages=512,
                                              max_context=2048))
    r = Request(prompt_len=4, max_new_tokens=2)
    r.meta["last_token_t"] = 0.0
    eng._note_itl(r, 0.01)
    assert eng.itl_p95 == pytest.approx(0.01)
    for t in (0.02, 0.03, 0.04):
        eng._note_itl(r, t)
    eng._note_itl(r, 1.0)                   # the stall
    assert eng.itl_p95 == pytest.approx(0.96)
    # a fresh request's first token opens no gap
    r2 = Request(prompt_len=4, max_new_tokens=2)
    before = len(eng._itl_samples)
    eng._note_itl(r2, 5.0)
    assert len(eng._itl_samples) == before


def test_itl_p95_published_and_bus_threshold_fires():
    bus = MetricBus()
    fired = []
    bus.subscribe("mxsim.itl_p95", lambda n, v, t: fired.append(v),
                  above=0.0, edge=False)
    loop = EventLoop()
    cm = CostModel(get_config("agent-7b"))
    col = Collector("node0", bus=bus)
    eng = SimEngine(loop, cm,
                    SchedulerConfig(max_slots=4, num_pages=1024,
                                    max_context=4096, max_batch_tokens=512,
                                    prefill_chunk=128, mixed=True),
                    name="mxsim", collector=col)
    for n in (600, 800):
        eng.submit(Request(prompt_len=n, max_new_tokens=8))
    loop.run_until(60.0)
    assert fired and max(fired) > 0.0


# ---------------------------------------------------------------------------
# Satellite 4: tracer segments still tile e2e latency with mixed steps
# ---------------------------------------------------------------------------

def test_mixed_segments_tile_latency_within_1pct():
    loop = EventLoop()
    cm = CostModel(get_config("agent-7b"))
    eng = SimEngine(loop, cm,
                    SchedulerConfig(max_slots=4, num_pages=2048,
                                    max_context=4096, max_batch_tokens=512,
                                    prefill_chunk=128, mixed=True),
                    name="mxtr")
    tr = Tracer(loop.now)
    tr.set_scope(None, 1.0)
    eng.tracer = tr
    reqs = [Request(prompt_len=n, max_new_tokens=12)
            for n in (900, 700, 1100, 500)]
    for r in reqs:
        eng.submit(r)
    loop.run_until(120.0)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    decomp = request_decomposition(tr.all_spans())
    assert len(decomp) == len(reqs)
    for span, segs, dur in decomp:
        assert set(segs) <= set(SEGMENTS)
        total = sum(segs.values())
        assert abs(total - dur) <= 0.01 * max(dur, 1e-9), (
            f"{span.name}: segments {total:.6f}s != e2e {dur:.6f}s")
        # fused steps attribute to BOTH phases, not one catch-all bucket
        assert segs.get("prefill", 0.0) > 0.0
        assert segs.get("decode", 0.0) > 0.0


# ---------------------------------------------------------------------------
# CostModel: mixed roofline pricing
# ---------------------------------------------------------------------------

def test_costmodel_mixed_prices_fusion_saving():
    cm = CostModel(get_config("agent-7b"))
    pf_f, pf_b = cm.prefill_cost(256, context=512)
    dc_f, dc_b = cm.decode_cost(8, 1024.0)
    mx_f, mx_b = cm.mixed_cost(256, 512, 8, 1024.0)
    assert mx_f == pytest.approx(pf_f + dc_f)        # FLOPs add
    weight_read = cm.n_active_params() * BYTES_PER_PARAM
    assert mx_b == pytest.approx(pf_b + dc_b - weight_read)
    # one fused step beats prefill + decode back to back
    assert cm.mixed_time(256, 512, 8, 1024.0) < (
        cm.prefill_time(256, context=512) + cm.decode_time(8, 1024.0))
    # and degenerates to plain prefill with no live decodes
    assert cm.mixed_cost(256, 512, 0, 0.0) == cm.prefill_cost(256,
                                                              context=512)


def test_sim_engine_mixed_reduces_decode_stall():
    """Same arrival trace, serialized vs mixed: every request finishes
    on both, and the mixed engine's worst inter-token gap is strictly
    smaller because long prefills no longer monopolize whole steps."""
    def run(mixed):
        loop = EventLoop()
        cm = CostModel(get_config("agent-7b"))
        eng = SimEngine(loop, cm,
                        SchedulerConfig(max_slots=8, num_pages=4096,
                                        max_context=8192,
                                        max_batch_tokens=512,
                                        prefill_chunk=128, mixed=mixed),
                        name=f"sim-{mixed}")
        worst = {}

        def on_token(r, tok, t):
            prev = r.meta.get("_t_prev")
            r.meta["_t_prev"] = t
            if prev is not None:
                worst[r.req_id] = max(worst.get(r.req_id, 0.0), t - prev)

        eng.on_token = on_token
        reqs = [Request(prompt_len=64, max_new_tokens=48)]
        for _ in range(4):                   # long prefills arrive behind
            reqs.append(Request(prompt_len=2000, max_new_tokens=8))
        for r in reqs:
            eng.submit(r)
        loop.run_until(600.0)
        assert all(r.state == RequestState.FINISHED for r in reqs)
        return max(worst.values())

    assert run(True) < run(False)


# ---------------------------------------------------------------------------
# Control plane: ChunkPolicy + intent rule close the loop on the knob
# ---------------------------------------------------------------------------

def _control(objs, bus):
    loop = EventLoop()
    reg = Registry()
    for o in objs:
        reg.register(o)
    store = StateStore()
    poller = CentralPoller(store)
    c = Controller(loop, reg, poller, interval=0.05, bus=bus)
    col = Collector(bus=bus)
    poller.attach(col)
    return loop, reg, col, c


class FakeMixedEngine:
    """Knob-surface stub: just prefill_chunk, for policy unit tests."""
    name, kind = "e0", "llm"

    def __init__(self, chunk=512):
        self.values = {"prefill_chunk": chunk}
        self._defaults = {}

    def card(self):
        from repro.core.types import AgentCard
        return AgentCard(name=self.name, kind=self.kind,
                         knobs=dict(self.values),
                         metrics=("itl_p95",), capabilities=())

    def get_param(self, k):
        return self.values[k]

    def set_param(self, k, v):
        self._defaults.setdefault(k, self.values[k])
        self.values[k] = v

    def reset_param(self, k):
        self.values[k] = self._defaults.get(k, self.values[k])


def test_chunk_policy_shrinks_on_stall_and_regrows():
    bus = MetricBus()
    eng = FakeMixedEngine(chunk=512)
    loop, reg, col, c = _control([eng], bus)
    pol = ChunkPolicy("e0", itl_slo=0.05, chunk_min=64, chunk_max=512,
                      dwell=0.0)
    c.install(pol)
    c.start()
    col.gauge("e0.itl_p95", 0.2, 0.01)            # stalled
    loop.run_until(0.4)
    # halves per tick down to the floor, then holds
    assert [w for _, w in pol.moves[:3]] == [256, 128, 64]
    assert eng.values["prefill_chunk"] == 64
    # calm + backlog => grow back
    col.gauge("e0.itl_p95", 0.001, 0.41)
    col.gauge("e0.prefill_queue_tokens", 4000, 0.41)
    loop.run_until(0.6)
    assert eng.values["prefill_chunk"] > 64


def test_chunk_policy_calm_without_backlog_holds():
    bus = MetricBus()
    eng = FakeMixedEngine(chunk=128)
    loop, reg, col, c = _control([eng], bus)
    c.install(ChunkPolicy("e0", itl_slo=0.05, dwell=0.0))
    c.start()
    col.gauge("e0.itl_p95", 0.001, 0.01)          # calm, no queue signal
    loop.run_until(0.2)
    assert eng.values["prefill_chunk"] == 128     # nothing to grow for


def test_intent_rule_sets_prefill_chunk_on_itl_breach():
    bus = MetricBus()
    eng = FakeMixedEngine(chunk=0)
    loop, reg, col, c = _control([eng], bus)
    c.install(compile_intent("""
rule stall on engine e0.itl_p95 > 0.05:
    => set engine e0.prefill_chunk 256
"""))
    col.gauge("e0.itl_p95", 0.01, 0.01)           # under threshold
    loop.run_until(0.02)
    assert eng.values["prefill_chunk"] == 0
    col.gauge("e0.itl_p95", 0.12, 0.05)           # breach
    loop.run_until(0.1)
    assert eng.values["prefill_chunk"] == 256
    assert any(a.kind == "set" for a in c.action_log())
