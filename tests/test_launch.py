"""Launch layer on a 1-device mesh: train/serve steps lower, compile AND
run with real numerics; collective parsing; cost extrapolation helpers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _jax_caps import HAVE_MESH_API, MESH_SKIP_REASON

# only the compile-and-run tests need the mesh API; the parsing and
# extrapolation helpers below run on any JAX
needs_mesh = pytest.mark.skipif(not HAVE_MESH_API, reason=MESH_SKIP_REASON)

from repro import models
from repro.configs import get_config, get_smoke
from repro.configs.base import ShapeConfig
from repro.launch import specs as specs_mod
from repro.launch.dryrun import (_lin, _period, _scaled_cfg,
                                 collective_bytes, cpu_bf16_inflation,
                                 model_flops)
from repro.launch.mesh import make_mesh
from repro.launch.serve import make_prefill_step, make_serve_step
from repro.launch.train import (AdamWConfig, TrainPlan, abstract_state,
                                make_train_step, opt_pspecs)
from repro.optim.adamw import adamw_init


@needs_mesh
def test_train_step_runs_and_learns():
    cfg = get_config("tiny-agent")
    mesh = make_mesh((1, 1), ("data", "model"))
    shape = ShapeConfig("t", 32, 4, "train")
    acfg = AdamWConfig(lr=5e-3, warmup_steps=0)
    with jax.set_mesh(mesh):
        step, _ = make_train_step(cfg, mesh, TrainPlan(microbatch=2),
                                  acfg, shape=shape)
        params = models.init(cfg, jax.random.key(0))
        opt = adamw_init(params, acfg)
        toks = np.random.default_rng(0).integers(
            0, cfg.vocab, (4, 32)).astype(np.int32)
        batch = {"tokens": toks, "labels": toks}
        losses = []
        for _ in range(12):
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3      # memorizes a fixed batch


@needs_mesh
def test_serve_step_matches_models_decode():
    cfg = get_config("tiny-agent")
    mesh = make_mesh((1, 1), ("data", "model"))
    shape = ShapeConfig("d", 64, 2, "decode")
    with jax.set_mesh(mesh):
        step, _ = make_serve_step(cfg, mesh, shape)
        params = models.init(cfg, jax.random.key(0))
        ctx = specs_mod.decode_context(shape)
        cache = models.init_cache(cfg, 2, ctx)
        toks = jnp.array([[3], [5]], jnp.int32)
        logits, cache2 = step(params, toks, cache)
        ref_cache = models.init_cache(cfg, 2, ctx)
        ref_logits, _ = models.decode_step(params, cfg, toks, ref_cache)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(ref_logits, np.float32),
                               atol=1e-4)


@needs_mesh
def test_prefill_step_runs():
    cfg = get_config("tiny-agent")
    mesh = make_mesh((1, 1), ("data", "model"))
    shape = ShapeConfig("p", 32, 2, "prefill")
    with jax.set_mesh(mesh):
        step, _ = make_prefill_step(cfg, mesh, shape)
        params = models.init(cfg, jax.random.key(0))
        cache = models.init_cache(cfg, 2, 32)
        toks = jnp.arange(64, dtype=jnp.int32).reshape(2, 32) % cfg.vocab
        logits, cache = step(params, toks, cache)
        assert logits.shape == (2, cfg.vocab)
        assert int(cache["pos"][0]) == 32


@needs_mesh
def test_opt_pspecs_structure_matches_state():
    cfg = get_smoke("llama3-405b")
    mesh = make_mesh((1, 1), ("data", "model"))
    for int8 in (False, True):
        acfg = AdamWConfig(int8_moments=int8)
        spec = opt_pspecs(cfg, mesh, acfg)
        _, state = abstract_state(cfg, acfg)
        assert (jax.tree.structure(spec) == jax.tree.structure(state))


# ---------------------------------------------------------------------------
# dry-run helpers
# ---------------------------------------------------------------------------

HLO = """
  %ag = bf16[32,1024]{1,0} all-gather(%x), replica_groups=...
  %ar.1 = f32[8,128]{1,0} all-reduce(%y), to_apply=%sum
  %rs = (bf16[16,64]{1,0}, bf16[16,64]{1,0}) reduce-scatter(%a, %b)
  %dot = f32[128,128]{1,0} dot(%p, %q)
  %a2a = s8[4,4]{1,0} all-to-all(%z)
"""


def test_collective_bytes_parsing():
    c = collective_bytes(HLO)
    assert c["all-gather"] == 32 * 1024 * 2
    assert c["all-reduce"] == 8 * 128 * 4
    assert c["reduce-scatter"] == 2 * 16 * 64 * 2
    assert c["all-to-all"] == 16
    assert c["count"] == 4
    assert c["total"] == sum(c[k] for k in
                             ("all-gather", "all-reduce", "reduce-scatter",
                              "all-to-all", "collective-permute"))


def test_cpu_bf16_inflation_detection():
    hlo = """
  %big16 = bf16[4096,16384]{1,0} fusion(%a)
  %big32 = f32[4096,16384]{1,0} convert(%big16)
  %small = f32[16,16]{1,0} convert(%c)
"""
    assert cpu_bf16_inflation(hlo) == 4096 * 16384 * 4


def test_scaled_cfg_periods():
    gem = get_config("gemma3-27b")
    assert _period(gem) == 6
    small = _scaled_cfg(gem, 2)
    assert small.n_layers == 12
    assert not small.scan_layers
    xl = get_config("xlstm-350m")
    assert _period(xl) == 8
    kimi = get_config("kimi-k2-1t-a32b")
    assert _period(kimi) == 1
    assert _scaled_cfg(kimi, 3).n_layers == kimi.first_k_dense + 3


def test_linear_extrapolation_exact_on_linear_data():
    fa = {"flops": 10.0, "bytes_accessed": 6.0,
          "collectives": {"all-gather": 4, "total": 4, "count": 2}}
    fb = {"flops": 16.0, "bytes_accessed": 8.0,
          "collectives": {"all-gather": 6, "total": 6, "count": 3}}
    out = _lin(fa, fb, 2, 4, 10)
    assert out["flops"] == pytest.approx(34.0)       # 4 + 3*u
    assert out["bytes_accessed"] == pytest.approx(14.0)   # 4 + 1*u
    assert out["collectives"]["all-gather"] == pytest.approx(12.0)
    assert out["collectives"]["count"] == 6      # 1 + 0.5*u


def test_model_flops_shapes():
    from repro.configs import SHAPES
    cfg = get_config("llama3-405b")
    n = 405e9
    mf = model_flops(cfg, SHAPES["train_4k"])
    assert 0.7 * 6 * n * 4096 * 256 < mf < 1.5 * 6 * n * 4096 * 256
    # MoE uses active params only
    kimi = get_config("kimi-k2-1t-a32b")
    mf_k = model_flops(kimi, SHAPES["train_4k"])
    assert mf_k < 6 * 500e9 * 4096 * 256      # far below total-param count
