"""Examples smoke: run every sim-substrate example end-to-end under a
bounded virtual clock, so examples can't silently rot as the planes
underneath them move.

Each example's ``main()`` is imported by path and executed with
``EventLoop.run_until`` clamped to a budget generous enough for the
examples' own end-state assertions, but hard-bounded so a future
regression (runaway load, a policy that never converges) fails fast
instead of hanging CI.  The real-JAX examples (serve_llm, train_lm) run
wall-clock model code, not the virtual clock — their layers are covered
by tests/test_serving.py, test_launch.py and test_checkpoint.py.
"""
import importlib.util
import sys
from pathlib import Path

import pytest

from repro.sim.clock import EventLoop

EXAMPLES_DIR = Path(__file__).resolve().parent.parent / "examples"
CLOCK_BUDGET = 90.0                      # virtual seconds per example
SIM_EXAMPLES = ("quickstart", "autoscale", "prefix_cache",
                "failover_drill", "workflow", "disagg", "tenancy",
                "trace")


def load_example(name: str):
    path = EXAMPLES_DIR / f"{name}.py"
    spec = importlib.util.spec_from_file_location(f"example_{name}", path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[spec.name] = mod
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture
def clamped_clock(monkeypatch):
    orig = EventLoop.run_until

    def bounded(self, t_end=float("inf"), max_events=10_000_000):
        return orig(self, min(t_end, CLOCK_BUDGET), max_events)

    monkeypatch.setattr(EventLoop, "run_until", bounded)


def test_all_examples_are_covered_or_excluded():
    """A new example must either join SIM_EXAMPLES or be a known
    real-JAX one — no silently untested files."""
    known = set(SIM_EXAMPLES) | {"serve_llm", "train_lm"}
    on_disk = {p.stem for p in EXAMPLES_DIR.glob("*.py")}
    assert on_disk == known, (
        f"examples changed: {sorted(on_disk ^ known)} — update "
        "tests/test_examples.py")


@pytest.mark.parametrize("name", SIM_EXAMPLES)
def test_example_runs_clean(name, clamped_clock, capsys):
    mod = load_example(name)
    mod.main()                           # examples assert their own outcome
    out = capsys.readouterr().out
    assert "tasks completed" in out or "OK" in out
