"""Unified ControlSurface: clamp/reset round-trip semantics across every
migrated controllable (channel, router, scheduler, engine, tool, group).

The acceptance bar for the refactor: exactly ONE set/reset
implementation (core/knobs.ControlSurface), with all the per-class
behaviours of the old hand-rolled shims preserved.
"""
import pytest

from repro.agents import AgenticPipeline, PipelineConfig, ToolAgent
from repro.core.dataplane import Channel
from repro.core.knobs import ControlSurface
from repro.core.types import Granularity, Priority
from repro.serving.engine_sim import SimEngine
from repro.serving.router import Router
from repro.serving.scheduler import Scheduler, SchedulerConfig
from repro.sim.clock import EventLoop
from repro.sim.costmodel import CostModel
from repro.sim.network import Link
from repro.configs import get_config


class _Sink:
    name = "sink"

    def deliver(self, msg):
        pass


def _channel():
    loop = EventLoop()
    return Channel(loop, Link(loop, bandwidth=1e9), "src", _Sink())


def _engine():
    loop = EventLoop()
    cm = CostModel(get_config("agent-7b"), chips=4)
    return SimEngine(loop, cm, SchedulerConfig(max_slots=4, num_pages=256))


# ---------------------------------------------------------------------------
# One implementation
# ---------------------------------------------------------------------------

def test_single_set_reset_implementation():
    """No migrated class redefines the Table-1 surface."""
    from repro.runtime.elastic import ElasticGroup
    from repro.serving.engine_base import EngineCore
    for cls in (Channel, Router, Scheduler, EngineCore, SimEngine,
                ToolAgent, ElasticGroup):
        assert issubclass(cls, ControlSurface)
        for meth in ("set_param", "reset_param", "get_param"):
            assert meth not in cls.__dict__, (
                f"{cls.__name__}.{meth} shadows ControlSurface")
    assert not hasattr(Scheduler, "set_knob")


# ---------------------------------------------------------------------------
# Round-trips per controllable
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("knob,value,expect", [
    ("granularity", "stream", Granularity.STREAM),
    ("stream_chunk", "16", 16),
    ("stream_chunk", 0, 1),                      # clamped to floor
    ("pace", -0.5, 0.0),                         # clamped to floor
    ("priority", 3, Priority.INTERACTIVE),
    ("gate_speculative", "on", True),
])
def test_channel_set_coerces_and_clamps(knob, value, expect):
    ch = _channel()
    ch.set_param(knob, value)
    assert ch.get_param(knob) == expect


def test_channel_reset_roundtrip_all_knobs():
    ch = _channel()
    before = {k: ch.get_param(k) for k in ch.KNOBS}
    ch.set_param("granularity", Granularity.STREAM)
    ch.set_param("stream_chunk", 2)
    ch.set_param("pace", 0.25)
    ch.set_param("priority", Priority.HIGH)
    ch.set_param("gate_speculative", True)
    for k in ch.KNOBS:
        ch.reset_param(k)
    assert {k: ch.get_param(k) for k in ch.KNOBS} == before


def test_unknown_knob_raises_everywhere():
    for obj in (_channel(), Router(EventLoop()), _engine(),
                Scheduler(SchedulerConfig()),
                ToolAgent("tool", EventLoop())):
        with pytest.raises(KeyError):
            obj.set_param("no_such_knob", 1)
        with pytest.raises(KeyError):
            obj.get_param("no_such_knob")
        with pytest.raises(KeyError):
            obj.reset_param("no_such_knob")


def test_router_policy_choices_validated():
    r = Router(EventLoop())
    r.set_param("policy", "least_loaded")
    assert r.policy == "least_loaded"
    with pytest.raises(ValueError):
        r.set_param("policy", "round_robin")
    r.reset_param("policy")
    assert r.policy == "static"


def test_scheduler_slot_resize_up_and_down():
    s = Scheduler(SchedulerConfig(max_slots=4, num_pages=64))
    s.set_param("max_num_seqs", 8)
    assert s.cfg.max_slots == 8 and len(s._free_slots) == 8
    s.set_param("max_num_seqs", 2)
    assert s.cfg.max_slots == 2 and s._free_slots == [0, 1]
    s.reset_param("max_num_seqs")
    assert s.cfg.max_slots == 4 and len(s._free_slots) == 4


def test_scheduler_clamps_instead_of_asserting():
    s = Scheduler(SchedulerConfig(max_slots=4, num_pages=64))
    s.set_param("max_num_seqs", 0)               # old code: AssertionError
    assert s.cfg.max_slots == 1
    s.set_param("max_batch_tokens", -5)
    assert s.cfg.max_batch_tokens == 1


def test_engine_delegates_scheduler_knobs_and_clamps_physical():
    eng = _engine()
    eng.set_param("max_num_seqs", 100)           # physical_slots = 4
    assert eng.scheduler.cfg.max_slots == 4
    eng.set_param("max_num_seqs", 2)
    assert eng.get_param("max_num_seqs") == 2
    eng.reset_param("max_num_seqs")
    assert eng.scheduler.cfg.max_slots == 4
    # engine-only knobs still work and coerce
    eng.set_param("paused", "true")
    assert eng.paused is True
    eng.set_param("temperature", "0.7")
    assert eng.temperature == 0.7


def test_engine_reset_roundtrip_all_knobs():
    eng = _engine()
    before = {k: eng.get_param(k) for k in eng.KNOBS}
    for k, v in [("max_num_seqs", 2), ("max_batch_tokens", 128),
                 ("prefill_chunk", 64), ("admit_priority_min", 2),
                 ("decode_first", True), ("temperature", 1.0),
                 ("paused", True)]:
        eng.set_param(k, v)
    for k in eng.KNOBS:
        eng.reset_param(k)
    assert {k: eng.get_param(k) for k in eng.KNOBS} == before


def test_tool_agent_roundtrip():
    t = ToolAgent("exec", EventLoop(), concurrency=2)
    t.set_param("concurrency", "6")
    t.set_param("throttle", 0.2)
    assert t.concurrency == 6 and t.throttle == 0.2
    t.set_param("concurrency", 0)                # clamped to >= 1
    assert t.concurrency == 1
    t.reset_param("concurrency")
    t.reset_param("throttle")
    assert t.concurrency == 2 and t.throttle == 0.0


def test_reset_without_set_is_noop():
    ch = _channel()
    ch.reset_param("pace")                       # no default recorded yet
    assert ch.pace == 0.0


# ---------------------------------------------------------------------------
# Audit + cards
# ---------------------------------------------------------------------------

def test_knob_log_records_transitions():
    ch = _channel()
    ch.set_param("stream_chunk", 4)
    ch.set_param("stream_chunk", 2)
    names = [(name, old, new) for (_, name, old, new) in ch.knob_log]
    assert names == [("stream_chunk", 8, 4), ("stream_chunk", 4, 2)]


def test_cards_derived_from_specs():
    eng = _engine()
    card = eng.card()
    assert card.kind == "llm"
    assert set(card.knobs) == set(eng.KNOBS)
    assert "kv_transfer" in card.capabilities
    ch = _channel()
    assert ch.card().kind == "channel"
    assert "granularity" in ch.card().knobs


def test_group_replicas_knob_scales_fleet():
    p = AgenticPipeline(PipelineConfig(n_testers=1))
    assert "tester-group" in p.registry.names()
    assert p.registry.card("tester-group").kind == "group"
    p.registry.set("tester-group", "replicas", 3)
    assert len(p.testers) == 3
    assert len(p.router.instances) == 3
    # scale back down: newest instances drain away once idle
    p.registry.set("tester-group", "replicas", 1)
    p.loop.run_until(p.loop.now() + 5.0)
    assert p.registry.get_param("tester-group", "replicas") == 1
    assert len(p.router.instances) == 1
    # reset restores the construction-time default (1) — already there
    p.registry.reset("tester-group", "replicas")
    assert p.registry.get_param("tester-group", "replicas") == 1
