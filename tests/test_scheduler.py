"""Scheduler + page-allocator invariants, including hypothesis property
tests over random workloads (skipped when hypothesis is not installed)."""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # pragma: no cover - env dependent
    HAVE_HYPOTHESIS = False

    def settings(**kw):                 # no-op decorators so module-level
        return lambda fn: fn            # @settings/@given still evaluate

    def given(*a, **kw):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def skipped():              # zero-arg: no fixture resolution
                pass
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **kw: None

    st = _AnyStrategy()

from repro.core.types import Priority, Request, RequestState
from repro.serving.kv_cache import PageAllocator
from repro.serving.scheduler import Scheduler, SchedulerConfig, StepKind


def _req(prompt=10, gen=5, prio=Priority.NORMAL):
    return Request(prompt_len=prompt, max_new_tokens=gen, priority=prio)


# ---------------------------------------------------------------------------
# PageAllocator
# ---------------------------------------------------------------------------

def test_allocator_basic():
    a = PageAllocator(num_pages=10, page_size=128)
    assert a.pages_for(1) == 1 and a.pages_for(128) == 1
    assert a.pages_for(129) == 2
    assert a.allocate("s1", 1000)        # 8 pages
    assert a.free_pages == 2
    assert not a.allocate("s2", 512)     # needs 4
    assert a.allocate("s2", 256)         # 2 fits
    a.free("s1")
    assert a.free_pages == 8


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "grow", "free"]),
                          st.integers(0, 7),
                          st.integers(0, 2000)), max_size=40))
def test_allocator_never_oversubscribes(ops):
    a = PageAllocator(num_pages=16, page_size=128)
    for op, sid, toks in ops:
        s = f"s{sid}"
        if op == "alloc":
            a.allocate(s, toks)
        elif op == "grow":
            a.grow_to(s, toks)
        else:
            a.free(s)
        used = sum(a._used.values())
        assert 0 <= used <= a.num_pages
        assert a.free_pages == a.num_pages - used


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------

def test_priority_admission_order():
    s = Scheduler(SchedulerConfig(max_slots=1, num_pages=64))
    lo = _req(prio=Priority.LOW)
    hi = _req(prio=Priority.INTERACTIVE)
    s.submit(lo)
    s.submit(hi)
    plan = s.plan_step()
    assert plan.kind == StepKind.PREFILL
    assert plan.prefills[0].req is hi            # one slot: high prio wins


def test_admit_priority_min_floor():
    s = Scheduler(SchedulerConfig(max_slots=4, num_pages=64,
                                  admit_priority_min=1))
    lo = _req(prio=Priority.LOW)
    s.submit(lo)
    assert s.plan_step().kind == StepKind.IDLE   # LOW = 0 < floor
    s.set_param("admit_priority_min", 0)
    assert s.plan_step().kind == StepKind.PREFILL


def test_prefill_chunking_respects_budget():
    s = Scheduler(SchedulerConfig(max_slots=4, num_pages=1024,
                                  max_batch_tokens=64, prefill_chunk=32))
    r = _req(prompt=200, gen=1)
    s.submit(r)
    plan = s.plan_step()
    assert plan.kind == StepKind.PREFILL
    assert sum(w.chunk for w in plan.prefills) <= 64


def test_progressive_availability_gates_prefill():
    s = Scheduler(SchedulerConfig(max_slots=2, num_pages=64))
    r = _req(prompt=100, gen=4)
    r.available = 0                               # nothing arrived yet
    s.submit(r)
    assert s.plan_step().kind == StepKind.IDLE
    r.feed(30)
    plan = s.plan_step()
    assert plan.kind == StepKind.PREFILL
    assert plan.prefills[0].chunk == 30
    r.prefilled = 30
    r.feed(70)
    plan = s.plan_step()
    assert plan.prefills[0].chunk == 70


def test_require_complete_prompt():
    s = Scheduler(SchedulerConfig(max_slots=2, num_pages=64,
                                  require_complete_prompt=True))
    r = _req(prompt=100, gen=4)
    r.available = 50
    s.submit(r)
    assert s.plan_step().kind == StepKind.IDLE
    r.feed(50)
    assert s.plan_step().kind == StepKind.PREFILL


def test_preemption_picks_lowest_priority_youngest():
    s = Scheduler(SchedulerConfig(max_slots=4, num_pages=12, page_size=128,
                                  max_context=1024))
    a = _req(prompt=256, gen=10, prio=Priority.HIGH)
    a.arrival_time = 0.0
    b = _req(prompt=256, gen=10, prio=Priority.LOW)
    b.arrival_time = 1.0
    c = _req(prompt=256, gen=10, prio=Priority.LOW)
    c.arrival_time = 2.0
    for r in (a, b, c):
        s.submit(r)
    s.plan_step()                                 # admits all three
    for r in (a, b, c):                           # prefill done -> running
        r.prefilled = r.prompt_len
        r.state = RequestState.RUNNING
    victim = s.preempt_one()
    assert victim is c                            # low prio, youngest
    assert victim.state == RequestState.PREEMPTED
    assert victim in s.waiting


def test_preempt_then_readmit_emits_each_token_once():
    """Regression (ISSUE-4 satellite): preempt_one used to zero
    ``generated``/``prefilled`` but keep ``output_tokens`` and
    ``first_token_time``, so a re-admitted victim re-emitted its tokens
    — duplicate output entries and a stale ttft stamp.  The victim's
    emission record must reset with its progress counters."""
    s = Scheduler(SchedulerConfig(max_slots=4, num_pages=12, page_size=128,
                                  max_context=1024))
    v = _req(prompt=256, gen=6, prio=Priority.LOW)
    s.submit(v)
    s.plan_step()
    v.prefilled = v.prompt_len
    v.state = RequestState.RUNNING
    # it decoded a bit before eviction
    v.generated = 3
    v.output_tokens.extend([11, 12, 13])
    v.first_token_time = 1.0
    victim = s.preempt_one()
    assert victim is v
    assert v.generated == 0 and v.prefilled == 0
    assert v.output_tokens == [] and v.first_token_time is None
    # drive the re-admitted victim to completion: exactly-once emission
    from repro.configs import get_config
    from repro.serving.engine_sim import SimEngine
    from repro.sim.clock import EventLoop
    from repro.sim.costmodel import CostModel
    loop = EventLoop()
    eng = SimEngine(loop, CostModel(get_config("agent-7b"), chips=4),
                    SchedulerConfig(max_slots=4, num_pages=64))
    eng.submit(v)
    loop.run_until(60.0)
    assert v.state == RequestState.FINISHED
    assert v.generated == v.max_new_tokens
    assert len(v.output_tokens) == v.max_new_tokens   # no duplicates


def test_preempt_readmit_end_to_end_no_duplicate_tokens():
    """Same property through the live engine loop: victims preempted
    mid-decode re-queue, re-prefill and re-decode; every finished
    request's output must still be exactly max_new_tokens long."""
    from repro.configs import get_config
    from repro.serving.engine_sim import SimEngine
    from repro.sim.clock import EventLoop
    from repro.sim.costmodel import CostModel
    loop = EventLoop()
    eng = SimEngine(loop, CostModel(get_config("agent-7b"), chips=4),
                    SchedulerConfig(max_slots=4, num_pages=64))
    reqs = [Request(prompt_len=120, max_new_tokens=40, priority=p)
            for p in (Priority.HIGH, Priority.NORMAL, Priority.LOW,
                      Priority.LOW)]
    for r in reqs:
        eng.submit(r)

    def evict():
        v = eng.scheduler.preempt_one()   # mid-flight decode eviction
        assert v is not None
        eng.kick()
    loop.call_at(0.05, evict)
    loop.call_at(0.15, evict)
    loop.run_until(300.0)
    assert eng.scheduler.preempt_count == 2
    assert all(r.state == RequestState.FINISHED for r in reqs)
    for r in reqs:
        assert len(r.output_tokens) == r.max_new_tokens
        assert r.generated == r.max_new_tokens


# ---------------------------------------------------------------------------
# Bit-exactness of the fifo_priority discipline (ISSUE-5 acceptance)
# ---------------------------------------------------------------------------


class _PreRefactorScheduler:
    """Verbatim mirror of the pre-tenancy inline scheduler logic
    (unified role, no cache): the sort lambda, the admit-while-
    admissible loop, and the lowest-priority-youngest preemption victim
    — exactly as they stood before the QueueDiscipline refactor.  The
    refactored scheduler's default ``fifo_priority`` discipline must
    reproduce this admit/preempt trace bit-exactly."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.alloc = PageAllocator(cfg.num_pages, cfg.page_size)
        self.waiting = []
        self.running = []
        self._free_slots = list(range(cfg.max_slots))
        self.preempt_count = 0

    def submit(self, req):
        req.state = RequestState.QUEUED
        if req.available < 0:
            req.available = req.prompt_len
        self.waiting.append(req)
        self._sort_waiting()

    def _sort_waiting(self):
        self.waiting.sort(key=lambda r: (
            -int(r.priority), r.deadline,
            -float(r.meta.get("cp_remaining", 0.0)), r.arrival_time))

    def _need(self, req):
        return min(req.prompt_len + req.max_new_tokens,
                   self.cfg.max_context)

    def _admissible(self, req):
        if int(req.priority) < self.cfg.admit_priority_min:
            return False
        if not self._free_slots:
            return False
        return self.alloc.can_allocate(self._need(req))

    def _admit(self, req):
        req.slot = self._free_slots.pop(0)
        if not self.alloc.allocate(req.req_id, self._need(req)):
            self.alloc.free(req.req_id)
            self._free_slots.insert(0, req.slot)
            req.slot = -1
            req.state = RequestState.QUEUED
            self.waiting.insert(0, req)
            return False
        req.state = RequestState.PREFILL
        self.running.append(req)
        return True

    def _release(self, req):
        self.alloc.free(req.req_id)
        if 0 <= req.slot < self.cfg.max_slots:
            self._free_slots.append(req.slot)
        req.slot = -1
        if req in self.running:
            self.running.remove(req)

    def finish(self, req, now):
        req.state = RequestState.FINISHED
        self._release(req)

    def preempt_one(self):
        candidates = [r for r in self.running
                      if r.state == RequestState.RUNNING]
        if not candidates:
            return None
        victim = min(candidates,
                     key=lambda r: (int(r.priority), -r.arrival_time))
        self._release(victim)
        victim.state = RequestState.PREEMPTED
        victim.prefilled = 0
        victim.generated = 0
        victim.output_tokens.clear()
        victim.first_token_time = None
        self.preempt_count += 1
        self.waiting.append(victim)
        self._sort_waiting()
        return victim

    def plan_step(self):
        if not self.cfg.decode_first or not self.running:
            while self.waiting and self._admissible(self.waiting[0]):
                if not self._admit(self.waiting.pop(0)):
                    break
        pending = [r for r in self.running
                   if r.state == RequestState.PREFILL
                   and r.prefilled < min(r.prompt_len, r.available)]
        if pending:
            budget = self.cfg.max_batch_tokens
            chunkcfg = self.cfg.prefill_chunk
            prefills = []
            for r in pending:
                if budget <= 0:
                    break
                remaining = min(r.prompt_len, r.available) - r.prefilled
                chunk = remaining if chunkcfg <= 0 else min(chunkcfg,
                                                            remaining)
                chunk = min(chunk, budget)
                if chunk <= 0:
                    continue
                prefills.append((r, chunk))
                budget -= chunk
            if prefills:
                return ("prefill", prefills)
        decodes = [r for r in self.running
                   if r.state == RequestState.RUNNING]
        if decodes:
            return ("decode", decodes)
        return ("idle", [])

    def ensure_decode_capacity(self, req):
        target = min(req.total_len + 1, self.cfg.max_context)
        while not self.alloc.grow_to(req.req_id, target):
            if not self.cfg.preempt:
                return False
            victim = self.preempt_one()
            if victim is None or victim is req:
                return False
        return True


def _mk_requests(specs):
    return [Request(prompt_len=p, max_new_tokens=g, priority=pr,
                    deadline=dl, arrival_time=float(i), req_id=f"x{i}",
                    meta={"cp_remaining": cp})
            for i, (p, g, pr, dl, cp) in enumerate(specs)]


def _normalize(plan):
    """One plan shape for both schedulers: (kind, [(id, chunk)] | [id])."""
    if isinstance(plan, tuple):                       # oracle
        kind, items = plan
        if kind == "prefill":
            return (kind, [(r.req_id, c) for r, c in items])
        return (kind, [r.req_id for r in items])
    if plan.kind == StepKind.PREFILL:
        return ("prefill", [(w.req.req_id, w.chunk) for w in plan.prefills])
    if plan.kind == StepKind.DECODE:
        return ("decode", [r.req_id for r in plan.decodes])
    return ("idle", [])


def _drive_trace(sched, reqs, ops):
    """Drive a scheduler through the op sequence, recording the full
    admit/plan/preempt trace after every op."""
    trace = []
    queue = list(reqs)
    for op in ops:
        if op == "submit":
            if queue:
                r = queue.pop(0)
                sched.submit(r)
                event = ("submitted", r.req_id)
            else:
                event = ("nosub", None)
        elif op == "preempt":
            v = sched.preempt_one()
            event = ("preempt", v.req_id if v is not None else None)
        else:
            plan = sched.plan_step()
            event = _normalize(plan)
            kind, items = event
            if kind == "prefill":
                for rid, chunk in items:
                    r = next(x for x in sched.running if x.req_id == rid)
                    r.prefilled += chunk
                    if r.prefilled >= r.prompt_len:
                        r.state = RequestState.RUNNING
            elif kind == "decode":
                for rid in items:
                    r = next((x for x in sched.running
                              if x.req_id == rid), None)
                    if r is None or not sched.ensure_decode_capacity(r):
                        continue
                    if r.state != RequestState.RUNNING:
                        continue
                    r.generated += 1
                    if r.done:
                        sched.finish(r, 0.0)
        trace.append((event,
                      [r.req_id for r in sched.waiting],
                      sorted(r.req_id for r in sched.running),
                      sched.preempt_count))
    return trace


_spec_st = st.tuples(st.integers(1, 300), st.integers(1, 20),
                     st.sampled_from(list(Priority)),
                     st.sampled_from([float("inf"), 1.0, 2.0]),
                     st.sampled_from([0.0, 1.5]))


@settings(max_examples=80, deadline=None)
@given(st.lists(_spec_st, min_size=1, max_size=16),
       st.lists(st.sampled_from(["submit", "step", "step", "submit",
                                 "preempt", "step"]),
                min_size=4, max_size=60))
def test_fifo_priority_bit_exact_with_pre_refactor_order(specs, ops):
    """ISSUE-5 acceptance: the default ``fifo_priority`` discipline
    reproduces the pre-refactor scheduler's admit/preempt trace
    bit-exactly on randomized workloads — the same plans, the same
    waiting order, the same victims, at every step."""
    cfg = SchedulerConfig(max_slots=4, num_pages=32, page_size=128,
                          max_context=512, max_batch_tokens=256,
                          prefill_chunk=64)
    new = Scheduler(cfg)
    assert new.discipline.name == "fifo_priority"    # the default
    old = _PreRefactorScheduler(SchedulerConfig(
        max_slots=4, num_pages=32, page_size=128,
        max_context=512, max_batch_tokens=256, prefill_chunk=64))
    trace_new = _drive_trace(new, _mk_requests(specs), ops)
    trace_old = _drive_trace(old, _mk_requests(specs), ops)
    assert trace_new == trace_old


@pytest.mark.parametrize("seed", range(25))
def test_fifo_priority_bit_exact_seeded(seed):
    """Deterministic twin of the hypothesis property above, so the
    bit-exactness check runs even where hypothesis is not installed."""
    import random
    rng = random.Random(seed)
    specs = [(rng.randint(1, 300), rng.randint(1, 20),
              rng.choice(list(Priority)),
              rng.choice([float("inf"), 1.0, 2.0]),
              rng.choice([0.0, 1.5]))
             for _ in range(rng.randint(1, 16))]
    ops = [rng.choice(["submit", "step", "step", "submit",
                       "preempt", "step"])
           for _ in range(rng.randint(8, 60))]
    cfg = dict(max_slots=4, num_pages=32, page_size=128,
               max_context=512, max_batch_tokens=256, prefill_chunk=64)
    new = Scheduler(SchedulerConfig(**cfg))
    old = _PreRefactorScheduler(SchedulerConfig(**cfg))
    trace_new = _drive_trace(new, _mk_requests(specs), ops)
    trace_old = _drive_trace(old, _mk_requests(specs), ops)
    assert trace_new == trace_old


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 300), st.integers(1, 20),
                          st.sampled_from(list(Priority))), min_size=1,
                max_size=24))
def test_scheduler_invariants_random_workload(reqs):
    """Drive random workloads to completion; invariants hold throughout."""
    s = Scheduler(SchedulerConfig(max_slots=4, num_pages=32, page_size=128,
                                  max_context=512, max_batch_tokens=256))
    pending = [Request(prompt_len=p, max_new_tokens=g, priority=pr)
               for p, g, pr in reqs]
    for r in pending:
        r.prompt_len = min(r.prompt_len, 300)
        s.submit(r)
    for step in range(2000):
        plan = s.plan_step()
        # invariant: slots never oversubscribed
        assert s.slots_in_use() <= s.cfg.max_slots
        assert s.slots_in_use() == len(s.running)
        # invariant: every running request holds pages
        for r in s.running:
            assert s.alloc.holds(r.req_id) > 0
        if plan.kind == StepKind.IDLE:
            break
        if plan.kind == StepKind.PREFILL:
            for w in plan.prefills:
                w.req.prefilled += w.chunk
                if w.req.prefilled >= w.req.prompt_len:
                    w.req.state = RequestState.RUNNING
        else:
            for r in plan.decodes:
                if not s.ensure_decode_capacity(r):
                    continue
                if r.state != RequestState.RUNNING:
                    continue
                r.generated += 1
                if r.done:
                    s.finish(r, float(step))
    # everything either finished or was preempted/waiting — no leaks
    assert s.slots_in_use() == len(s.running)
    finished = [r for r in pending if r.state == RequestState.FINISHED]
    for r in finished:
        assert s.alloc.holds(r.req_id) == 0       # pages released
