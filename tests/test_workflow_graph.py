"""Workflow graph plane: DAG structure, critical-path computation, and
deadline propagation — including a property test that topological
priority never inverts across an edge (skipped without hypothesis)."""
import math

import pytest

from repro.agents.agent import expected_tool_latency
from repro.agents.graph import (GraphError, GraphTask, WorkflowGraph,
                                debate, deep_review, fig1, map_reduce)
from repro.agents.stage import StageKind

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYP = True
except ImportError:                      # pragma: no cover - env dependent
    HAVE_HYP = False


def unit_cost(spec, est_in):
    """Deterministic hand-checkable cost: 1s per stage + 0.01s/out tok.
    TOOL stages charge their *expected* dwell under the heavy-tailed
    latency model (== tool_latency when the tail is off)."""
    if spec.kind is StageKind.TOOL:
        return expected_tool_latency(spec.tool_latency,
                                     spec.tool_latency_cv,
                                     spec.tool_timeout)
    return 1.0 + 0.01 * spec.out_tokens


# ---------------------------------------------------------------------------
# structure + validation
# ---------------------------------------------------------------------------


def test_construction_and_topo():
    g = WorkflowGraph("t")
    g.stage("a")
    g.stage("b")
    g.stage("c")
    g.chain("a", "b", "c")
    assert g.sources() == ["a"] and g.sinks() == ["c"]
    assert g.topo_order() == ["a", "b", "c"]
    assert g.preds("b") == ["a"] and g.succs("b") == ["c"]


def test_validation_errors():
    g = WorkflowGraph("bad")
    with pytest.raises(GraphError):
        g.validate()                     # empty
    g.stage("a")
    with pytest.raises(GraphError):
        g.add_edge("a", "zzz")           # unknown stage
    with pytest.raises(GraphError):
        g.add_edge("a", "a")             # self-edge
    g.stage("a2")
    g.add_edge("a", "a2")
    with pytest.raises(GraphError):
        g.add_edge("a", "a2")            # duplicate edge
    with pytest.raises(GraphError):
        g.stage("a")                     # duplicate stage

    cyc = WorkflowGraph("cycle")
    cyc.stage("x")
    cyc.stage("y")
    cyc.add_edge("x", "y")
    cyc.add_edge("y", "x")
    with pytest.raises(GraphError):
        cyc.validate()

    br = WorkflowGraph("branch1")
    br.stage("b", kind=StageKind.BRANCH)
    br.stage("only")
    br.add_edge("b", "only")
    with pytest.raises(GraphError):
        br.validate()                    # BRANCH needs >= 2 successors


def test_validate_rejects_branch_starved_fanin():
    """branch -> arm_a | arm_b -> merge: only one arm runs per task, so
    a merge that waits for ALL inputs can never fire — validate() must
    reject it unless join_k or join_timeout provides an escape."""
    def build(join_kw):
        g = WorkflowGraph("ifelse")
        g.stage("verdict", kind=StageKind.BRANCH)
        g.stage("arm_a")
        g.stage("arm_b")
        g.stage("merge", kind=StageKind.JOIN, **join_kw)
        g.add_edge("verdict", "arm_a")
        g.add_edge("verdict", "arm_b")
        g.add_edge("arm_a", "merge")
        g.add_edge("arm_b", "merge")
        return g

    with pytest.raises(GraphError, match="may never fire"):
        build({}).validate()
    build({"join_k": 1}).validate()            # escapes are accepted
    build({"join_timeout": 1.0}).validate()


def test_prebuilt_graphs_validate():
    for g in (fig1(), map_reduce(width=3), deep_review(depth=2), debate()):
        g.validate()
    assert fig1().template == "fig1"
    assert debate().stages["factcheck"].kind is StageKind.TOOL


# ---------------------------------------------------------------------------
# critical path: hand-built DAGs with known longest paths
# ---------------------------------------------------------------------------


def test_critical_path_chain():
    g = WorkflowGraph("chain")
    for n in ("a", "b", "c"):
        g.stage(n, out_tokens=0)        # unit_cost -> exactly 1.0 each
    g.chain("a", "b", "c")
    cp = g.critical_path(unit_cost)
    assert cp == {"a": 3.0, "b": 2.0, "c": 1.0}
    assert g.cp_total(cp) == 3.0


def test_critical_path_diamond_takes_heavier_arm():
    #      /-- fat (out 100) --\
    #  src                      sink     longest path = src+fat+sink
    #      \-- thin (out 0) ---/
    g = WorkflowGraph("diamond")
    g.stage("src", out_tokens=0)
    g.stage("fat", out_tokens=100)      # cost 2.0
    g.stage("thin", out_tokens=0)       # cost 1.0
    g.stage("sink", kind=StageKind.JOIN, out_tokens=0)
    g.add_edge("src", "fat")
    g.add_edge("src", "thin")
    g.add_edge("fat", "sink")
    g.add_edge("thin", "sink")
    cp = g.critical_path(unit_cost)
    assert cp["sink"] == 1.0
    assert cp["fat"] == 3.0 and cp["thin"] == 2.0
    assert cp["src"] == pytest.approx(1.0 + 3.0)    # via the fat arm


def test_critical_path_join_and_fanout_inputs():
    """est_inputs: a join sees the sum of its predecessors' outputs; a
    fan-out multiplies its per-call output by its width."""
    g = map_reduce(width=5, out_tokens=10)
    est = g.est_inputs(prompt_tokens=64)
    assert est["planner"] == 64.0
    assert est["map"] == float(g.stages["planner"].out_tokens)
    assert est["reduce"] == 5 * 10.0    # width x out_tokens
    # tool stages pass tokens through
    d = debate()
    est_d = d.est_inputs()
    assert est_d["judge"] == est_d["factcheck"]


def test_deadline_propagation_monotone_along_edges():
    """deadline(s) = submit + slack * (cp_total - cp_after(s)) must be
    non-decreasing along every edge; cp_remaining strictly decreases."""
    for g in (map_reduce(width=4), deep_review(depth=5), debate()):
        cp = g.critical_path(unit_cost)
        est = g.est_inputs()
        total = g.cp_total(cp)
        through = {n: total - (cp[n] - unit_cost(g.stages[n], est[n]))
                   for n in g.stages}
        for (u, v) in g.edges:
            assert cp[u] > cp[v], (g.name, u, v)
            assert through[u] <= through[v] + 1e-9, (g.name, u, v)


def test_critical_path_includes_tool_latency_pinned():
    """Hand-checked debate CPs: the TOOL stage sits on the longest path
    and contributes its full expected dwell.  Per-stage unit costs:
    moderator 1.48, pro/con 1.80, judge 1.72, verdict 1.24, revise 1.64
    (the heavier verdict arm), accept 1.16."""
    cp = debate().critical_path(unit_cost)       # tool_latency = 0.05
    assert cp["judge"] == pytest.approx(4.60)
    assert cp["factcheck"] == pytest.approx(4.65)
    assert cp["pro"] == pytest.approx(6.45)
    assert cp["moderator"] == pytest.approx(7.93)

    # heavy tail: the lognormal's *mean* (median * exp(sigma^2/2)), not
    # the nominal median, lands on the path — with cv=1 a "2 s" tool
    # really costs 2*sqrt(2) s per call in expectation
    tall = debate(tool_latency=2.0, tool_latency_cv=1.0)
    cph = tall.critical_path(unit_cost)
    exp_tool = 2.0 * math.sqrt(2.0)
    assert cph["factcheck"] == pytest.approx(exp_tool + 4.60)
    assert cph["moderator"] == pytest.approx(1.48 + 1.80 + exp_tool + 4.60)
    flat = debate(tool_latency=2.0).critical_path(unit_cost)
    assert cph["moderator"] > flat["moderator"]  # the tail is not free


def test_critical_path_fig1_pinned():
    """fig1 hand-check: developer (out 288) = 3.88, tester (out 40) =
    1.40; the chain's total is their sum."""
    cp = fig1().critical_path(unit_cost)
    assert cp["tester"] == pytest.approx(1.40)
    assert cp["developer"] == pytest.approx(5.28)
    assert fig1().cp_total(cp) == pytest.approx(5.28)


def test_deep_review_tool_insertion():
    """tool_latency > 0 threads a research TOOL stage after every
    reviewer; the default shape stays tool-free and the chain stays
    valid either way."""
    plain = deep_review(depth=3).validate()
    assert not any(s.kind is StageKind.TOOL for s in plain.stages.values())
    tooled = deep_review(depth=3, tool_latency=1.0, tool_latency_cv=0.5,
                         tool_timeout=4.0).validate()
    research = [n for n, s in tooled.stages.items()
                if s.kind is StageKind.TOOL]
    assert len(research) == 3
    assert tooled.stages["research-0"].tool_latency_cv == 0.5
    assert tooled.succs("reviewer-0") == ["research-0"]
    assert tooled.succs("research-0") == ["reviewer-1"]
    # every tool on the chain adds its expected dwell to the source cp
    cp_plain = plain.critical_path(unit_cost)
    cp_tool = tooled.critical_path(unit_cost)
    per_tool = expected_tool_latency(1.0, 0.5, 4.0)
    assert (cp_tool["author"] - cp_plain["author"]
            == pytest.approx(3 * per_tool))


def test_graph_task_defaults():
    t = GraphTask(session="s")
    assert t.deadline == math.inf and t.finished_at == 0.0
    assert t.task_id.startswith("wtask")


# ---------------------------------------------------------------------------
# property: topological priority never inverts across an edge
# ---------------------------------------------------------------------------


if HAVE_HYP:

    @st.composite
    def random_dags(draw):
        n = draw(st.integers(min_value=2, max_value=8))
        g = WorkflowGraph("rand")
        for i in range(n):
            g.stage(f"s{i}",
                    out_tokens=draw(st.integers(min_value=0, max_value=200)))
        # edges only i -> j with i < j: acyclic by construction
        for i in range(n):
            for j in range(i + 1, n):
                if draw(st.booleans()):
                    g.add_edge(f"s{i}", f"s{j}")
        return g

    @settings(max_examples=60, deadline=None)
    @given(random_dags())
    def test_priority_never_inverts_across_edges(g):
        cp = g.critical_path(unit_cost)
        est = g.est_inputs()
        total = g.cp_total(cp)
        for (u, v) in g.edges:
            # longest-remaining-path priority: upstream of an edge always
            # carries strictly more remaining work ...
            assert cp[u] > cp[v]
            # ... and its propagated finish-deadline is never later
            du = total - (cp[u] - unit_cost(g.stages[u], est[u]))
            dv = total - (cp[v] - unit_cost(g.stages[v], est[v]))
            assert du <= dv + 1e-9

else:                                    # pragma: no cover - env dependent

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_priority_never_inverts_across_edges():
        pass
