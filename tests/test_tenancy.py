"""Tenancy plane: tenant directory + buckets, queue disciplines,
router admission metering, guard policy, intent selector, rollups."""
import math

import pytest

from repro.core.controller import Controller
from repro.core.intent import compile_intent
from repro.core.metrics import CentralPoller, Collector, MetricBus, StateStore
from repro.core.policies import TenantGuardPolicy
from repro.core.registry import Registry
from repro.core.rules import RequestRule, RuleTable
from repro.core.tenancy import TenantDirectory, TenantSpec
from repro.core.types import Message, Priority, Request, RequestState
from repro.serving.router import Router
from repro.serving.scheduler import Scheduler, SchedulerConfig, StepKind
from repro.sim.clock import EventLoop


def _req(prompt=64, gen=8, prio=Priority.NORMAL, tenant="default", **kw):
    return Request(prompt_len=prompt, max_new_tokens=gen, priority=prio,
                   tenant=tenant, **kw)


# ---------------------------------------------------------------------------
# TenantDirectory + token buckets
# ---------------------------------------------------------------------------

def test_token_bucket_rate_and_refill():
    d = TenantDirectory()
    d.add(TenantSpec("t", rate=100.0, burst=200.0))
    assert d.try_take("t", 200, 0.0)          # full burst available
    assert not d.try_take("t", 50, 0.0)       # drained
    assert d.time_until("t", 50, 0.0) == pytest.approx(0.5)
    assert d.try_take("t", 50, 0.5)           # refilled 50 tokens
    # bucket caps at burst: a long idle banks at most 200 tokens
    assert d.try_take("t", 150, 100.0)        # full -> 50 left
    assert not d.try_take("t", 100, 100.0)    # 50 < 100, not full


def test_oversized_message_passes_when_bucket_full():
    """A message costing more than ``burst`` must not deadlock: it
    passes once the bucket is full, driving the level negative (debt),
    and the long-run rate stays enforced."""
    d = TenantDirectory()
    d.add(TenantSpec("t", rate=100.0, burst=50.0))
    assert d.try_take("t", 200, 0.0)          # full bucket: debt allowed
    assert not d.try_take("t", 10, 0.0)       # in debt: held
    # refill horizon is bounded by burst, not by the oversized cost
    assert d.time_until("t", 200, 0.0) == pytest.approx(2.0)
    assert d.try_take("t", 200, 2.0)          # full again after 2s


def test_unmetered_and_paused_tenants():
    d = TenantDirectory()
    assert d.try_take("anon", 1e9, 0.0)       # auto-registered, unmetered
    assert d.time_until("anon", 1e9, 0.0) == 0.0
    d.get("anon").paused = True
    assert not d.try_take("anon", 1, 0.0)
    assert d.time_until("anon", 1, 0.0) == math.inf


def test_tenant_entry_is_a_table1_controllable():
    reg = Registry()
    d = TenantDirectory(registry=reg)
    d.add(TenantSpec("gold", weight=4.0, rate=100.0))
    assert "tenant.gold" in reg.names()
    reg.set("tenant.gold", "weight", 8.0)
    assert d.weight("gold") == 8.0
    reg.set("tenant.gold", "paused", True)
    assert d.paused("gold")
    reg.reset("tenant.gold", "weight")
    assert d.weight("gold") == 4.0
    with pytest.raises(ValueError):
        d.add(TenantSpec("gold"))             # duplicate


def test_knob_change_fires_release_hooks():
    d = TenantDirectory()
    d.add(TenantSpec("t", rate=1.0))
    fired = []
    d.subscribe_release(lambda: fired.append(1))
    d.get("t").set_param("rate", 50.0)
    assert fired
    d.get("t").set_param("rate", 50.0)        # no-op change: no re-fire
    assert len(fired) == 1


def test_rollups_published():
    bus = MetricBus()
    col = Collector("n", bus=bus)
    d = TenantDirectory(collector=col, share_pub_interval=0.0)
    d.add(TenantSpec("a"))
    d.add(TenantSpec("b"))
    d.note_served("a", 300, 1.0)
    d.note_served("b", 100, 1.001)
    assert col.last("tenant.a.share") == pytest.approx(0.75)
    assert col.last("tenant.b.share") == pytest.approx(0.25)
    for v in (0.1, 0.2, 1.0):
        d.observe_ttft("a", v, 2.0)
    # derived via FleetAggregate.watch_window on the bus path
    assert col.last("tenant.a.p95_ttft") == pytest.approx(0.92)
    d.note_admitted("a", 64, 3.0)
    d.note_throttled("a", 3.1)
    assert col.last("tenant.a.throttle_rate") == pytest.approx(0.5)
    assert d.get("a").throttled_count == 1
    assert d.get("a").admitted_tokens == 64


def test_rollups_without_bus_fall_back():
    col = Collector("n")                      # no bus: RollingStat path
    d = TenantDirectory(collector=col)
    for v in (0.1, 0.2, 1.0):
        d.observe_ttft("a", v, 1.0)
    assert col.last("tenant.a.p95_ttft") == pytest.approx(0.92)


# ---------------------------------------------------------------------------
# Queue disciplines
# ---------------------------------------------------------------------------

def test_weighted_fair_orders_by_tenant_virtual_time():
    d = TenantDirectory()
    d.add(TenantSpec("noisy", weight=1.0))
    d.add(TenantSpec("gold", weight=4.0))
    s = Scheduler(SchedulerConfig(max_slots=4, num_pages=64,
                                  discipline="weighted_fair"), tenants=d)
    noisy = [_req(tenant="noisy") for _ in range(3)]
    for i, r in enumerate(noisy):
        r.arrival_time = float(i)
        s.submit(r)
    gold = _req(tenant="gold")
    gold.arrival_time = 10.0                  # arrives LAST (both active)
    s.submit(gold)
    s.charge(noisy[0], 800, 0.0)              # noisy far over share
    s._sort_waiting()
    assert s.waiting[0] is gold               # but sorts FIRST


def test_weighted_fair_priority_preserved_within_tenant():
    s = Scheduler(SchedulerConfig(max_slots=4, num_pages=64,
                                  discipline="weighted_fair"))
    lo = _req(tenant="t", prio=Priority.LOW)
    hi = _req(tenant="t", prio=Priority.INTERACTIVE)
    lo.arrival_time, hi.arrival_time = 0.0, 1.0
    s.submit(lo)
    s.submit(hi)
    assert s.waiting[0] is hi


def test_weighted_fair_idle_tenant_banks_no_credit():
    s = Scheduler(SchedulerConfig(max_slots=4, num_pages=256,
                                  discipline="weighted_fair"))
    busy = _req(tenant="busy")
    s.submit(busy)
    s.plan_step()                             # admit busy
    s.charge(busy, 1000, 0.0)
    # "sleeper" was idle the whole time; on arrival it enters at the
    # active floor (busy's virtual time), not at 0
    sleeper = _req(tenant="sleeper")
    s.submit(sleeper)
    disc = s.discipline
    assert disc.vtime["sleeper"] == pytest.approx(disc.vtime["busy"])


def test_weighted_fair_active_tenant_keeps_lag_on_resubmit():
    """Regression: a new submit from a tenant that ALREADY has
    queued/running work must not re-floor its virtual time up to the
    other tenants' — that would erase an underserved tenant's accrued
    lag and neutralize the weight knob."""
    s = Scheduler(SchedulerConfig(max_slots=4, num_pages=256,
                                  discipline="weighted_fair"))
    g1, n1 = _req(tenant="gold"), _req(tenant="noisy")
    s.submit(g1)
    s.submit(n1)
    s.charge(g1, 10, 0.0)
    s.charge(n1, 1000, 0.0)
    g2 = _req(tenant="gold")
    s.submit(g2)                              # gold still has g1 queued
    assert s.discipline.vtime["gold"] == pytest.approx(10.0)


def test_weighted_fair_idle_tenant_debt_forgiven():
    """Regression: a tenant returning from idle re-enters AT the active
    floor in both directions — stale virtual-time debt from a past
    solo-busy period must not starve it in the new backlogged period."""
    s = Scheduler(SchedulerConfig(max_slots=4, num_pages=256,
                                  discipline="weighted_fair"))
    heavy = _req(tenant="heavy")
    s.submit(heavy)
    s.plan_step()
    s.charge(heavy, 1_000_000, 0.0)           # ran alone, huge vtime
    s.finish(heavy, 0.0)                      # drains; goes idle
    fresh = _req(tenant="fresh")
    s.submit(fresh)                           # enters at floor 0
    back = _req(tenant="heavy")
    s.submit(back)                            # returns from idle
    assert s.discipline.vtime["heavy"] == pytest.approx(
        s.discipline.vtime["fresh"])


def test_weighted_fair_weight_divides_charge():
    d = TenantDirectory()
    d.add(TenantSpec("heavy", weight=4.0))
    d.add(TenantSpec("light", weight=1.0))
    s = Scheduler(SchedulerConfig(max_slots=4, num_pages=64,
                                  discipline="weighted_fair"), tenants=d)
    a, b = _req(tenant="heavy"), _req(tenant="light")
    s.submit(a)
    s.submit(b)
    s.charge(a, 400, 0.0)
    s.charge(b, 400, 0.0)
    assert s.discipline.vtime["heavy"] == pytest.approx(100.0)
    assert s.discipline.vtime["light"] == pytest.approx(400.0)


def test_preemption_victim_from_most_over_share_tenant():
    s = Scheduler(SchedulerConfig(max_slots=4, num_pages=64,
                                  discipline="weighted_fair"))
    a = _req(tenant="over", prio=Priority.HIGH)
    b = _req(tenant="under", prio=Priority.LOW)
    for r in (a, b):
        s.submit(r)
    s.plan_step()
    for r in (a, b):
        r.prefilled = r.prompt_len
        r.state = RequestState.RUNNING
    s.charge(a, 10_000, 0.0)                  # "over" way past its share
    victim = s.preempt_one()
    # fifo would evict b (LOW); fairness evicts the over-share tenant's
    # sequence even though it outranks b on priority
    assert victim is a


def test_paused_tenant_skipped_without_blocking_others():
    d = TenantDirectory()
    d.add(TenantSpec("p"))
    d.get("p").paused = True
    s = Scheduler(SchedulerConfig(max_slots=4, num_pages=64), tenants=d)
    blocked = _req(tenant="p", prio=Priority.HIGH)
    ok = _req(tenant="q", prio=Priority.LOW)
    s.submit(blocked)
    s.submit(ok)
    plan = s.plan_step()
    assert plan.kind == StepKind.PREFILL
    assert [w.req for w in plan.prefills] == [ok]
    assert blocked in s.waiting               # held, not dropped
    d.get("p").paused = False
    plan = s.plan_step()
    assert blocked in [w.req for w in plan.prefills]


def test_discipline_knob_switch_rebuilds_accounting():
    s = Scheduler(SchedulerConfig(max_slots=4, num_pages=64))
    assert s.discipline.name == "fifo_priority"
    s.set_param("discipline", "weighted_fair")
    assert s.discipline.name == "weighted_fair"
    s.charge(_req(tenant="t"), 100, 0.0)
    assert s.discipline.vtime["t"] == 100.0
    s.set_param("discipline", "fifo_priority")
    s.set_param("discipline", "weighted_fair")
    assert s.discipline.vtime == {}           # fresh accounting


# ---------------------------------------------------------------------------
# Router admission metering
# ---------------------------------------------------------------------------

class _Sink:
    def __init__(self, name="sink"):
        self.name = name
        self.got = []

    def deliver(self, msg):
        self.got.append(msg)

    def load(self):
        return 0.0


def _msg(tenant, tokens=100, mid=None):
    m = Message(src="a", dst="b", payload={"session": "s"}, tokens=tokens,
                tenant=tenant)
    if mid:
        m.msg_id = mid
    return m


def test_router_throttles_then_releases_on_refill():
    loop = EventLoop()
    d = TenantDirectory()
    d.add(TenantSpec("t", rate=100.0, burst=100.0))
    r = Router(loop, tenants=d)
    sink = _Sink()
    r.add_instance(sink)
    r.deliver(_msg("t", tokens=100))          # burst spent
    r.deliver(_msg("t", tokens=100))          # held
    r.deliver(_msg("t", tokens=100))          # held
    assert len(sink.got) == 1
    assert r.throttled_count == 2
    loop.run_until(3.0)                       # refill drip: both release
    assert len(sink.got) == 3
    assert r.throttled_count == 0
    assert d.get("t").throttled_count == 2    # counted once per message


def test_router_fresh_arrivals_do_not_starve_held_messages():
    """Regression: while a tenant has throttled messages held, new
    arrivals must queue behind them — not steal the refilled tokens out
    from under a large held message."""
    loop = EventLoop()
    d = TenantDirectory()
    d.add(TenantSpec("t", rate=100.0, burst=100.0))
    r = Router(loop, tenants=d)
    sink = _Sink()
    r.add_instance(sink)
    r.deliver(_msg("t", tokens=100, mid="first"))   # burst spent
    big = _msg("t", tokens=100, mid="big")
    r.deliver(big)                                  # held
    # stream of small arrivals that would fit the partial refill
    for i in range(5):
        loop.run_until(loop.now() + 0.3)            # ~30 tokens refill
        r.deliver(_msg("t", tokens=20, mid=f"small{i}"))
    loop.run_until(loop.now() + 5.0)                # drain everything
    order = [m.msg_id for m in sink.got]
    assert order[0] == "first"
    assert order[1] == "big"                        # held head drains first
    assert set(order[2:]) == {f"small{i}" for i in range(5)}


def test_router_pause_holds_until_knob_release():
    loop = EventLoop()
    d = TenantDirectory()
    d.add(TenantSpec("t"))
    d.get("t").paused = True
    r = Router(loop, tenants=d)
    sink = _Sink()
    r.add_instance(sink)
    r.deliver(_msg("t"))
    loop.run_until(5.0)
    assert not sink.got and r.throttled_count == 1
    d.get("t").set_param("paused", False)     # knob change pumps the held set
    assert len(sink.got) == 1 and r.throttled_count == 0


def test_router_unmetered_tenants_flow_untouched():
    loop = EventLoop()
    r = Router(loop, tenants=TenantDirectory())
    sink = _Sink()
    r.add_instance(sink)
    r.deliver(_msg("whoever"))
    assert len(sink.got) == 1


def test_blocked_then_released_message_not_double_charged():
    loop = EventLoop()
    d = TenantDirectory()
    d.add(TenantSpec("t", rate=1000.0, burst=100.0))
    rules = RuleTable()
    rules.install(RequestRule(tenant="t", block=True))
    r = Router(loop, rules=rules, tenants=d)
    sink = _Sink()
    r.add_instance(sink)
    r.deliver(_msg("t", tokens=100))          # metered, then rule-blocked
    assert r.held_count == 1
    spent = d.get("t").admitted_tokens
    rules.remove_request_rules(lambda x: x.block)
    r.deliver(_msg("other", tokens=1))        # version bump pumps held
    assert len(sink.got) == 2
    assert d.get("t").admitted_tokens == spent  # no second charge


def test_request_rule_tenant_match():
    rt = RuleTable()
    rt.install(RequestRule(tenant="gold", route_to="i1"))
    assert rt.route_for(_msg("gold")) == "i1"
    assert rt.route_for(_msg("other")) is None
    rt.install(RequestRule(tenant="b-*", block=True))
    assert rt.blocked(_msg("b-3"))
    assert not rt.blocked(_msg("gold"))


# ---------------------------------------------------------------------------
# Guard policy + intent selector
# ---------------------------------------------------------------------------

def _control_plane():
    loop = EventLoop()
    bus = MetricBus()
    col = Collector("n", bus=bus)
    store = StateStore()
    poller = CentralPoller(store)
    poller.attach(col)
    reg = Registry()
    c = Controller(loop, reg, poller, interval=0.05, bus=bus)
    return loop, bus, col, store, reg, c


def test_tenant_guard_policy_tightens_and_relaxes():
    loop, bus, col, store, reg, c = _control_plane()
    d = TenantDirectory(collector=col, registry=reg)
    d.add(TenantSpec("gold", weight=4.0))
    d.add(TenantSpec("batch", slo_class="batch"))
    pol = TenantGuardPolicy("gold", ["batch"], slo_ttft=0.5, sustain=2)
    c.install(pol)
    for i in range(6):
        d.observe_ttft("gold", 2.0, 0.01 * i)     # sustained breach
    c.start()
    loop.run_until(0.3)
    assert pol.tightened
    assert d.weight("gold") == 8.0
    assert d.paused("batch")
    # recovery samples land late enough that the breach ages out of the
    # policy's 2s evaluation window before the relax check
    for i in range(30):
        d.observe_ttft("gold", 0.01, 2.5 + 0.01 * i)
    loop.run_until(5.0)
    assert not pol.tightened
    assert d.weight("gold") == 4.0
    assert not d.paused("batch")


def test_tenant_guard_relaxes_when_gold_goes_quiet():
    """Regression: a tightened guard must not leave batch tenants
    paused (= starved) forever once the gold tenant stops sending —
    no-samples-in-window means there is nothing left to protect."""
    loop, bus, col, store, reg, c = _control_plane()
    d = TenantDirectory(collector=col, registry=reg)
    d.add(TenantSpec("gold", weight=4.0))
    d.add(TenantSpec("batch", slo_class="batch"))
    pol = TenantGuardPolicy("gold", ["batch"], slo_ttft=0.5, sustain=2,
                            window=1.0)
    c.install(pol)
    for i in range(6):
        d.observe_ttft("gold", 2.0, 0.01 * i)     # breach, then silence
    c.start()
    loop.run_until(0.3)
    assert pol.tightened and d.paused("batch")
    loop.run_until(3.0)                           # breach ages out, no
    assert not pol.tightened                      # new gold samples
    assert not d.paused("batch")


def test_pool_submit_stamps_arrival_before_throttle_hold():
    """Regression: the TTFT clock starts at pool submission, so time a
    request spends held by the tenant meter is visible in its latency
    metrics — not silently excluded."""
    from repro.configs import get_config
    from repro.serving.disagg import DisaggPool
    from repro.serving.engine_sim import SimEngine
    from repro.serving.kv_transfer import (KVTransferManager,
                                           SessionDirectory)
    from repro.sim.costmodel import CostModel
    loop = EventLoop()
    d = TenantDirectory()
    d.add(TenantSpec("slow", rate=100.0, burst=64.0))
    cm = CostModel(get_config("agent-7b"), chips=1)
    eng = SimEngine(loop, cm, SchedulerConfig(max_slots=4, num_pages=256),
                    name="e0")
    kvx = KVTransferManager(loop, SessionDirectory(),
                            bytes_fn=cm.kv_transfer_bytes)
    pool = DisaggPool(loop, [eng], kvx, tenants=d)
    loop.run_until(2.0)                           # advance the clock
    r0 = Request(prompt_len=64, max_new_tokens=4, tenant="slow")
    pool.submit(r0)                               # drains the bucket
    r = Request(prompt_len=64, max_new_tokens=4, tenant="slow")
    pool.submit(r)                                # held by the meter
    assert pool.router.throttled_count == 1
    assert r.arrival_time == pytest.approx(2.0)   # stamped at submit
    loop.run_until(10.0)
    assert r.state is RequestState.FINISHED
    assert r.arrival_time == pytest.approx(2.0)   # engine kept the stamp
    assert r.first_token_time - r.arrival_time > 0.3  # hold is visible


def test_throttled_release_still_opens_prepinned_handoff():
    """Regression: a message released from the throttle queue must
    still consume its (prefill, decode) pre-pin and open the proactive
    handoff — the pin used to be recorded on the async re-delivery path
    where nothing ever consumed it."""
    from repro.configs import get_config
    from repro.serving.disagg import DisaggPool
    from repro.serving.engine_sim import SimEngine
    from repro.serving.kv_transfer import (KVTransferManager,
                                           SessionDirectory)
    from repro.sim.costmodel import CostModel
    loop = EventLoop()
    d = TenantDirectory()
    d.add(TenantSpec("slow", rate=200.0, burst=64.0))
    cm = CostModel(get_config("agent-7b"), chips=1)
    engines = [
        SimEngine(loop, cm,
                  SchedulerConfig(max_slots=4, num_pages=256,
                                  role=role), name=f"e{i}")
        for i, role in enumerate(("prefill", "decode"))]
    kvx = KVTransferManager(loop, SessionDirectory(),
                            bytes_fn=cm.kv_transfer_bytes)
    pool = DisaggPool(loop, engines, kvx, tenants=d)
    r0 = Request(prompt_len=256, max_new_tokens=4, tenant="slow")
    pool.submit(r0)                               # drains the bucket
    r = Request(prompt_len=256, max_new_tokens=4, tenant="slow")
    pool.submit(r)                                # held by the meter
    assert pool.router.throttled_count == 1
    loop.run_until(20.0)
    assert r0.state is RequestState.FINISHED
    assert r.state is RequestState.FINISHED
    assert pool.handoffs == 2                     # both went proactive
    assert pool.router._pairs == {}               # pins consumed, no leak


def test_intent_tenant_selector_end_to_end():
    loop, bus, col, store, reg, c = _control_plane()
    d = TenantDirectory(collector=col, registry=reg)
    d.add(TenantSpec("gold"))
    d.add(TenantSpec("batch"))
    c.install(compile_intent("""
rule guard on tenant gold.p95_ttft > 1.5 hold 2:
    => set tenant batch.weight 0.2; set tenant batch.paused true
"""))
    c.start()
    for i in range(4):
        d.observe_ttft("gold", 3.0, 0.01 * i)  # p95_ttft rollup > 1.5
    loop.run_until(0.5)
    assert d.weight("batch") == pytest.approx(0.2)
    assert d.paused("batch")


def test_intent_tenant_selector_desugars_conditions():
    pol = compile_intent("""
rule r1: when last(tenant gold.share) < 0.2 => set tenant gold.weight 9
""")
    term = pol.rules[0].cond.terms[0]
    assert term.metric == "tenant.gold.share"
