"""Physical page ids + kernel block tables: the allocator↔kernel page
contract.  Property: under any allocate/share/acquire/promote/free/drop
interleaving the physical ids stay a disjoint partition of
``range(num_pages)`` (free ∪ private ∪ shared), every count matches its
id list, and ``page_table`` rows are consistent.  Plus the end-to-end
check: KV scattered into pages by the allocator's tables attends
identically (interpret mode) to the same KV laid out contiguously."""
import random

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # pragma: no cover - env dependent
    HAVE_HYPOTHESIS = False

    def settings(**kw):                 # no-op decorators so module-level
        return lambda fn: fn            # @settings/@given still evaluate

    def given(*a, **kw):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def skipped():              # zero-arg: no fixture resolution
                pass
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **kw: None

    st = _AnyStrategy()

from repro.serving.kv_cache import PageAllocator, block_tables


# ---------------------------------------------------------------------------
# id-partition invariant
# ---------------------------------------------------------------------------

def _ids_partition(a: PageAllocator):
    """free ∪ private ∪ shared ids must tile [0, num_pages) exactly, and
    every count the scheduling plane reads must agree with the id lists
    the kernel plane gathers through."""
    free = list(a._free_ids)
    priv = [i for ids in a._seq_ids.values() for i in ids]
    shared = [i for ids in a._block_ids.values() for i in ids]
    everything = free + priv + shared
    assert len(everything) == a.num_pages, "ids leaked or duplicated"
    assert set(everything) == set(range(a.num_pages))
    assert len(free) == a.free_pages
    assert len(priv) == a.private_pages
    assert len(shared) == a.shared_pages
    for seq, ids in a._seq_ids.items():
        assert len(ids) == a.holds(seq)
    for bid, ids in a._block_ids.items():
        assert len(ids) == a._blocks[bid].pages
        assert a.block_pages(bid) == ids
    # page_table = acquired blocks (in order) then private pages
    for seq in set(a._used) | set(a._seq_blocks):
        want = [i for b in a._seq_blocks.get(seq, ())
                for i in a._block_ids.get(b, ())]
        want += a._seq_ids.get(seq, [])
        assert a.page_table(seq) == want
    # host tier: used ∪ free host ids are exactly host_capacity_pages
    # distinct ids, all outside the HBM range — the spill plane can
    # never leak into (or out of) the partition above
    h_free = list(a._host_free_ids)
    h_used = [i for ids in a._host_ids.values() for i in ids]
    h_all = h_free + h_used
    assert len(h_all) == len(set(h_all)) == a.host_capacity_pages, \
        "host ids leaked or duplicated"
    assert all(i >= a.num_pages for i in h_all)
    assert len(h_used) == a.host_pages
    assert len(h_free) == a.host_free_pages
    assert set(a._host_blocks) == set(a._host_ids)
    for seq in a._host_ids:             # suspended => zero HBM footprint
        assert seq not in a._seq_ids and seq not in a._used
        assert seq not in a._seq_blocks


def _random_walk(a: PageAllocator, ops):
    seqs = [f"s{i}" for i in range(4)]
    blocks = [f"b{i}" for i in range(4)]
    for op, i, n in ops:
        if op == "alloc":
            a.allocate(seqs[i % 4], n)
        elif op == "share":
            a.share(blocks[i % 4], 1 + n % 3)
        elif op == "acquire":
            a.acquire(seqs[i % 4], blocks[n % 4])
        elif op == "promote":
            a.promote(seqs[i % 4], blocks[n % 4], 1 + n % 2)
        elif op == "free":
            a.free(seqs[i % 4])
        elif op == "drop":
            a.drop_block(blocks[i % 4])
        elif op == "suspend":
            a.suspend(seqs[i % 4])
        elif op == "restore":
            a.restore(seqs[i % 4])
        elif op == "drop_susp":
            a.drop_suspended(seqs[i % 4])
        elif op == "setcap":
            a.set_host_capacity(n % 10)
        elif op == "reset":
            a.reset()
        _ids_partition(a)


_OPS = ["alloc", "share", "acquire", "promote", "free", "drop",
        "suspend", "restore", "drop_susp", "setcap", "reset"]


def test_id_partition_random_walk():
    """Deterministic stand-in for the hypothesis property (runs even
    where hypothesis is not installed)."""
    rng = random.Random(11)
    for trial in range(50):
        a = PageAllocator(num_pages=12, page_size=64,
                          host_capacity_pages=6)
        ops = [(rng.choice(_OPS), rng.randrange(4), rng.randrange(500))
               for _ in range(60)]
        _random_walk(a, ops)


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(
    st.sampled_from(_OPS),
    st.integers(0, 3), st.integers(0, 500)), max_size=60))
def test_id_partition_property(ops):
    _random_walk(PageAllocator(num_pages=12, page_size=64,
                               host_capacity_pages=6), ops)


# ---------------------------------------------------------------------------
# host spill tier (tool-call suspend/resume)
# ---------------------------------------------------------------------------

def test_suspend_restore_partition_roundtrip():
    a = PageAllocator(num_pages=8, page_size=64, host_capacity_pages=2)
    assert a.allocate("s0", 3 * 64)
    assert a.promote("s0", "sys", 1)     # 1 shared + 2 private pages
    assert a.acquire("s1", "sys")
    assert a.suspend("s0") == "host"     # only the private pages spill
    _ids_partition(a)
    assert a.is_suspended("s0") and a.host_pages == 2
    assert a.block_refs("sys") == 1      # the sharer keeps the prefix hot
    assert not a.allocate("s0", 64)      # suspended sequences can't grow
    assert a.restore("s0")
    _ids_partition(a)
    assert not a.is_suspended("s0") and a.host_pages == 0
    # the restored table leads with the re-acquired prefix chain
    assert a.page_table("s0")[0] == a.block_pages("sys")[0]
    assert a.holds("s0") == 2
    # a footprint beyond host capacity falls off the ladder to "drop"
    assert a.allocate("big", 5 * 64)
    assert a.suspend("big") == "drop"
    assert not a.is_suspended("big")
    _ids_partition(a)


def test_reset_clears_host_tier():
    a = PageAllocator(num_pages=8, page_size=64, host_capacity_pages=4)
    assert a.allocate("s0", 3 * 64)
    assert a.suspend("s0") == "host"
    assert a.host_pages == 3
    a.reset()
    assert a.host_pages == 0 and a.host_free_pages == 4
    assert not a.is_suspended("s0")
    _ids_partition(a)


# ---------------------------------------------------------------------------
# shared-prefix round trip into kernel block tables
# ---------------------------------------------------------------------------

def test_promote_moves_front_private_ids():
    a = PageAllocator(num_pages=16, page_size=64)
    assert a.allocate("s0", 4 * 64)
    before = a.page_table("s0")
    assert a.promote("s0", "blk", 2)
    # the *front* ids (prefix tokens) became the shared block; the table
    # seen by the kernel is unchanged — same pages, same order
    assert a.block_pages("blk") == before[:2]
    assert a.page_table("s0") == before
    _ids_partition(a)


def test_shared_prefix_rows_repeat_physical_ids():
    a = PageAllocator(num_pages=32, page_size=64)
    assert a.allocate("s0", 3 * 64)
    assert a.promote("s0", "sys", 2)
    # a second sequence acquires the cached prefix, then grows privately
    assert a.acquire("s1", "sys")
    assert a.allocate("s1", 2 * 64)     # 2 private pages after the prefix
    t0, t1 = a.page_table("s0"), a.page_table("s1")
    assert t0[:2] == t1[:2] == a.block_pages("sys")
    assert set(t0[2:]).isdisjoint(t1[2:])
    rows = block_tables(a, ["s0", "s1"], pad_to=6)
    assert [len(r) for r in rows] == [6, 6]
    assert rows[0][:3] == t0 and rows[0][3:] == [-1] * 3
    assert rows[1][:4] == t1 and rows[1][4:] == [-1] * 2
    # freeing the sharer keeps the block resident (refcounted), and the
    # survivor's table is untouched
    a.free("s1")
    assert a.page_table("s0") == t0
    _ids_partition(a)


def test_block_tables_ragged_rows_pad_with_minus_one():
    a = PageAllocator(num_pages=8, page_size=64)
    a.allocate("long", 3 * 64)
    a.allocate("short", 64)
    rows = block_tables(a, ["long", "short"])
    assert len(rows[0]) == len(rows[1]) == 3
    assert rows[1][1:] == [-1, -1]


# ---------------------------------------------------------------------------
# paged vs contiguous attention through allocator layouts (interpret)
# ---------------------------------------------------------------------------

def test_paged_attention_matches_contiguous_through_allocator():
    jax = pytest.importorskip("jax")
    from _jax_caps import HAVE_PALLAS_API, PALLAS_SKIP_REASON
    if not HAVE_PALLAS_API:
        pytest.skip(PALLAS_SKIP_REASON)
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import ops

    page, hkv, h, dh = 16, 2, 4, 64
    a = PageAllocator(num_pages=16, page_size=page)
    # s0 prefills 3 pages and promotes its 2-page prefix; s1 acquires the
    # prefix and adds one private page — classic shared-system-prompt
    assert a.allocate("s0", 3 * page)
    assert a.promote("s0", "sys", 2)
    assert a.acquire("s1", "sys")
    assert a.allocate("s1", page)
    tables = block_tables(a, ["s0", "s1"])
    ctx = [3 * page - 3, 2 * page + 7]      # non-page-aligned lengths
    b, width = len(tables), len(tables[0])

    # contiguous ground-truth KV per sequence (shared prefix identical)
    ks = jax.random.split(jax.random.key(3), 3)
    t_max = width * page
    prefix = jax.random.normal(ks[0], (2 * page, hkv, dh))
    k_seq = jax.random.normal(ks[1], (b, t_max, hkv, dh))
    v_seq = jax.random.normal(ks[2], (b, t_max, hkv, dh))
    k_seq = k_seq.at[:, :2 * page].set(prefix)        # same prefix content
    v_seq = v_seq.at[:, :2 * page].set(prefix[::-1])

    # scatter into the physical pool exactly where the tables point
    n_pool = a.num_pages
    k_pages = jnp.zeros((n_pool, page, hkv, dh))
    v_pages = jnp.zeros((n_pool, page, hkv, dh))
    for i, row in enumerate(tables):
        for j, pid in enumerate(row):
            if pid < 0:
                continue
            k_pages = k_pages.at[pid].set(k_seq[i, j * page:(j + 1) * page])
            v_pages = v_pages.at[pid].set(v_seq[i, j * page:(j + 1) * page])

    q = jax.random.normal(jax.random.key(4), (b, 1, h, dh))
    out_paged = ops.paged_decode_attention(
        q, k_pages, v_pages, jnp.asarray(tables, jnp.int32),
        jnp.asarray(ctx, jnp.int32), interpret=True)

    # contiguous oracle path: ring-style kpos masks per-sequence length
    kpos = jnp.broadcast_to(jnp.arange(t_max)[None], (b, t_max))
    kpos = jnp.where(kpos < jnp.asarray(ctx)[:, None], kpos, -1)
    qp = jnp.asarray(ctx) - 1
    out_contig = ops.decode_attention(q, k_seq, v_seq, kpos, qp,
                                      interpret=True)
    np.testing.assert_allclose(np.asarray(out_paged),
                               np.asarray(out_contig), atol=2e-5, rtol=2e-5)
