"""Disaggregation plane: role knob semantics, the prefill→decode
handoff pipeline, runtime role transitions (the ISSUE-4 acceptance
test), the disagg router policy, the RoleBalancerPolicy, and the
`engine` intent selector."""
import pytest

from repro.configs import get_config
from repro.core.controller import Controller
from repro.core.intent import compile_intent
from repro.core.metrics import (CentralPoller, Collector, FleetAggregate,
                                MetricBus, StateStore)
from repro.core.policies import RoleBalancerPolicy
from repro.core.registry import Registry
from repro.core.types import Request, RequestState
from repro.serving.disagg import DisaggPool
from repro.serving.engine_sim import SimEngine
from repro.serving.kv_transfer import KVTransferManager, SessionDirectory
from repro.serving.scheduler import Scheduler, SchedulerConfig, StepKind
from repro.sim.clock import EventLoop
from repro.sim.costmodel import CostModel


def _fleet(roles, slots=8, with_controller=False):
    loop = EventLoop()
    bus = MetricBus()
    col = Collector("t", bus=bus)
    cm = CostModel(get_config("agent-7b"), chips=4)
    engines = [
        SimEngine(loop, cm,
                  SchedulerConfig(max_slots=slots, num_pages=2048,
                                  max_context=4096, role=r),
                  name=f"e{i}", collector=col)
        for i, r in enumerate(roles)]
    kvx = KVTransferManager(loop, SessionDirectory(),
                            bytes_fn=cm.kv_transfer_bytes, collector=col)
    pool = DisaggPool(loop, engines, kvx, collector=col)
    if not with_controller:
        return loop, engines, kvx, pool
    store = StateStore()
    poller = CentralPoller(store)
    poller.attach(col)
    registry = Registry()
    for e in engines:
        registry.register(e)
    controller = Controller(loop, registry, poller, interval=0.05, bus=bus)
    return loop, engines, kvx, pool, controller


def _guard_no_decode_on_prefill_role(engines):
    """Wrap every scheduler's plan_step with the acceptance invariant:
    a prefill-role engine never plans a decode step."""
    for e in engines:
        orig = e.scheduler.plan_step

        def checked(e=e, orig=orig):
            plan = orig()
            assert not (plan.kind == StepKind.DECODE
                        and e.role == "prefill"), \
                f"{e.name}: decode planned while role=prefill"
            return plan
        e.scheduler.plan_step = checked


# ---------------------------------------------------------------------------
# Scheduler-level role semantics
# ---------------------------------------------------------------------------

def test_scheduler_prefill_role_never_plans_decode():
    s = Scheduler(SchedulerConfig(max_slots=4, num_pages=256,
                                  role="prefill"))
    r = Request(prompt_len=32, max_new_tokens=8)
    s.submit(r)
    plan = s.plan_step()
    assert plan.kind == StepKind.PREFILL
    r.prefilled = r.prompt_len
    r.state = RequestState.RUNNING
    assert s.plan_step().kind == StepKind.IDLE     # never DECODE


def test_scheduler_decode_role_never_admits_from_waiting():
    s = Scheduler(SchedulerConfig(max_slots=4, num_pages=256,
                                  role="decode"))
    s.submit(Request(prompt_len=32, max_new_tokens=8))
    assert s.plan_step().kind == StepKind.IDLE
    # ... but the admit_direct (handoff) path works
    r = Request(prompt_len=32, max_new_tokens=8)
    r.prefilled = r.prompt_len
    r.generated = 1
    assert s.admit_direct(r)
    assert s.plan_step().kind == StepKind.DECODE


def test_admit_direct_refused_on_prefill_role():
    s = Scheduler(SchedulerConfig(max_slots=4, num_pages=256,
                                  role="prefill"))
    r = Request(prompt_len=32, max_new_tokens=8)
    assert not s.admit_direct(r)


def test_role_gauges():
    s = Scheduler(SchedulerConfig(max_slots=4, num_pages=256))
    a = Request(prompt_len=100, max_new_tokens=4)
    s.submit(a)
    assert s.prefill_queue_tokens == 100
    s.plan_step()                    # admits; still unprefilled
    assert s.prefill_queue_tokens == 100
    a.prefilled = a.prompt_len
    a.state = RequestState.RUNNING
    assert s.prefill_queue_tokens == 0
    assert s.decode_slot_util == pytest.approx(0.25)


def test_role_knob_requires_fabric():
    loop = EventLoop()
    cm = CostModel(get_config("agent-7b"), chips=4)
    eng = SimEngine(loop, cm, SchedulerConfig(max_slots=4, num_pages=256))
    with pytest.raises(RuntimeError, match="fabric"):
        eng.set_param("role", "prefill")
    assert eng.role == "unified"     # reverted, not half-set


def test_fabricless_specialized_engines_fail_loud():
    """An engine *constructed* with a specialized role but never wired
    into a DisaggPool must raise instead of silently stranding work."""
    loop = EventLoop()
    cm = CostModel(get_config("agent-7b"), chips=4)
    pre = SimEngine(loop, cm, SchedulerConfig(max_slots=4, num_pages=256,
                                              role="prefill"))
    pre.submit(Request(prompt_len=32, max_new_tokens=8))
    with pytest.raises(RuntimeError, match="no disaggregation fabric"):
        loop.run_until(10.0)         # prefill completes -> no sink
    dec = SimEngine(loop, cm, SchedulerConfig(max_slots=4, num_pages=256,
                                              role="decode"))
    with pytest.raises(RuntimeError, match="fabric"):
        dec.submit(Request(prompt_len=32, max_new_tokens=8))


def test_preempt_on_decode_engine_bounces_victim():
    """A victim preempted on a decode-role engine cannot be re-admitted
    there (decode role never admits from waiting): it must bounce back
    through the fabric, re-prefill elsewhere, and still finish."""
    loop, engines, kvx, pool = _fleet(("prefill", "decode"))
    r = Request(prompt_len=128, max_new_tokens=64)
    pool.submit(r)
    arrival = r.arrival_time
    dec = engines[1]

    def evict():
        assert r in dec.scheduler.running     # decoding on the decode eng
        v = dec.scheduler.preempt_one()
        assert v is r
        assert r not in dec.scheduler.waiting  # bounced, not stranded
    loop.call_at(0.15, evict)
    loop.run_until(120.0)
    assert r.state == RequestState.FINISHED
    assert len(r.output_tokens) == r.max_new_tokens
    assert pool.handoffs >= 2        # original + post-bounce re-handoff
    # the bounce re-enters submit, but latency still counts from the
    # ORIGINAL arrival — restamping would hide pre-preemption queueing
    assert r.arrival_time == arrival


def test_one_token_requests_leave_no_handoff_records():
    """A pre-pinned request that finishes at its first token never
    reaches the handoff path; its record must still be cleaned up."""
    loop, engines, kvx, pool = _fleet(("prefill", "decode"))
    reqs = [Request(prompt_len=64, max_new_tokens=1) for _ in range(5)]
    for r in reqs:
        pool.submit(r)
    assert kvx.handoff_records           # pre-pins opened at submit
    loop.run_until(30.0)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert pool.handoffs == 0            # done at first token: no handoff
    assert not kvx.handoff_records       # ... and no leaked records


def test_stale_decode_step_never_emits_for_migrated_request():
    """A decode step in flight when its requests migrate must not emit
    tokens for them on the old engine — even if the destination has
    already re-admitted them to RUNNING (the state check alone cannot
    tell the two engines apart)."""
    loop, engines, kvx, pool = _fleet(("unified", "unified"))
    # near-instant transfers so re-admission can beat the stale step
    kvx.bandwidth = 1e15
    kvx.latency = 1e-7
    e0, e1 = engines
    reqs = [Request(prompt_len=32, max_new_tokens=400) for _ in range(4)]
    for r in reqs:
        e0.submit(r)                     # all decode on e0
    loop.run_until(0.05)
    decoding = [r for r in reqs if r.state == RequestState.RUNNING
                and r in e0.scheduler.running]
    assert decoding                      # mid-flight on e0
    e0.set_param("role", "prefill")      # drains them to e1
    before = e0.tokens_generated
    loop.run_until(0.2)                  # stale e0 step lands in here
    assert e0.tokens_generated == before  # no emission post-migration
    for r in decoding:
        assert r in e1.scheduler.running or r.state == RequestState.FINISHED
    loop.run_until(120.0)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert all(len(r.output_tokens) == r.max_new_tokens for r in reqs)
    # e0's slots were never corrupted by a stale finish
    assert e0.scheduler.slots_in_use() == 0


def test_arrival_rehomes_when_pinned_engine_left_decode_duty():
    """A handoff whose pinned decode engine flips to prefill while the
    KV tail is on the wire must re-home to another decode engine, not
    strand in that engine's backlog forever."""
    loop, engines, kvx, pool = _fleet(("prefill", "decode", "decode"))
    _guard_no_decode_on_prefill_role(engines)
    r = Request(prompt_len=2048, max_new_tokens=8)
    pool.submit(r)
    rec = kvx.handoff_records[r.req_id]
    pinned = rec.dst

    def flip_pinned():
        # flip the pinned target while the request is still in flight
        # (prefilling or mid-transfer)
        assert r.state != RequestState.FINISHED
        self_eng = pool.engines[pinned]
        self_eng.set_param("role", "prefill")
    loop.call_at(0.01, flip_pinned)
    loop.run_until(120.0)
    assert r.state == RequestState.FINISHED
    assert len(r.output_tokens) == r.max_new_tokens
    assert not pool._backlog.get(pinned)       # nothing stranded there


def test_flip_to_decode_drops_stale_handoff_records():
    """A prefill engine flipped to decode grandfathers its mid-prefill
    sequences (they decode in place); their open handoff sessions must
    be dropped, not kept streaming to a stale destination."""
    loop, engines, kvx, pool = _fleet(("prefill", "decode", "decode"))
    engines[0].set_param("prefill_chunk", 64)
    r = Request(prompt_len=4096, max_new_tokens=4)
    pool.submit(r)
    assert r.req_id in kvx.handoff_records

    def flip():
        assert 0 < r.prefilled < r.prompt_len   # genuinely mid-prefill
        engines[0].set_param("role", "decode")
        assert r.req_id not in kvx.handoff_records
    loop.call_at(0.03, flip)
    loop.run_until(120.0)
    assert r.state == RequestState.FINISHED
    assert not kvx.handoff_records


def test_flip_to_unified_drops_stale_handoff_records():
    """A prefill engine re-unified mid-prefill decodes its sequences in
    place; their open handoff sessions must not leak records."""
    loop, engines, kvx, pool = _fleet(("prefill", "decode"))
    engines[0].set_param("prefill_chunk", 64)
    r = Request(prompt_len=4096, max_new_tokens=4)
    pool.submit(r)
    assert r.req_id in kvx.handoff_records    # pre-pinned at submit

    def reunify():
        assert r.prefilled < r.prompt_len     # genuinely mid-prefill
        engines[0].set_param("role", "unified")
        assert r.req_id not in kvx.handoff_records
    loop.call_at(0.02, reunify)
    loop.run_until(60.0)
    assert r.state == RequestState.FINISHED
    assert not kvx.handoff_records


# ---------------------------------------------------------------------------
# Handoff pipeline end-to-end
# ---------------------------------------------------------------------------

def test_disagg_pool_end_to_end():
    loop, engines, kvx, pool = _fleet(("prefill", "decode", "decode"))
    _guard_no_decode_on_prefill_role(engines)
    reqs = [Request(prompt_len=256, max_new_tokens=16) for _ in range(8)]
    for r in reqs:
        pool.submit(r)
    loop.run_until(60.0)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert all(len(r.output_tokens) == 16 for r in reqs)
    assert pool.handoffs == 8
    assert kvx.handoffs >= 8
    # first token (TTFT) produced by the prefill engine
    assert engines[0].tokens_generated == 8
    assert engines[0].decode_steps == 0
    # decode tail ran on the decode engines
    assert engines[1].prefill_steps == 0 and engines[2].prefill_steps == 0
    assert engines[1].tokens_generated + engines[2].tokens_generated \
        == 8 * 15
    # records are cleaned up after admission
    assert not kvx.handoff_records


def test_handoff_chunk_streaming_overlaps_prefill():
    """With chunked prefill, KV chunks stream while later chunks are
    still prefilling, so most bytes are on the wire before finish."""
    loop, engines, kvx, pool = _fleet(("prefill", "decode"))
    engines[0].set_param("prefill_chunk", 128)
    streamed_at_finish = {}
    orig = kvx.finish_handoff

    def spy(req_id, src, dst, total, on_ready):
        rec = kvx.handoff_records.get(req_id)
        streamed_at_finish[req_id] = rec.streamed_tokens if rec else 0
        return orig(req_id, src, dst, total, on_ready)
    kvx.finish_handoff = spy
    r = Request(prompt_len=1024, max_new_tokens=4)
    pool.submit(r)
    loop.run_until(30.0)
    assert r.state == RequestState.FINISHED
    # chunks for everything but the last prefill chunk streamed early
    assert streamed_at_finish[r.req_id] >= 1024 - 128


def test_unified_fleet_decodes_in_place():
    loop, engines, kvx, pool = _fleet(("unified", "unified"))
    reqs = [Request(prompt_len=64, max_new_tokens=8) for _ in range(4)]
    for r in reqs:
        pool.submit(r)
    loop.run_until(30.0)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert pool.handoffs == 0 and kvx.handoffs == 0


def test_disagg_router_prepins_decode_engine():
    loop, engines, kvx, pool = _fleet(("prefill", "decode", "decode"))
    r = Request(prompt_len=128, max_new_tokens=4)
    pool.submit(r)
    # pre-pin opened a handoff session before any prefill happened
    rec = kvx.handoff_records.get(r.req_id)
    assert rec is not None and rec.src == "e0"
    assert rec.dst in ("e1", "e2")
    assert pool.router.disagg_routed == 1
    loop.run_until(30.0)
    assert r.state == RequestState.FINISHED


# ---------------------------------------------------------------------------
# Runtime role transitions (the dedicated ISSUE-4 acceptance test)
# ---------------------------------------------------------------------------

def test_role_transition_drains_safely_via_set():
    """Flip roles mid-flight through set(): no request lost, no decode
    on a prefill-role engine, every token emitted exactly once."""
    loop, engines, kvx, pool = _fleet(("unified", "unified", "unified"))
    _guard_no_decode_on_prefill_role(engines)
    reqs = [Request(prompt_len=128, max_new_tokens=48) for _ in range(12)]
    for i, r in enumerate(reqs):
        loop.call_at(0.005 * i, lambda r=r: pool.submit(r))
    # mid-flight: specialize the fleet, then re-unify one engine
    loop.call_at(0.1, lambda: engines[0].set_param("role", "prefill"))
    loop.call_at(0.2, lambda: engines[1].set_param("role", "decode"))
    loop.call_at(0.6, lambda: engines[1].set_param("role", "unified"))
    loop.run_until(120.0)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    # exactly-once token emission (no duplicates from drains/migrations)
    assert all(len(r.output_tokens) == r.max_new_tokens for r in reqs)
    assert all(r.generated == r.max_new_tokens for r in reqs)
    assert pool.migrations > 0       # the flip really drained decodes


def test_role_transition_via_intent_rule():
    """The ISSUE-4 grammar: an event rule flips a role from a fleet
    gauge, through the same knob surface."""
    loop, engines, kvx, pool, controller = _fleet(
        ("prefill", "decode", "decode"), with_controller=True)
    _guard_no_decode_on_prefill_role(engines)
    policy = compile_intent(
        "rule surge on cluster.prefill_pressure > 2 hold 1:\n"
        "    => set engine e1.role prefill\n")
    controller.install(policy)
    controller.start()
    reqs = [Request(prompt_len=2048, max_new_tokens=8) for _ in range(24)]
    loop.call_at(0.5, lambda: [pool.submit(r) for r in reqs])
    loop.run_until(120.0)
    assert policy.rules[0].fire_count >= 1
    assert engines[1].role == "prefill"           # rule flipped it
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert all(len(r.output_tokens) == r.max_new_tokens for r in reqs)


def test_flip_to_decode_bounces_waiting_prompts():
    loop, engines, kvx, pool = _fleet(("unified", "unified"))
    e0, e1 = engines
    e0.set_param("paused", True)     # let work pile up un-admitted
    # fill e0's waiting queue directly (bypassing the router)
    reqs = [Request(prompt_len=64, max_new_tokens=4) for _ in range(3)]
    for r in reqs:
        e0.submit(r)
    assert e0.scheduler.queue_len == 3
    e0.set_param("role", "decode")   # waiting prompts bounce to e1
    assert e0.scheduler.queue_len == 0
    e0.set_param("paused", False)
    loop.run_until(30.0)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert e0.prefill_steps == 0     # e1 prefilled everything


# ---------------------------------------------------------------------------
# RoleBalancerPolicy
# ---------------------------------------------------------------------------

def test_role_balancer_conscripts_and_releases():
    loop, engines, kvx, pool, controller = _fleet(
        ("prefill", "decode", "decode"), with_controller=True)
    pol = RoleBalancerPolicy(
        [e.name for e in engines], pressure_hi=1.0, pressure_lo=0.05,
        min_prefill=1, min_decode=1, dwell=0.2, release_dwell=0.2,
        window=0.3, slot_profile={"prefill": 8, "decode": 8})
    controller.install(pol)
    controller.start()
    # sustained prefill flood: pressure >> hi
    reqs = [Request(prompt_len=2048, max_new_tokens=4) for _ in range(64)]
    for i, r in enumerate(reqs):
        loop.call_at(0.02 * i, lambda r=r: pool.submit(r))
    loop.run_until(8.0)
    ups = [f for f in pol.flips if f[2] == "prefill"]
    assert ups, "sustained pressure must conscript a prefill engine"
    loop.run_until(120.0)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    downs = [f for f in pol.flips if f[2] == "decode"]
    assert downs, "cleared pressure must release it back to decode"
    # guard rails held throughout: fleet never lost its decode path
    roles = pool.roles()
    assert any(r != "prefill" for r in roles.values())


def test_fleet_aggregate_publishes_cluster_gauges():
    bus = MetricBus()
    col = Collector("t", bus=bus)
    agg = FleetAggregate(col, prefix="cluster")
    agg.watch("q", ["a.x", "b.x"], how="sum")
    agg.watch("m", ["a.x", "b.x"], how="mean", scale=2.0)
    col.gauge("a.x", 3.0, 1.0)
    assert col.last("cluster.q") == 3.0
    col.gauge("b.x", 5.0, 2.0)
    assert col.last("cluster.q") == 8.0
    assert col.last("cluster.m") == 8.0          # mean 4 * scale 2
    # cluster gauges themselves ride the bus (intent triggers see them)
    fired = []
    bus.subscribe("cluster.q", above=7.0, fn=lambda n, v, t: fired.append(v))
    col.gauge("a.x", 4.0, 3.0)
    assert fired == [9.0]


def test_fleet_aggregate_requires_bus():
    with pytest.raises(ValueError):
        FleetAggregate(Collector("t"))


# ---------------------------------------------------------------------------
# intent selector sugar
# ---------------------------------------------------------------------------

def test_intent_engine_selector_desugars():
    pol = compile_intent(
        "rule r1: when last(engine e3.prefill_queue_tokens) > 5 "
        "=> set engine e3.role prefill; reset engine e3.max_num_seqs\n")
    term = pol.rules[0].cond.terms[0]
    assert term.metric == "e3.prefill_queue_tokens"   # selector dropped


def test_workflow_pipeline_builds_role_typed_pool():
    """TierSpec.roles turns a tier into a role-typed pool: stage calls
    prefill on the prefill replica and decode elsewhere, end to end
    through the workflow plane."""
    from repro.agents.graph import map_reduce
    from repro.agents.pipeline import (AgenticPipeline, TierSpec,
                                       WorkflowConfig)
    from repro.agents.workloads import GraphBurst
    cfg = WorkflowConfig(tiers={
        "large": TierSpec("agent-7b", chips=4, replicas=3, slots=16,
                          roles=("prefill", "decode", "decode"))},
        router_policy="least_loaded")
    wp = AgenticPipeline.build(map_reduce(width=4), cfg)
    _guard_no_decode_on_prefill_role(
        [w.engine for w in wp.workers])
    burst = GraphBurst(wp, 6, prompt_tokens=128, stagger=0.05)
    burst.start()
    wp.run(until=300.0)
    assert len(wp.done) == 6
    pool = wp.disagg_pools["large"]
    assert pool.handoffs > 0
    assert wp.workers[0].engine.decode_steps == 0     # prefill replica
    # the pool's cluster gauges are namespaced per tier
    assert pool.fleet is not None
    assert all(w.startswith("cluster.large.") for w in pool.fleet.watches)


def test_costmodel_handoff_time_overlap():
    cm = CostModel(get_config("agent-7b"), chips=4)
    raw = cm.handoff_time(2048, bandwidth=1e9, latency=1e-3)
    assert raw > 1e-3
    overlapped = cm.handoff_time(2048, bandwidth=1e9, latency=1e-3,
                                 overlap_s=raw)
    assert overlapped == pytest.approx(1e-3)      # floored at link latency
    assert cm.handoff_time(2048, bandwidth=1e9, latency=1e-3,
                           overlap_s=raw / 2) \
        == pytest.approx(raw / 2, rel=1e-6)
