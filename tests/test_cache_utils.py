"""Slot-level cache surgery: extract/insert round-trip across model
families — the mechanical basis of KV migration (serving/kv_transfer.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_config
from repro.serving import cache_utils


def _randomize(cache, key):
    leaves, treedef = jax.tree.flatten(cache)
    ks = jax.random.split(key, len(leaves))
    out = []
    for leaf, k in zip(leaves, ks):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            out.append(jax.random.normal(k, leaf.shape, leaf.dtype))
        else:
            out.append(jax.random.randint(k, leaf.shape, 0, 7
                                          ).astype(leaf.dtype))
    return jax.tree.unflatten(treedef, out)


@pytest.mark.parametrize("slot", [0, 1, 2])
def test_cache_extract_insert_round_trip(slot):
    cfg = get_config("tiny-agent")
    ctx = 64
    axes = cache_utils.batch_axes(cfg, ctx)
    cache = _randomize(models.init_cache(cfg, 3, ctx), jax.random.key(0))
    sub = cache_utils.cache_extract(cache, slot, axes)
    # the extracted slice is batch=1 shaped
    for leaf, ax in zip(jax.tree.leaves(sub), axes[1]):
        assert leaf.shape[ax] == 1
    # inserting it back into a blank cache reproduces exactly that slot
    blank = models.init_cache(cfg, 3, ctx)
    merged = cache_utils.cache_insert(blank, sub, slot, axes)
    back = cache_utils.cache_extract(merged, slot, axes)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(sub)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # ...and leaves the other slots untouched
    other = (slot + 1) % 3
    for a, b in zip(jax.tree.leaves(
                        cache_utils.cache_extract(merged, other, axes)),
                    jax.tree.leaves(
                        cache_utils.cache_extract(blank, other, axes))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cache_insert_then_extract_is_identity_on_foreign_cache():
    """Migration path: state extracted on one engine lands bit-exact in a
    different (non-blank) destination cache."""
    cfg = get_config("tiny-agent")
    ctx = 32
    axes = cache_utils.batch_axes(cfg, ctx)
    src = _randomize(models.init_cache(cfg, 2, ctx), jax.random.key(1))
    dst = _randomize(models.init_cache(cfg, 2, ctx), jax.random.key(2))
    sub = cache_utils.cache_extract(src, 1, axes)
    dst2 = cache_utils.cache_insert(dst, sub, 0, axes)
    back = cache_utils.cache_extract(dst2, 0, axes)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(sub)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
    assert cache_utils.cache_nbytes(sub) < cache_utils.cache_nbytes(src)
